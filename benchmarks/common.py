"""Shared helpers for the benchmark suite."""

import time

import jax
import jax.numpy as jnp


def lm_batch(cfg, b, s, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def timeit(fn, *args, repeats=3):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def csv(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
