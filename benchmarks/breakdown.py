"""Paper Fig. 16 — time breakdown per optimization plan.

  base  warm-up tracer + OPT eviction + device-aware OS placement
  osc   OS chunks forced to host (no device-aware placement) — the
        paper's "OSC" bar
  sp    static 20%% device budget for chunks, no tracer-guided budget —
        the paper's "SP" bar
"""

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine


def run(plan):
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")
    kw = dict(device_memory_bytes=5_000_000, policy="opt")
    if plan == "osc":
        kw["device_aware_placement"] = False
    if plan == "sp":
        kw["warmup_chunk_fraction"] = 0.2
        kw["device_aware_placement"] = False
    eng = PatrickStarEngine(model_class(cfg), cfg, **kw)
    if plan == "sp":
        # never leave warm-up budgeting: keep the static 20% partition
        eng.tracer.end_warmup = lambda: None
    batch = lm_batch(cfg, 4, 64)
    eng.step(batch)
    m = eng.step(batch)
    return m


def main():
    base = run("base")
    for plan in ("base", "osc", "sp"):
        m = run(plan)
        csv(f"breakdown/{plan}", m.total_s * 1e6,
            f"fwd={m.fwd_s:.3f};bwd={m.bwd_s:.3f};adam={m.adam_s:.3f};"
            f"moved_MB={m.moved_bytes/1e6:.2f}")


if __name__ == "__main__":
    main()
