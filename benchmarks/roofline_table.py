"""Assemble the §Dry-run / §Roofline tables from results/dryrun/*.json
plus the analytical cost model (see analysis/costmodel.py for why the
raw HLO numbers undercount scanned layers).

Writes results/roofline.md and prints a compact CSV.
"""

import json
import pathlib

from repro.analysis import costmodel
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gpt2-paper")]
HBM_PER_CHIP = 16e9  # v5e


def main():
    outdir = pathlib.Path("results/dryrun")
    rows = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape_name, shape in INPUT_SHAPES.items():
            rec_p = outdir / f"{arch}__{shape_name}__1pod.json"
            rec2_p = outdir / f"{arch}__{shape_name}__2pod.json"
            if not rec_p.exists():
                continue
            rec = json.loads(rec_p.read_text())
            rec2 = json.loads(rec2_p.read_text()) if rec2_p.exists() else {}
            if rec.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape_name,
                             "status": "skipped",
                             "note": rec.get("reason", "")})
                continue
            ct = costmodel.analyze_pair(cfg, shape, dp=16, tp=16, pods=1)
            sec = ct.seconds()
            per_dev = rec.get("per_device_bytes", 0)
            # scan xs/ys cache double-buffer correction (decode shapes):
            # TPU while-loop buffer donation keeps one copy, the XLA:CPU
            # analysis reports two (args ~= one full cache set).
            adj = per_dev
            if shape.kind == "decode":
                adj = per_dev - rec.get("alias_bytes", 0)
            rows.append({
                "arch": arch, "shape": shape_name, "status": "ok",
                "status2pod": rec2.get("status", "-"),
                "per_dev_gb": per_dev / 1e9,
                "adj_gb": adj / 1e9,
                "fits": adj <= HBM_PER_CHIP,
                "a_compute_s": sec["compute_s"],
                "a_memory_s": sec["memory_s"],
                "a_coll_s": sec["collective_s"],
                "dominant": ct.dominant(),
                "hlo_flops": rec.get("flops", 0),
                "hlo_coll_bytes": rec.get("collective_link_bytes", 0),
                "a_flops": ct.flops,
                "model_flops": rec.get("model_flops_per_device", 0),
                "compile_s": rec.get("compile_s", 0),
            })

    md = ["# Roofline table (single-pod 16x16 = 256 chips, per device)",
          "",
          "| arch | shape | 2pod | dev GB (adj) | fits 16G | compute s | "
          "memory s | collective s | dominant | 6ND/analytic |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                      f"| skipped | {r['note'][:40]} |")
            continue
        useful = (r["model_flops"] / r["a_flops"]) if r["a_flops"] else 0
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['status2pod']} | "
            f"{r['per_dev_gb']:.2f} ({r['adj_gb']:.2f}) | "
            f"{'Y' if r['fits'] else 'N'} | "
            f"{r['a_compute_s']:.4g} | {r['a_memory_s']:.4g} | "
            f"{r['a_coll_s']:.4g} | {r['dominant']} | {useful:.2f} |")
    text = "\n".join(md) + "\n"
    pathlib.Path("results/roofline.md").write_text(text)
    print(text)


if __name__ == "__main__":
    main()
