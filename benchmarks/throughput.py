"""Paper Fig. 14/15 — iteration throughput across model sizes and
strategies (engine, simulated device, CPU wall-clock; relative numbers
are the signal, as the paper's Tflops are hardware-bound)."""

import time

import numpy as np

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine


def run(layers, policy, device_bytes, placement=True):
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=layers, param_dtype="float32", compute_dtype="float32")
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=device_bytes, policy=policy,
                            device_aware_placement=placement)
    batch = lm_batch(cfg, 4, 64)
    eng.step(batch)  # warm-up iteration (traces)
    t0 = time.perf_counter()
    n = 3
    moved = 0
    for _ in range(n):
        m = eng.step(batch)
        moved += m.moved_bytes
    dt = (time.perf_counter() - t0) / n
    # model flops per iteration ~ 6*N*D, D from the ACTUAL batch shape
    # (a literal 4*64 here silently diverged whenever the lm_batch args
    # above were edited)
    n_params = eng.cmap.total_numel
    tokens = int(np.prod(batch["tokens"].shape))
    flops = 6 * n_params * tokens
    return dt, flops / dt / 1e9, moved / n


def main():
    for layers in (2, 4, 8):
        dt, gflops, moved = run(layers, "opt", 6_000_000)
        csv(f"throughput/L{layers}", dt * 1e6,
            f"gflops={gflops:.2f};moved_MB={moved/1e6:.1f}")


if __name__ == "__main__":
    main()
