"""Paper Section 8.3 — eviction strategy ablation: CPU<->device chunk
traffic for OPT (tracer-guided Belady) vs LRU vs FIFO across budgets over
the unified (all-streams) heterogeneous pool, plus the schedule-driven
prefetcher's overlap split: post-warm-up staging must strictly reduce
critical-path H2D bytes vs demand paging at EQUAL total transfer volume.
Emits a JSON report with prefetch hit-rate and hidden vs critical bytes.
``--smoke`` runs a single budget (the assertions still fire) for CI."""

import argparse
import json

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine


def run(policy, budget, prefetch=False):
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=budget, policy=policy,
                            device_aware_placement=False, prefetch=prefetch)
    batch = lm_batch(cfg, 4, 64)
    eng.step(batch)
    m = eng.step(batch)
    eng.pool.check_invariants()
    assert eng.pool.peak_device_bytes <= budget
    return m


def adversarial_microbench():
    """LM fwd/bwd sweeps are LRU-friendly (reverse scans), so the engine
    numbers tie; the mechanism win shows on cyclic reference patterns —
    the manager-level Belady demonstration."""
    from repro.core.chunk import TensorSpec, build_chunk_map
    from repro.core.manager import ChunkManager
    from repro.core.state import TensorState

    import numpy as np

    specs = [TensorSpec(f"t{i}", (64,)) for i in range(8)]
    cmap = build_chunk_map(specs, 64)
    pattern = [0, 1, 2, 3] * 16
    out = {}
    # 3-chunk device tier, in the stream's real bytes (cmap chunk size x
    # the manager dtype) rather than a hardcoded fp32 itemsize
    dtype = np.dtype(np.float32)
    budget = 3 * cmap.chunk_size * dtype.itemsize
    for policy in ("opt", "lru", "fifo"):
        mgr = ChunkManager(cmap, dtype=dtype, device_capacity_bytes=budget,
                           policy=policy)
        moments = {}
        for m, t in enumerate(pattern):
            moments.setdefault(t, []).append(m)
        mgr.register_moments(moments)
        for m, t in enumerate(pattern):
            mgr.set_moment(m)
            mgr.access_tensor(f"t{t}")
            mgr.release_tensor(f"t{t}", TensorState.HOLD_AFTER_FWD)
        out[policy] = mgr.stats.total_bytes
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: one budget, assertions intact")
    args = ap.parse_args()
    report = {}
    budgets = (2_500_000,) if args.smoke else (2_500_000, 4_000_000, 6_000_000)
    for budget in budgets:
        demand = run("opt", budget, prefetch=False)
        vals = {"opt": demand.moved_bytes}
        vals.update({p: run(p, budget).moved_bytes for p in ("lru", "fifo")})
        csv(f"eviction/budget{budget//1_000_000}MB", 0.0,
            f"opt={vals['opt']};lru={vals['lru']};fifo={vals['fifo']}")
        assert vals["opt"] <= vals["lru"], vals

        # schedule-driven prefetch vs demand paging, OPT policy
        staged = run("opt", budget, prefetch=True)
        total = lambda m: m.h2d_bytes + m.adam_h2d_bytes
        assert total(staged) == total(demand), (total(staged), total(demand))
        assert staged.critical_h2d_bytes < demand.critical_h2d_bytes, (
            staged.critical_h2d_bytes, demand.critical_h2d_bytes)
        assert (staged.hidden_h2d_bytes + staged.critical_h2d_bytes
                == total(staged))
        report[f"budget_{budget}"] = {
            "policy_moved_bytes": vals,
            "total_h2d_bytes": total(staged),
            "demand_critical_h2d_bytes": demand.critical_h2d_bytes,
            "prefetch_critical_h2d_bytes": staged.critical_h2d_bytes,
            "prefetch_hidden_h2d_bytes": staged.hidden_h2d_bytes,
            "prefetch_hit_rate": round(staged.prefetch_hit_rate, 4),
        }
        csv(f"eviction/prefetch{budget//1_000_000}MB", 0.0,
            f"critical={staged.critical_h2d_bytes};"
            f"hidden={staged.hidden_h2d_bytes};"
            f"demand_critical={demand.critical_h2d_bytes};"
            f"hit_rate={staged.prefetch_hit_rate:.2f}")

    mb = adversarial_microbench()
    csv("eviction/cyclic_microbench", 0.0,
        f"opt={mb['opt']};lru={mb['lru']};fifo={mb['fifo']}")
    assert mb["opt"] < mb["lru"]
    report["cyclic_microbench"] = mb
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
