"""Transfer-timeline benchmark (Fig. 16-style step breakdown with stall
bars): the two-queue DMA model surfaces hidden bytes that exceed their
operator's overlap window as stall seconds, and bandwidth-aware prefetch
(issue depth/time chosen against the timeline's projected idle windows)
must cut those stalls vs the fixed ``lookahead=6 / max_inflight=2``
policy at IDENTICAL H2D/D2H byte volumes and identical training losses.

Asserted acceptance bars (--smoke runs them in CI):

  * infinite bandwidth => zero stall and step time == summed compute;
  * tight bandwidth    => aware_stall <= STALL_RATIO_BAR * fixed_stall,
    with per-step H2D and D2H byte volumes equal and losses bit-equal;
  * conservation: hidden + critical == h2d, wall == compute + stalls.

The differentiating scenario is the paper's device-aware placement
(Section 8.2): OS chunk groups living in GPU margin space are evicted by
FWD/activation pressure mid-step and must be restaged before their ADAM
moments.  The fixed-depth prefetcher issues at most 2 transfers ahead,
so the dense ADAM reference burst (4 streams per moment) arrives late;
the bandwidth-aware policy pre-stages the quads through BWD's long idle
window.  Emits a JSON report.
"""

import argparse
import json

from benchmarks.common import csv, lm_batch
from repro.analysis.costmodel import train_operator_costs
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.timeline import TransferTimeline

BUDGET = 4_000_000
STEPS = 3  # measured post-warm-up steps
STALL_RATIO_BAR = 0.85  # aware must cut total stall to <= this x fixed


def _cfg():
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")


def run(cfg, batch, *, h2d_bw, d2h_bw, aware, calibrated=False):
    tl = TransferTimeline.calibrated() if calibrated else \
        TransferTimeline(h2d_bandwidth=h2d_bw, d2h_bandwidth=d2h_bw)
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=BUDGET, policy="opt",
        device_aware_placement=True, timeline=tl,
        bandwidth_aware_prefetch=aware)
    eng.step(batch)  # warm-up (tracer + schedules + durations)
    out = {"h2d_bytes": 0, "d2h_bytes": 0, "hidden": 0, "critical": 0,
           "compute_s": 0.0, "h2d_stall_s": 0.0, "d2h_stall_s": 0.0,
           "gather_stall_s": 0.0, "wall_s": 0.0, "losses": []}
    for _ in range(STEPS):
        m = eng.step(batch)
        t = m.timeline
        out["h2d_bytes"] += m.h2d_bytes + m.adam_h2d_bytes
        out["d2h_bytes"] += m.d2h_bytes + m.adam_d2h_bytes
        out["hidden"] += m.hidden_h2d_bytes
        out["critical"] += m.critical_h2d_bytes
        out["compute_s"] += t.compute_s
        out["h2d_stall_s"] += t.h2d_stall_s
        out["d2h_stall_s"] += t.d2h_stall_s
        out["gather_stall_s"] += t.gather_stall_s
        out["wall_s"] += t.wall_s
        out["losses"].append(m.loss)
        # conservation: every wall second is classified exactly once
        assert abs(t.wall_s - t.step_s) <= 1e-9 * max(t.wall_s, 1e-30), (
            t.wall_s, t.step_s)
        assert m.hidden_h2d_bytes + m.critical_h2d_bytes \
            == m.h2d_bytes + m.adam_h2d_bytes
    out["stall_s"] = (out["h2d_stall_s"] + out["d2h_stall_s"]
                      + out["gather_stall_s"])
    eng.pool.check_invariants()
    return out


def telemetry_overhead_guard(cfg, batch, report):
    """The telemetry plane must be cheap: disabled it is one predicate
    per call site (covered by the byte-identity unit test); enabled it
    may not add more than 15% to a traced step's wall time.  Min over
    repeats plus a small absolute floor to keep CI timer noise out."""
    import time

    from repro.core.telemetry import Telemetry

    def once(hub):
        tl = TransferTimeline(h2d_bandwidth=None, d2h_bandwidth=None)
        eng = PatrickStarEngine(
            model_class(cfg), cfg, device_memory_bytes=BUDGET, policy="opt",
            device_aware_placement=True, timeline=tl, telemetry=hub)
        eng.step(batch)  # warm-up (compile + tracer + schedules)
        t0 = time.perf_counter()
        eng.step(batch)
        return time.perf_counter() - t0

    # interleave the two variants: host-load drift then hits both mins
    # equally instead of biasing whichever ran in the quiet window
    hub = Telemetry()
    pairs = [(once(None), once(hub)) for _ in range(4)]
    base = min(b for b, _ in pairs)
    traced = min(t for _, t in pairs)
    assert hub.events, "enabled hub recorded nothing"
    ratio = traced / base
    assert traced <= 1.15 * base + 1e-2, (
        f"telemetry overhead too high: {traced:.4f}s traced vs "
        f"{base:.4f}s disabled ({ratio:.2f}x)")
    report["telemetry_overhead"] = {
        "disabled_s": base, "enabled_s": traced, "ratio": round(ratio, 3)}
    csv("timeline/telemetry_overhead", 0.0,
        f"disabled={base:.3e};enabled={traced:.3e};ratio={ratio:.3f}")


def bar(label, r, scale):
    """One Fig. 16-style horizontal breakdown bar (text)."""
    seg = lambda s, ch: ch * max(int(round(s / scale * 60)), 1 if s > 0 else 0)
    print(f"  {label:<18} |{seg(r['compute_s'], '#')}"
          f"{seg(r['h2d_stall_s'], 'h')}{seg(r['d2h_stall_s'], 'd')}"
          f"{seg(r['gather_stall_s'], 'g')}| "
          f"step={r['wall_s']:.3e}s stall={r['stall_s']:.3e}s")


def distributed_breakdown(report):
    """Full mode: p=2 eager plane with a finite collective lane — the
    step decomposition gains a gather_stall term and the hidden/critical
    gather split becomes temporal."""
    from repro.core.distributed import DistributedPatrickStarEngine

    cfg = _cfg().replace(num_layers=2)
    batch = lm_batch(cfg, 4, 32)
    eng = DistributedPatrickStarEngine(
        model_class(cfg), cfg, nproc=2, device_memory_bytes=BUDGET,
        device_aware_placement=False,
        timeline_factory=lambda: TransferTimeline(collective_bandwidth=5e9))
    eng.step(batch)
    agg = {"compute_s": 0.0, "gather_stall_s": 0.0, "wall_s": 0.0}
    for _ in range(2):
        m = eng.step(batch)
        t = m.rank_metrics[0].timeline
        agg["compute_s"] += t.compute_s
        agg["gather_stall_s"] += t.gather_stall_s
        agg["wall_s"] += t.wall_s
        assert abs(t.wall_s - t.step_s) <= 1e-9 * max(t.wall_s, 1e-30)
    eng.check_invariants()
    assert agg["gather_stall_s"] > 0.0  # the collective lane is finite
    report["distributed_p2"] = agg
    csv("timeline/distributed_p2", 0.0,
        f"compute={agg['compute_s']:.3e};gather_stall={agg['gather_stall_s']:.3e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: one bandwidth point, assertions intact")
    args = ap.parse_args()
    cfg = _cfg()
    batch = lm_batch(cfg, 4, 64)

    # per-operator durations + chunk size fix the bandwidth scale: a
    # chunk's wire time in units of one forward layer's compute
    probe = PatrickStarEngine(model_class(cfg), cfg,
                              device_memory_bytes=BUDGET, policy="opt")
    cb = probe.params_mgr.chunk_bytes
    costs = train_operator_costs(cfg, global_batch=4, seq_len=64,
                                 num_layer_ops=4, chunk_bytes=cb)
    del probe

    report = {"budget_bytes": BUDGET, "chunk_bytes": cb,
              "fwd_layer_s": costs.fwd_layer_s}

    # -------- infinite bandwidth: stall is exactly zero ------------------
    inf = run(cfg, batch, h2d_bw=None, d2h_bw=None, aware=True)
    assert inf["stall_s"] == 0.0, inf
    assert abs(inf["wall_s"] - inf["compute_s"]) \
        <= 1e-9 * max(inf["wall_s"], 1e-30)
    report["infinite_bw"] = inf
    csv("timeline/infinite_bw", 0.0,
        f"compute={inf['compute_s']:.3e};stall={inf['stall_s']:.3e}")

    # -------- telemetry overhead guard (runs in smoke too) ---------------
    telemetry_overhead_guard(cfg, batch, report)

    # -------- calibrated bandwidth: absolute Fig. 16-style seconds -------
    # H2D/D2H at the roofline's PCIe-class host-link rate (collectives at
    # ICI rate) instead of ad-hoc test scales, so the reported breakdown
    # is in real seconds for the modeled hardware.
    from repro.analysis.roofline import HOST_LINK_BW, ICI_BW

    cal = run(cfg, batch, h2d_bw=None, d2h_bw=None, aware=True,
              calibrated=True)
    assert cal["wall_s"] >= cal["compute_s"] > 0.0, cal
    assert cal["h2d_bytes"] == inf["h2d_bytes"], (cal, inf)  # volume parity
    report["calibrated"] = {
        "host_link_bytes_per_s": HOST_LINK_BW,
        "collective_bytes_per_s": ICI_BW,
        **{k: v for k, v in cal.items() if k != "losses"},
    }
    csv("timeline/calibrated", 0.0,
        f"wall={cal['wall_s']:.3e};compute={cal['compute_s']:.3e};"
        f"stall={cal['stall_s']:.3e};h2d_bw={HOST_LINK_BW:.0f}")

    # -------- tight bandwidth: aware vs fixed at equal volumes -----------
    mults = (1.0,) if args.smoke else (0.5, 1.0, 2.0)
    print("step breakdown (#=compute h=h2d-stall d=d2h-stall g=gather-stall)")
    for mult in mults:
        bw = cb / (mult * costs.fwd_layer_s)  # chunk wire = mult fwd layers
        fixed = run(cfg, batch, h2d_bw=bw, d2h_bw=bw, aware=False)
        aware = run(cfg, batch, h2d_bw=bw, d2h_bw=bw, aware=True)
        # byte-volume neutrality: bandwidth-awareness changes WHEN bytes
        # move, never how many
        assert aware["h2d_bytes"] == fixed["h2d_bytes"], (aware, fixed)
        assert aware["d2h_bytes"] == fixed["d2h_bytes"], (aware, fixed)
        # training loss parity: prefetch policy never changes the math
        assert aware["losses"] == fixed["losses"], (aware["losses"],
                                                    fixed["losses"])
        ratio = aware["stall_s"] / fixed["stall_s"]
        assert ratio <= STALL_RATIO_BAR, (
            f"bandwidth-aware prefetch must cut stall to <= "
            f"{STALL_RATIO_BAR}x fixed-depth: got {ratio:.3f} "
            f"({aware['stall_s']:.3e} vs {fixed['stall_s']:.3e})")
        scale = max(fixed["wall_s"], aware["wall_s"])
        print(f"chunk wire = {mult} x fwd layer (bw={bw:.3e} B/s):")
        bar("fixed-depth", fixed, scale)
        bar("bandwidth-aware", aware, scale)
        report[f"tight_bw_x{mult}"] = {
            "bandwidth_bytes_per_s": bw,
            "fixed": {k: v for k, v in fixed.items() if k != "losses"},
            "aware": {k: v for k, v in aware.items() if k != "losses"},
            "stall_ratio": round(ratio, 4),
        }
        csv(f"timeline/stall_x{mult}", 0.0,
            f"fixed={fixed['stall_s']:.3e};aware={aware['stall_s']:.3e};"
            f"ratio={ratio:.3f};h2d_bytes={aware['h2d_bytes']}")

    if not args.smoke:
        distributed_breakdown(report)

    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
