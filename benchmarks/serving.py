"""Serving-plane benchmark: the kv stream as managed chunks vs the
unmanaged baseline (raw device-resident caches), and the eager round vs
the compiled round step, at one fixed tight device budget.

Measures, per mode (``managed`` / ``unmanaged`` eager, ``compiled``):

  * **max concurrent sequences** — how many of a request burst the
    continuous-batching admission loop can run at once.  Unmanaged KV
    must fit entirely beside the param working set on the device;
    managed KV pages cold sequences to host and is bounded by the
    two-tier total instead.
  * **steady-state tokens/s** over the drain of the whole backlog.
    Every mode serves the burst TWICE on one engine: the first pass
    prices jit compilation (the compiled round's padded slot shapes)
    and jax dispatch caches, the second identical pass is timed — so
    eager vs compiled compares steady-state rounds, not compile time.

Asserts the acceptance bars: >= 2x max concurrent sequences managed vs
unmanaged, >= 5x tokens/s compiled vs eager-managed (``--smoke``),
identical outputs across all three modes AND across the two passes
(determinism through kv-stream re-registration), ``check_invariants()``
clean, and the per-round device peak within the budget in every mode.
Emits a JSON report.  ``--smoke`` shrinks the burst for CI.
"""

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import csv
from repro.configs import get_config, model_class
from repro.core.serving import ServingEngine
from repro.runtime.serve import CompiledServingEngine

DEVICE_BUDGET = 1_200_000  # < param stream + a few sequences' KV
HOST_BUDGET = 16_000_000

SPEEDUP_BAR = 5.0  # compiled vs eager-managed tokens/s (--smoke bar)


def serve(cfg, prompts, new_tokens, horizon, mode):
    manage_kv = mode != "unmanaged"
    cls = CompiledServingEngine if mode == "compiled" else ServingEngine
    eng = cls(
        model_class(cfg), cfg,
        device_memory_bytes=DEVICE_BUDGET,
        host_memory_bytes=HOST_BUDGET if manage_kv else None,
        max_seq_len=horizon, manage_kv=manage_kv, seed=0)

    def burst():
        rids = [eng.submit(p, new_tokens) for p in prompts]
        tok0 = eng.total_decode_tokens + eng.total_prefill_tokens
        t0 = time.perf_counter()
        mets = eng.run(max_rounds=2000)
        wall = time.perf_counter() - t0
        for m in mets:
            # pool-side per-round device peak: the budget held every round
            assert m.peak_device_bytes <= DEVICE_BUDGET, (
                m.round_index, m.peak_device_bytes)
        tokens = eng.total_decode_tokens + eng.total_prefill_tokens - tok0
        return [eng.result(r) for r in rids], tokens, wall

    warm_out, _, _ = burst()       # compile + warm caches
    out, tokens, wall = burst()    # steady state (timed)
    eng.check_invariants()
    assert eng.pool.peak_device_bytes <= DEVICE_BUDGET
    # determinism through drain/re-registration of the kv stream
    assert out == warm_out

    report = {
        "max_concurrent": eng.peak_concurrency,
        "rounds": eng.rounds,
        "decode_tokens": eng.total_decode_tokens,
        "prefill_tokens": eng.total_prefill_tokens,
        "tokens_per_s": round(tokens / wall, 1),
        "h2d_bytes": eng.pool.stats.h2d_bytes,
        "d2h_bytes": eng.pool.stats.d2h_bytes,
        "prefetch_hit_rate": round(eng.pool.prefetch.hit_rate, 4),
        "kv_seq_bytes": eng.kv_seq_bytes,
    }
    if mode == "compiled":
        report["decode_compiles"] = eng.decode_compile_count
        report["prefill_compiles"] = eng.prefill_compile_count
        report["padded_slots"] = eng.padded_slots
    return report, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: smaller burst, assertions intact")
    args = ap.parse_args()
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    n_req, new_tokens, horizon = (20, 8, 40) if args.smoke else (32, 12, 48)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (n_req, 8), 0, cfg.vocab_size))

    managed, out_m = serve(cfg, prompts, new_tokens, horizon, "managed")
    unmanaged, out_u = serve(cfg, prompts, new_tokens, horizon, "unmanaged")
    compiled, out_c = serve(cfg, prompts, new_tokens, horizon, "compiled")
    # neither chunk management nor the compiled lowering may change a token
    assert out_m == out_u
    assert out_m == out_c
    ratio = managed["max_concurrent"] / unmanaged["max_concurrent"]
    assert ratio >= 2.0, (managed["max_concurrent"],
                          unmanaged["max_concurrent"])
    speedup = compiled["tokens_per_s"] / managed["tokens_per_s"]
    if args.smoke:
        assert speedup >= SPEEDUP_BAR, (
            compiled["tokens_per_s"], managed["tokens_per_s"])

    report = {
        "device_budget_bytes": DEVICE_BUDGET,
        "requests": n_req,
        "managed": managed,
        "unmanaged": unmanaged,
        "compiled": compiled,
        "concurrency_ratio": round(ratio, 2),
        "compiled_speedup": round(speedup, 2),
    }
    csv("serving/max_concurrent", 0.0,
        f"managed={managed['max_concurrent']};"
        f"unmanaged={unmanaged['max_concurrent']};ratio={ratio:.2f}")
    csv("serving/tokens_per_s", 0.0,
        f"eager={managed['tokens_per_s']};"
        f"unmanaged={unmanaged['tokens_per_s']};"
        f"compiled={compiled['tokens_per_s']};speedup={speedup:.2f}")
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
