"""Paper Section 7 cost model + Table 5 — chunked all-gather vs
broadcast-based volume, and measured HLO collective bytes of the compiled
train step (validates the analytic model at dp=2).  Also reports the
eager runtime's unified-pool tier traffic (hidden vs critical-path H2D
under schedule-driven prefetch) so collective and offload volume land in
one place."""

import jax
import jax.numpy as jnp

from benchmarks.common import csv, lm_batch
from repro.analysis.roofline import parse_collectives
from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.core import zero
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    for p in (2, 4, 8):
        tree = {"w": jnp.zeros((1024, 256))}
        lay = zero.make_layout(tree, nproc=p, dtype=jnp.bfloat16)
        vol = zero.comm_volume_bytes(lay)
        ratio = vol["broadcast_baseline_bytes"] / max(
            vol["chunked_allgather_bytes"], 1)
        csv(f"comm_volume/analytic_p{p}", 0.0,
            f"chunked={vol['chunked_allgather_bytes']:.0f};"
            f"broadcast={vol['broadcast_baseline_bytes']:.0f};x{ratio:.2f}")

    # eager runtime: unified-pool CPU<->device traffic for one step, split
    # into prefetch-hidden and critical-path H2D bytes
    from repro.core.engine import PatrickStarEngine
    ecfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")
    eng = PatrickStarEngine(model_class(ecfg), ecfg,
                            device_memory_bytes=3_000_000,
                            device_aware_placement=False)
    eb = lm_batch(ecfg, 4, 64)
    eng.step(eb)
    m = eng.step(eb)
    csv("comm_volume/eager_pool_step", 0.0,
        f"h2d={m.h2d_bytes + m.adam_h2d_bytes};"
        f"d2h={m.d2h_bytes + m.adam_d2h_bytes};"
        f"hidden={m.hidden_h2d_bytes};critical={m.critical_h2d_bytes};"
        f"hit_rate={m.prefetch_hit_rate:.2f}")

    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    shape = InputShape("bench", 64, 4, "train")
    jf, args, _ = driver.build_train_step(rt, shape)
    txt = jf.lower(*args).compile().as_text()
    st = parse_collectives(txt)
    csv("comm_volume/hlo_train_step", 0.0, st.summary().replace(",", ";"))
    # per-step chunk volume: every layer gathered (fwd+bwd) + grads RS
    cap = sum((l.capacity if n == "stem" else
               l.capacity * rt.group_lengths[n]) * 2
              for n, l in rt.layouts.items())
    csv("comm_volume/analytic_step_bytes", 0.0,
        f"3x(p-1)/p*cap={3 * 0.5 * cap:.0f}")


if __name__ == "__main__":
    main()
