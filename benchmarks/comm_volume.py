"""Paper Section 7 cost model + Table 5 — chunked all-gather vs
broadcast-based volume; the eager distributed engine's MEASURED
collective ledger against the analytic model (exact, asserted); measured
HLO collective bytes of the compiled train step (validates the analytic
model at dp=2); and the eager runtime's unified-pool tier traffic
(hidden vs critical-path H2D under schedule-driven prefetch) so
collective and offload volume land in one place.

``--smoke`` runs the cheap, assertion-bearing subset for CI: the
analytic table, the eager single-rank pool traffic, and the eager
distributed analytic-parity proof (skipping the compiled-step lowering).
"""

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core import zero


def analytic_table():
    for p in (2, 4, 8):
        tree = {"w": jnp.zeros((1024, 256))}
        lay = zero.make_layout(tree, nproc=p, dtype=jnp.bfloat16)
        vol = zero.comm_volume_bytes(lay)
        ratio = vol["broadcast_baseline_bytes"] / max(
            vol["chunked_allgather_bytes"], 1)
        csv(f"comm_volume/analytic_p{p}", 0.0,
            f"chunked={vol['chunked_allgather_bytes']:.0f};"
            f"broadcast={vol['broadcast_baseline_bytes']:.0f};x{ratio:.2f}")


def eager_pool_traffic():
    """Single-rank unified-pool CPU<->device traffic for one step, split
    into prefetch-hidden and critical-path H2D bytes."""
    from repro.core.engine import PatrickStarEngine
    ecfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")
    eng = PatrickStarEngine(model_class(ecfg), ecfg,
                            device_memory_bytes=3_000_000,
                            device_aware_placement=False)
    eb = lm_batch(ecfg, 4, 64)
    eng.step(eb)
    m = eng.step(eb)
    csv("comm_volume/eager_pool_step", 0.0,
        f"h2d={m.h2d_bytes + m.adam_h2d_bytes};"
        f"d2h={m.d2h_bytes + m.adam_d2h_bytes};"
        f"hidden={m.hidden_h2d_bytes};critical={m.critical_h2d_bytes};"
        f"hit_rate={m.prefetch_hit_rate:.2f}")


def eager_distributed_parity():
    """The tentpole proof, exercised on every CI run: the rank-parallel
    eager engine's measured all-gather + reduce-scatter bytes equal the
    analytic 3(p-1)/p chunk-store volume EXACTLY, on every step, and the
    gather prefetcher converts critical gather bytes to hidden at equal
    total volume."""
    from repro.core.distributed import DistributedPatrickStarEngine
    ecfg = get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    eb = lm_batch(ecfg, 4, 32)
    for p in (2, 4):
        dist = DistributedPatrickStarEngine(
            model_class(ecfg), ecfg, nproc=p,
            device_memory_bytes=4_000_000, lr=1e-2)
        vol = zero.comm_volume_bytes(dist.cmap, itemsize=4)
        exact = int(vol["chunked_capacity_bytes"])
        warm = dist.step(eb)  # warm-up: all gathers are demand/critical
        post = dist.step(eb)
        for tag, m in (("warmup", warm), ("steady", post)):
            assert m.chunk_collective_bytes == exact, (
                p, tag, m.chunk_collective_bytes, exact)
            assert m.allgather_bytes == 2 * m.reduce_scatter_bytes
        assert warm.hidden_allgather_bytes == 0
        assert post.hidden_allgather_bytes > 0  # gather prefetch engaged
        assert (post.hidden_allgather_bytes + post.critical_allgather_bytes
                == post.allgather_bytes)
        dist.check_invariants()
        csv(f"comm_volume/eager_dist_p{p}", 0.0,
            f"measured={post.chunk_collective_bytes};analytic={exact};"
            f"ag={post.allgather_bytes};rs={post.reduce_scatter_bytes};"
            f"hidden_ag={post.hidden_allgather_bytes};"
            f"allreduce_stem={post.allreduce_bytes};loss={post.loss:.4f}")


def compiled_hlo_volume():
    from repro.analysis.roofline import parse_collectives
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = get_config("qwen3-0.6b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    shape = InputShape("bench", 64, 4, "train")
    jf, args, _ = driver.build_train_step(rt, shape)
    txt = jf.lower(*args).compile().as_text()
    st = parse_collectives(txt)
    csv("comm_volume/hlo_train_step", 0.0, st.summary().replace(",", ";"))
    # per-step chunk volume: every layer gathered (fwd+bwd) + grads RS
    cap = sum((l.capacity if n == "stem" else
               l.capacity * rt.group_lengths[n]) * 2
              for n, l in rt.layouts.items())
    csv("comm_volume/analytic_step_bytes", 0.0,
        f"3x(p-1)/p*cap={3 * 0.5 * cap:.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: analytic + eager parity assertions only")
    args = ap.parse_args()
    analytic_table()
    eager_pool_traffic()
    eager_distributed_parity()
    if not args.smoke:
        compiled_hlo_volume()


if __name__ == "__main__":
    main()
