"""Paper Table 3 / Fig. 12 — chunk-size search: utilization across
candidate sizes, arch dependence, infeasible settings under a budget."""

import jax

from benchmarks.common import csv
from repro.configs import ARCH_IDS, get_config, model_class
from repro.core.chunk import ChunkMapError, TensorSpec, search_chunk_size
from repro.models.layers import AxisCtx


def main():
    # FULL configs: param_specs is shape-only (eval_shape, no allocation),
    # so the search runs at real scale like the paper's offline tool
    for arch in ("qwen3-0.6b", "mixtral-8x7b", "xlstm-1.3b", "deepseek-7b"):
        cfg = get_config(arch)
        model = model_class(cfg)(cfg, AxisCtx())
        specs = model.param_specs()
        flat = jax.tree_util.tree_flatten_with_path(specs["groups"])[0]
        # single-layer shapes (strip the stacked [L, ...] axis) — the
        # chunk layout is per layer, as in the runtime.  Stacked expert
        # weights [E, d, f] explode into per-expert tensors for the
        # search (the paper's per-tensor mapping granularity).
        tensors = []
        for path, l in flat:
            name = jax.tree_util.keystr(path)
            shape = tuple(l.shape[1:])
            if len(shape) == 3 and any(w in name for w in
                                       ("w_gate", "w_up", "w_down")):
                for e in range(shape[0]):
                    tensors.append(TensorSpec(f"{name}[{e}]", shape[1:]))
            else:
                tensors.append(TensorSpec(name, shape))
        res = search_chunk_size(tensors, nproc=8, align=256)
        csv(f"chunk_search/{arch}", 0.0,
            f"size={res.chunk_size};util={res.utilization:.3f};"
            f"candidates={len(res.candidates)}")
        assert res.utilization > 0.55, (arch, res.utilization)
        # NOTE: per-layer chunk layouts pay comm-group padding (chunks
        # rounded up to a multiple of dp) — see EXPERIMENTS.md discussion
        # paper Fig. 12: some sizes are infeasible under a tight budget
        try:
            search_chunk_size(tensors, nproc=8, align=256,
                              memory_budget_elems=res.num_chunks
                              * res.chunk_size // 2)
            feasible = True
        except ChunkMapError:
            feasible = False
        csv(f"chunk_search/{arch}_halved_budget", 0.0,
            f"feasible={feasible}")


if __name__ == "__main__":
    main()
