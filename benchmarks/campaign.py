"""Full dry-run campaign: every (arch x shape x mesh), JSONs incrementally."""
import json, pathlib, time, traceback, sys

ORDER = ["qwen3-0.6b", "xlstm-1.3b", "zamba2-1.2b", "qwen2.5-3b",
         "phi-3-vision-4.2b", "whisper-large-v3", "deepseek-7b",
         "mixtral-8x7b", "deepseek-v2-lite-16b", "nemotron-4-340b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

def main():
    from repro.launch.dryrun import dryrun_one
    outdir = pathlib.Path("results/dryrun")
    outdir.mkdir(parents=True, exist_ok=True)
    for arch in ORDER:
        for shape in SHAPES:
            for mp in (False, True):
                tag = f"{arch}__{shape}__{'2pod' if mp else '1pod'}"
                f = outdir / f"{tag}.json"
                if f.exists() and json.loads(f.read_text()).get("status") in ("ok", "skipped"):
                    print(f"{tag}: cached", flush=True)
                    continue
                t0 = time.time()
                try:
                    rec = dryrun_one(arch, shape, multi_pod=mp, verbose=False)
                except Exception as e:
                    traceback.print_exc(limit=5)
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": f"{type(e).__name__}: {str(e)[:500]}"}
                f.write_text(json.dumps(rec, indent=1))
                print(f"{tag}: {rec['status']} ({time.time()-t0:.0f}s)", flush=True)

if __name__ == "__main__":
    main()
