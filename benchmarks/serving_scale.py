"""Fleet-scale serving benchmark: paged KV chunks + rank-sharded
sequences (the ROADMAP's "millions of users" direction).

Three asserted acceptance bars (``--smoke`` runs them all in CI):

  * **capacity scales in ranks** — the same request burst against a
    1-rank and a 2-rank :class:`~repro.core.distributed.DistributedServingEngine`
    fleet at an IDENTICAL per-rank device+host budget must reach
    >= ``SCALING_BAR``x the fleet-wide max concurrent sequences (KV is
    rank-local, admission is per-rank, so capacity is additive), with
    token-for-token identical outputs (round-robin placement changes
    batching, never a token) and every rank's per-round device peak
    within its budget.
  * **long-sequence feasibility** — a long-horizon request whose
    whole-horizon kv chunk cannot fit beside the param floor is
    REJECTED by the unpaged baseline at a given budget (the
    working-set-floor ValueError / never-admissible guard) but served
    to completion by the paged engine at the SAME budget: paging turns
    the admission unit from horizons into pages.
  * **paging never changes a token** — paged eager, paged compiled and
    the unpaged oracle emit identical tokens on a workload all three
    can run, with per-round device peaks within budget everywhere.

Emits a JSON report.  ``--smoke`` shrinks the burst for CI.
"""

import argparse
import json

import numpy as np

from benchmarks.common import csv
from repro.configs import get_config, model_class
from repro.core.distributed import DistributedServingEngine
from repro.core.memory import OutOfMemory
from repro.core.serving import ServeRequest, ServingEngine, \
    swap_headroom_bytes
from repro.runtime.serve import CompiledServingEngine

PAGE_TOKENS = 8
SCALING_BAR = 1.8  # fleet capacity 1 -> 2 ranks
TARGET_PER_RANK = 3  # budgets sized to admit this many sequences per rank


def _cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _prompts(cfg, n, plen, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
            for _ in range(n)]


def _drain(eng, device_budget):
    """Run a fleet (or single engine) dry, asserting the per-rank
    per-round device peak against the fixed budget."""
    for m in eng.run(max_rounds=4000):
        rms = m.rank_metrics if hasattr(m, "rank_metrics") else [m]
        for rm in rms:
            if rm is not None:
                assert rm.peak_device_bytes <= device_budget, (
                    m.round_index, rm.peak_device_bytes, device_budget)
    eng.check_invariants()


def capacity_scaling(cfg, args, report):
    """Bar (a): fleet-wide concurrent-sequence capacity ~doubles from
    1 -> 2 ranks at a fixed per-rank budget."""
    horizon = 40
    plen, new_tokens = 8, 8
    n_req = 12 if args.smoke else 24
    prompts = _prompts(cfg, n_req, plen)

    # size the budgets from the engine's own admission constants so the
    # per-rank capacity is exactly TARGET_PER_RANK by construction
    probe = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=64_000_000,
        host_memory_bytes=64_000_000, max_seq_len=horizon,
        page_tokens=PAGE_TOKENS)
    commit = probe._kv_commit_bytes(ServeRequest(
        rid=-1, prompt=prompts[0], max_new_tokens=new_tokens))
    headroom = swap_headroom_bytes(probe.params_mgr.chunk_bytes,
                                   probe.kv_chunk_bytes)
    device = probe._param_floor_bytes + 4 * probe.kv_chunk_bytes
    host = (probe._param_stream_bytes + headroom
            + TARGET_PER_RANK * commit + commit // 2 - device)
    del probe

    def fleet(nproc):
        f = DistributedServingEngine(
            model_class(cfg), cfg, nproc=nproc,
            device_memory_bytes=device, host_memory_bytes=host,
            max_seq_len=horizon, page_tokens=PAGE_TOKENS, seed=0)
        gids = [f.submit(p, new_tokens) for p in prompts]
        _drain(f, device)
        return f, [f.result(g) for g in gids]

    f1, out1 = fleet(1)
    f2, out2 = fleet(2)
    # placement must never change a token
    assert out1 == out2, "rank sharding changed tokens"
    ratio = f2.peak_concurrency / f1.peak_concurrency
    assert ratio >= SCALING_BAR, (
        f"fleet capacity must scale >= {SCALING_BAR}x from 1 -> 2 ranks "
        f"at a fixed per-rank budget: got {f1.peak_concurrency} -> "
        f"{f2.peak_concurrency} ({ratio:.2f}x)")
    report["capacity_scaling"] = {
        "per_rank_device_bytes": device,
        "per_rank_host_bytes": host,
        "kv_commit_bytes_per_seq": commit,
        "max_concurrent_1rank": f1.peak_concurrency,
        "max_concurrent_2rank": f2.peak_concurrency,
        "scaling_ratio": round(ratio, 3),
        "rounds_1rank": f1.rounds,
        "rounds_2rank": f2.rounds,
    }
    csv("serving_scale/capacity", ratio,
        f"c1={f1.peak_concurrency};c2={f2.peak_concurrency};"
        f"device={device};host={host}")


def long_sequence_feasibility(cfg, args, report):
    """Bar (b): at a budget where the unpaged whole-horizon kv chunk
    cannot fit beside the param floor, paging serves the request."""
    horizon = 192 if args.smoke else 384
    plen, new_tokens = 8, 24
    prompt = _prompts(cfg, 1, plen, seed=3)[0]

    # unpaged constants at this horizon (built with a generous budget)
    probe = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=64_000_000,
        host_memory_bytes=64_000_000, max_seq_len=horizon)
    full_chunk = probe.kv_chunk_bytes
    floor = probe._param_floor_bytes
    host = (probe._param_stream_bytes
            + swap_headroom_bytes(probe.params_mgr.chunk_bytes, full_chunk)
            + probe.kv_seq_bytes)
    del probe
    # one full-horizon chunk and a half fits, but the unpaged floor
    # (param floor + TWO whole-horizon chunks) does not
    device = floor + full_chunk + full_chunk // 2

    rejected = False
    try:
        base = ServingEngine(
            model_class(cfg), cfg, device_memory_bytes=device,
            host_memory_bytes=host, max_seq_len=horizon)
        base.submit(prompt, new_tokens)
        base.run(max_rounds=4000)
    except (ValueError, OutOfMemory) as e:
        rejected = True
        reason = f"{type(e).__name__}: {e}"
    assert rejected, (
        "unpaged baseline unexpectedly served the long sequence at "
        f"device={device}")

    paged = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=device,
        host_memory_bytes=host, max_seq_len=horizon,
        page_tokens=PAGE_TOKENS)
    rid = paged.submit(prompt, new_tokens)
    _drain(paged, device)
    out = paged.result(rid)
    assert len(out) == new_tokens
    report["long_sequence"] = {
        "horizon": horizon,
        "device_bytes": device,
        "host_bytes": host,
        "unpaged_chunk_bytes": full_chunk,
        "paged_chunk_bytes": paged.kv_chunk_bytes,
        "unpaged_rejection": reason,
        "paged_pages_per_seq": paged._pages_per_seq,
        "paged_rounds": paged.rounds,
    }
    csv("serving_scale/long_seq", 1.0,
        f"horizon={horizon};device={device};"
        f"full_chunk={full_chunk};page_chunk={paged.kv_chunk_bytes}")


def paging_parity(cfg, args, report):
    """Bar (c): paged eager == paged compiled == unpaged oracle,
    token for token, on a workload all three can run."""
    horizon = 40
    device, host = 1_300_000, 8_000_000
    n_req = 6 if args.smoke else 12
    prompts = _prompts(cfg, n_req, 8, seed=5)
    # staggered lifetimes churn admission/retirement and page appends
    news = [(10, 4, 10, 6, 8, 10)[i % 6] for i in range(n_req)]

    def serve(cls, page_tokens):
        eng = cls(model_class(cfg), cfg, device_memory_bytes=device,
                  host_memory_bytes=host, max_seq_len=horizon,
                  page_tokens=page_tokens, seed=0)
        rids = [eng.submit(p, n) for p, n in zip(prompts, news)]
        _drain(eng, device)
        return eng, [eng.result(r) for r in rids]

    _, oracle = serve(ServingEngine, None)
    pe, eager = serve(ServingEngine, PAGE_TOKENS)
    pc, comp = serve(CompiledServingEngine, PAGE_TOKENS)
    assert eager == oracle, "paged eager diverged from the unpaged oracle"
    assert comp == oracle, "paged compiled diverged from the unpaged oracle"

    # the 2-rank paged fleet serves the same burst to the same tokens
    f = DistributedServingEngine(
        model_class(cfg), cfg, nproc=2, device_memory_bytes=device,
        host_memory_bytes=host, max_seq_len=horizon,
        page_tokens=PAGE_TOKENS, seed=0)
    gids = [f.submit(p, n) for p, n in zip(prompts, news)]
    _drain(f, device)
    assert [f.result(g) for g in gids] == oracle, "fleet diverged"

    report["parity"] = {
        "n_req": n_req,
        "eager_rounds": pe.rounds,
        "compiled_rounds": pc.rounds,
        "fleet_rounds": f.rounds,
        "paged_d2h_bytes": pe.pool.stats.d2h_bytes,
    }
    csv("serving_scale/parity", 1.0,
        f"n={n_req};eager_rounds={pe.rounds};fleet_rounds={f.rounds}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: smaller burst, assertions intact")
    args = ap.parse_args()
    cfg = _cfg()
    report = {"page_tokens": PAGE_TOKENS, "scaling_bar": SCALING_BAR}
    capacity_scaling(cfg, args, report)
    long_sequence_feasibility(cfg, args, report)
    paging_parity(cfg, args, report)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
