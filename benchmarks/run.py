"""Benchmark suite runner: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines."""

# The comm-volume benchmark compiles a dp=2 x tp=2 step, so the bench
# process uses 4 host devices (NOT the dry-run's 512 — that stays local
# to repro/launch/dryrun.py).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", ""))

import sys
import traceback

MODULES = [
    "benchmarks.model_scale",     # Fig. 13
    "benchmarks.throughput",      # Fig. 14/15
    "benchmarks.breakdown",       # Fig. 16
    "benchmarks.comm_volume",     # Sec. 7 / Table 5
    "benchmarks.chunk_search",    # Table 3 / Fig. 12
    "benchmarks.eviction",        # Sec. 8.3
    "benchmarks.tracer_bench",    # Fig. 2 / Sec. 8.1
    "benchmarks.max_batch",       # Sec. 6 "larger batch" / act stream
    "benchmarks.serving",         # serving plane: kv stream capacity
    "benchmarks.serving_compiled",  # compiled round-step scaling
    "benchmarks.timeline",        # transfer timeline / Fig. 16 stalls
    "benchmarks.serving_scale",   # paged KV + rank-sharded fleet capacity
    "benchmarks.tiers",           # third-tier (ZeRO-Infinity) host-wall unlock
    "benchmarks.cotenancy",       # multi-tenant pool: train + serve co-resident
]


def main() -> None:
    import argparse
    import importlib

    # Only --trace-dir is consumed here; everything else (e.g. --smoke)
    # stays on sys.argv for the per-module argparsers.
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--trace-dir", default=None)
    ns, rest = ap.parse_known_args()
    sys.argv = [sys.argv[0]] + rest

    if ns.trace_dir:
        os.makedirs(ns.trace_dir, exist_ok=True)
        from repro.analysis import tracereport
        from repro.core import telemetry

    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        hub = None
        if ns.trace_dir:
            # Fresh hub per module, installed as the default so every
            # pool the module builds is traced with zero wiring; state
            # events off to keep CI traces lean.
            hub = telemetry.Telemetry(capture_states=False)
            telemetry.set_default_hub(hub)
        try:
            importlib.import_module(mod).main()
            if hub is not None and hub.events:
                path = os.path.join(ns.trace_dir,
                                    mod.rsplit(".", 1)[-1] + ".json")
                trace = hub.dump_chrome_trace(path)
                # re-load what we just wrote and re-assert conservation
                # both from the JSON and against the live counters
                tracereport.validate(tracereport.load(path))
                hub.assert_conservation()
                print(f"{mod},0.0,trace={path};"
                      f"events={len(hub.events)}")
                del trace
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{mod},0.0,ERROR")
        finally:
            if hub is not None:
                telemetry.set_default_hub(None)
    if failures:
        sys.exit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
