"""Benchmark suite runner: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines."""

# The comm-volume benchmark compiles a dp=2 x tp=2 step, so the bench
# process uses 4 host devices (NOT the dry-run's 512 — that stays local
# to repro/launch/dryrun.py).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", ""))

import sys
import traceback

MODULES = [
    "benchmarks.model_scale",     # Fig. 13
    "benchmarks.throughput",      # Fig. 14/15
    "benchmarks.breakdown",       # Fig. 16
    "benchmarks.comm_volume",     # Sec. 7 / Table 5
    "benchmarks.chunk_search",    # Table 3 / Fig. 12
    "benchmarks.eviction",        # Sec. 8.3
    "benchmarks.tracer_bench",    # Fig. 2 / Sec. 8.1
    "benchmarks.max_batch",       # Sec. 6 "larger batch" / act stream
    "benchmarks.serving",         # serving plane: kv stream capacity
    "benchmarks.serving_compiled",  # compiled round-step scaling
    "benchmarks.timeline",        # transfer timeline / Fig. 16 stalls
    "benchmarks.serving_scale",   # paged KV + rank-sharded fleet capacity
    "benchmarks.tiers",           # third-tier (ZeRO-Infinity) host-wall unlock
    "benchmarks.cotenancy",       # multi-tenant pool: train + serve co-resident
]


def main() -> None:
    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        try:
            importlib.import_module(mod).main()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{mod},0.0,ERROR")
    if failures:
        sys.exit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
