"""Third memory tier (ZeRO-Infinity direction): the host-wall unlock.

PatrickStar assumes host RAM absorbs everything the device evicts; the
paper's own Fig. 10 host-constrained corner breaks that assumption.
ZeRO-Infinity's answer is an NVMe-class tier behind CPU memory, and the
pool's N-level tier stack reproduces it: host evictions demote down to
the slow tier (h2s), chunks promote back on demand or via a two-hop
stage (s2h + h2d, the legs chained on the timeline).

Measured here, with asserted acceptance bars:

1. **The unlock.**  At a host budget where the two-tier pool raises
   ``OutOfMemory`` (the model-data streams simply do not fit
   device+host), the SAME budgets plus a slow tier train fine — and the
   tiering is placement-only: per-step losses match an unconstrained run
   to <= 1e-6.
2. **Conservation over the new lanes.**  With finite bandwidths on all
   four DMA lanes, ``hidden + critical == h2d`` still holds (two-hop
   stages classify only their h2d leg) and every step decomposes as
   ``wall == compute + stalls``; at infinite bandwidth every stall is
   exactly zero.
"""

import argparse
import json

from benchmarks.common import lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.memory import OutOfMemory
from repro.core.timeline import TransferTimeline

SEQ = 64
BATCH = 4
DEVICE_BUDGET = 4_000_000
# searched chunk bytes for this config (557_056 B): the host budget
# below holds ~4 chunks — device+host < the 12 model-data chunks (4
# streams x 3), so the two-tier pool cannot even finish engine init.
HOST_BUDGET = 2_300_000
SLOW_BUDGET = 8_000_000


def _cfg(num_layers):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=num_layers, param_dtype="float32",
        compute_dtype="float32")


def train(cfg, *, host=None, slow=None, steps=3, timeline=None):
    """Run ``steps`` iterations; returns (losses, step reports, engine)."""
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=DEVICE_BUDGET,
        host_memory_bytes=host, slow_memory_bytes=slow, timeline=timeline)
    batch = lm_batch(cfg, BATCH, SEQ)
    losses, reports = [], []
    for _ in range(steps):
        met = eng.step(batch)
        losses.append(float(met.loss))
        reports.append(met.timeline)
    eng.pool.check_invariants()
    return losses, reports, eng


def two_tier_fails(cfg):
    try:
        train(cfg, host=HOST_BUDGET, steps=1)
        return False
    except OutOfMemory:
        return True


def timeline_conservation(cfg, steps):
    """Per-step wall == compute + stalls with the slow lanes in play,
    plus the zero-stall degenerate case at infinite bandwidth."""
    tl = TransferTimeline.calibrated()
    _, fin_steps, eng = train(cfg, host=HOST_BUDGET, slow=SLOW_BUDGET,
                              steps=steps, timeline=tl)
    # conservation law 1: every H2D byte classified exactly once
    pf, st = eng.pool.prefetch, eng.pool.stats
    assert pf.hidden_h2d_bytes + pf.critical_h2d_bytes == st.h2d_bytes, (
        pf.hidden_h2d_bytes, pf.critical_h2d_bytes, st.h2d_bytes)
    assert st.h2s_bytes > 0 and st.s2h_bytes > 0, (
        "slow lanes saw no traffic; the scenario is not exercising tier 3")
    _, inf_steps, _ = train(cfg, host=HOST_BUDGET, slow=SLOW_BUDGET,
                            steps=steps, timeline=TransferTimeline())
    return fin_steps, inf_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer layers/steps for CI")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()
    steps = 3 if args.smoke else args.steps
    cfg = _cfg(args.layers)

    # 1. the two-tier pool cannot train at this host budget
    blocked = two_tier_fails(cfg)

    # 2. the same budgets + a slow tier train to parity with unbounded host
    base, _, _ = train(cfg, steps=steps)
    tiered, _, eng3 = train(cfg, host=HOST_BUDGET, slow=SLOW_BUDGET,
                            steps=steps)
    max_diff = max(abs(a - b) for a, b in zip(base, tiered))
    pool = eng3.pool

    # 3. conservation with finite bandwidths on all four lanes
    fin_steps, inf_steps = timeline_conservation(cfg, steps)

    report = {
        "device_budget_bytes": DEVICE_BUDGET,
        "host_budget_bytes": HOST_BUDGET,
        "slow_budget_bytes": SLOW_BUDGET,
        "num_layers": args.layers,
        "steps": steps,
        "two_tier_oom": blocked,
        "losses_unconstrained": base,
        "losses_three_tier": tiered,
        "max_per_step_loss_diff": max_diff,
        "slow_bytes_used": pool.slow_bytes_used(),
        "h2s_transfers": pool.stats.h2s_count,
        "s2h_transfers": pool.stats.s2h_count,
        "stall_s_per_step": [t.stall_s for t in fin_steps],
        "h2s_stall_s": sum(t.h2s_stall_s for t in fin_steps),
        "s2h_stall_s": sum(t.s2h_stall_s for t in fin_steps),
    }
    print(json.dumps(report, indent=2))

    # acceptance bars
    assert blocked, (
        "two-tier pool trained at the constrained host budget; the "
        "scenario no longer demonstrates the third-tier unlock")
    assert pool.slow_bytes_used() > 0 or pool.stats.h2s_count > 0, (
        "three-tier run never touched the slow tier")
    assert max_diff <= 1e-6, max_diff
    for t in fin_steps:
        assert abs(t.wall_s - t.step_s) <= 1e-9 * max(t.wall_s, 1e-30), (
            t.wall_s, t.step_s)
    for t in inf_steps:
        assert t.stall_s == 0.0, t
        assert abs(t.wall_s - t.compute_s) <= 1e-12, t


if __name__ == "__main__":
    main()
