"""Co-tenancy benchmark: training + serving on ONE HeteroMemory pool.

A PatrickStarEngine fine-tunes one model while a ServingEngine serves
another, both leased from the same pool (Angel-PTM direction: one memory
manager hosting many jobs).  The serving tenant gets a high eviction
priority and per-tier soft budgets; the trainer is unbudgeted and
backfills whatever the server is not using.  Compared against:

  * **solo baselines** — each engine alone on a private pool sized to
    its co-tenancy planning share (what the tenant "paid for").
  * **a static 50/50 split** — two private pools, each half the shared
    pool.  The halves strand capacity: the trainer's model data does not
    fit half the host tier and it cannot borrow the half the server
    never touches, so the split OOMs where the shared pool trains fine.

Asserted acceptance bars:

1. co-resident serving emits token-for-token the solo-serving outputs;
2. the serve tenant's tier budgets hold every round (tenant-scoped
   device peak <= its device budget, host usage <= its host budget) and
   the trainer never evicts a single serve chunk
   (``pool.evictions[("serve", "train")] == 0`` — the priority shield);
3. mean serving round latency (modeled, shared calibrated timeline)
   <= LATENCY_BAR x solo-serving;
4. co-resident trainer throughput >= THROUGHPUT_BAR x solo training,
   and its per-step losses match solo exactly (placement never changes
   math);
5. the static split fails at least one of bars 3/4.

``--smoke`` shrinks the burst/steps for CI; every assertion stays on.
"""

import argparse
import json
import statistics

import jax
import numpy as np

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.memory import HeteroMemory, OutOfMemory
from repro.core.serving import ServingEngine
from repro.core.timeline import TransferTimeline

# shared pool = the sum of the two tenants' planning shares
SERVE_DEVICE = 1_200_000  # serve tenant device soft budget (tight: < params)
SERVE_HOST = 2_500_000    # holds the param stream + the whole kv burst
TRAIN_DEVICE = 4_000_000  # trainer planning share (explicit, not a budget)
DEVICE_POOL = SERVE_DEVICE + TRAIN_DEVICE
HOST_POOL = 13_000_000    # > trainer-need + serve budget, but HALF of it
                          # is far below the trainer's host floor (~8-10MB
                          # of optimizer state + warm-up residency): the
                          # split strands the host bytes the server never
                          # uses

LATENCY_BAR = 1.25        # co-resident serve latency vs solo
THROUGHPUT_BAR = 0.5      # co-resident trainer throughput vs solo

SEQ = 64
BATCH = 4
HORIZON = 40
PAGE_TOKENS = 8


def _serve_cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _train_cfg():
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=3, param_dtype="float32", compute_dtype="float32")


def _drive_serving(eng, prompts, new_tokens, *, budgets=None, pool=None):
    """Submit the burst and round it to completion; returns (tokens,
    per-round modeled latencies).  ``budgets`` asserts the serve
    tenant's soft budgets after every round (co-tenancy mode)."""
    rids = [eng.submit(p, new_tokens) for p in prompts]
    lat = []
    while (m := eng.step_round()) is not None:
        lat.append(m.timeline.wall_s)
        if budgets is not None:
            dev_budget, host_budget = budgets
            assert m.peak_device_bytes <= dev_budget, (
                m.round_index, m.peak_device_bytes)
            assert eng.tenant.host_bytes_used() <= host_budget, (
                m.round_index, eng.tenant.host_bytes_used())
            assert pool.evictions[("serve", "train")] == 0, dict(
                pool.evictions)
        eng.check_invariants()
    return [eng.result(r) for r in rids], lat


def solo_serving(prompts, new_tokens, *, device=SERVE_DEVICE,
                 host=SERVE_HOST):
    cfg = _serve_cfg()
    eng = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=device,
        host_memory_bytes=host, max_seq_len=HORIZON,
        page_tokens=PAGE_TOKENS, seed=0,
        timeline=TransferTimeline.calibrated())
    toks, lat = _drive_serving(eng, prompts, new_tokens)
    eng.pool.check_invariants()
    return toks, lat


def solo_training(steps, *, device=TRAIN_DEVICE, host=HOST_POOL):
    cfg = _train_cfg()
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=device,
        host_memory_bytes=host, timeline=TransferTimeline.calibrated())
    batch = lm_batch(cfg, BATCH, SEQ)
    losses, walls = [], []
    for _ in range(steps):
        met = eng.step(batch)
        losses.append(float(met.loss))
        walls.append(met.timeline.wall_s)
    eng.pool.check_invariants()
    return losses, walls


def coresident(prompts, new_tokens, steps, serve_every=3):
    """Both engines on one pool: the trainer takes a step, the server
    runs up to ``serve_every`` rounds in between (coarse interleave —
    one process, so rounds and steps alternate rather than overlap; the
    shared calibrated timeline still prices both tenants' traffic over
    the same DMA lanes)."""
    pool = HeteroMemory(
        device_capacity_bytes=DEVICE_POOL, host_capacity_bytes=HOST_POOL,
        policy="opt")
    pool.set_timeline(TransferTimeline.calibrated())
    serve_t = pool.create_tenant(
        "serve", priority=10, device_budget_bytes=SERVE_DEVICE,
        host_budget_bytes=SERVE_HOST)
    train_t = pool.create_tenant("train")

    scfg, tcfg = _serve_cfg(), _train_cfg()
    serve_eng = ServingEngine(
        model_class(scfg), scfg, pool=pool, tenant=serve_t,
        max_seq_len=HORIZON, page_tokens=PAGE_TOKENS, seed=0)
    train_eng = PatrickStarEngine(
        model_class(tcfg), tcfg, pool=pool, tenant=train_t,
        device_memory_bytes=TRAIN_DEVICE)
    batch = lm_batch(tcfg, BATCH, SEQ)

    rids = [serve_eng.submit(p, new_tokens) for p in prompts]
    lat, losses, walls = [], [], []
    step = 0
    while True:
        served = False
        for _ in range(serve_every):
            m = serve_eng.step_round()
            if m is None:
                break
            served = True
            lat.append(m.timeline.wall_s)
            # bar 2: the serve tenant's soft budgets hold every round,
            # and the trainer never claimed one of its chunks
            assert m.peak_device_bytes <= SERVE_DEVICE, (
                m.round_index, m.peak_device_bytes)
            assert serve_t.host_bytes_used() <= SERVE_HOST, (
                m.round_index, serve_t.host_bytes_used())
            assert pool.evictions[("serve", "train")] == 0, dict(
                pool.evictions)
            serve_eng.check_invariants()
        if step < steps:
            met = train_eng.step(batch)
            losses.append(float(met.loss))
            walls.append(met.timeline.wall_s)
            step += 1
        elif not served:
            break
    pool.check_invariants()
    toks = [serve_eng.result(r) for r in rids]
    report = {
        "serve_rounds": serve_eng.rounds,
        "train_steps": step,
        "cross_evictions": {f"{v}<-{b}": n
                            for (v, b), n in sorted(pool.evictions.items())},
        "serve_peak_device_bytes": serve_t.peak_device_bytes,
        "train_peak_device_bytes": train_t.peak_device_bytes,
        "serve_h2d_bytes": serve_t.stats.h2d_bytes,
        "train_h2d_bytes": train_t.stats.h2d_bytes,
    }
    return toks, lat, losses, walls, report


def static_split(prompts, new_tokens, steps):
    """The baseline: two private pools, each HALF the shared pool on
    both tiers.  Serving is fine on its half; the trainer's model data
    does not fit half the host tier and cannot borrow the rest."""
    toks, lat = solo_serving(prompts, new_tokens,
                             device=DEVICE_POOL // 2, host=HOST_POOL // 2)
    try:
        _, walls = solo_training(steps, device=DEVICE_POOL // 2,
                                 host=HOST_POOL // 2)
        oom = False
    except OutOfMemory:
        walls, oom = [], True
    return toks, lat, walls, oom


def _throughput(walls):
    """Steps per modeled second, first (trace/compile) step excluded."""
    tail = walls[1:] if len(walls) > 1 else walls
    if not tail:
        return 0.0
    return 1.0 / statistics.mean(tail)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: smaller burst, assertions intact")
    args = ap.parse_args()
    n_req, new_tokens, steps = (8, 6, 4) if args.smoke else (16, 10, 8)
    scfg = _serve_cfg()
    prompts = np.asarray(jax.random.randint(
        jax.random.key(5), (n_req, 8), 0, scfg.vocab_size))

    solo_toks, solo_lat = solo_serving(prompts, new_tokens)
    solo_losses, solo_walls = solo_training(steps)
    co_toks, co_lat, co_losses, co_walls, co_rep = coresident(
        prompts, new_tokens, steps)
    sp_toks, sp_lat, sp_walls, sp_oom = static_split(
        prompts, new_tokens, steps)

    lat_ratio = statistics.mean(co_lat) / statistics.mean(solo_lat)
    tp_ratio = _throughput(co_walls) / _throughput(solo_walls)
    sp_lat_ratio = statistics.mean(sp_lat) / statistics.mean(solo_lat)
    sp_tp = _throughput(sp_walls)
    sp_tp_ratio = sp_tp / _throughput(solo_walls)

    report = {
        "device_pool_bytes": DEVICE_POOL,
        "host_pool_bytes": HOST_POOL,
        "serve_budgets": [SERVE_DEVICE, SERVE_HOST],
        "requests": n_req,
        "train_steps": steps,
        "latency_ratio": round(lat_ratio, 3),
        "throughput_ratio": round(tp_ratio, 3),
        "split_trainer_oom": sp_oom,
        "split_latency_ratio": round(sp_lat_ratio, 3),
        "split_throughput_ratio": round(sp_tp_ratio, 3),
        "coresident": co_rep,
    }
    print(json.dumps(report, indent=2))

    # bar 1: chunk residency, shared or not, never changes a token
    assert co_toks == solo_toks
    assert sp_toks == solo_toks
    # bar 4: co-training is the solo math exactly, at acceptable speed
    assert co_losses == solo_losses, (co_losses, solo_losses)
    assert lat_ratio <= LATENCY_BAR, lat_ratio
    assert tp_ratio >= THROUGHPUT_BAR, tp_ratio
    # bar 5: the static 50/50 split fails at least one bar the shared
    # pool passes (its trainer cannot even run at half the host tier)
    assert sp_lat_ratio > LATENCY_BAR or sp_tp_ratio < THROUGHPUT_BAR, (
        sp_lat_ratio, sp_tp_ratio)

    csv("cotenancy/latency", 0.0,
        f"co={statistics.mean(co_lat):.3e};solo={statistics.mean(solo_lat):.3e};"
        f"ratio={lat_ratio:.3f}")
    csv("cotenancy/throughput", 0.0,
        f"ratio={tp_ratio:.3f};split_oom={sp_oom};"
        f"split_tp_ratio={sp_tp_ratio:.3f}")


if __name__ == "__main__":
    main()
