"""Compiled serving round scaling: steady-state round throughput of
:class:`~repro.runtime.serve.CompiledServingEngine` as the burst (and so
the padded slot count) grows.

The eager-vs-compiled speedup bar lives in ``benchmarks/serving.py``;
this module characterises the compiled plane alone:

  * **tokens/s per padded-slot scale** — each burst size lands on a
    power-of-two slot shape; throughput should grow with occupancy
    because one round step serves every slot in a single dispatch.
  * **recompilation discipline** — across ALL bursts the decode step
    compiles once per distinct padded shape and never for membership
    churn; the run asserts the exact expected compile count.

One engine serves every burst in sequence (warm-up burst first at the
largest scale, so the timed bursts measure steady state), with the
per-round device peak asserted within the budget throughout.
``--smoke`` trims the burst ladder for CI.
"""

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import csv
from repro.configs import get_config, model_class
from repro.runtime.serve import CompiledServingEngine

DEVICE_BUDGET = 1_200_000
HOST_BUDGET = 16_000_000
NEW_TOKENS = 8
HORIZON = 40


def _pow2(n):
    return 1 << max(0, int(n) - 1).bit_length()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: shorter burst ladder")
    args = ap.parse_args()
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    bursts = [6, 12] if args.smoke else [6, 12, 24]

    eng = CompiledServingEngine(
        model_class(cfg), cfg, device_memory_bytes=DEVICE_BUDGET,
        host_memory_bytes=HOST_BUDGET, max_seq_len=HORIZON, seed=0)

    def drain(n_req, seed):
        prompts = np.asarray(jax.random.randint(
            jax.random.key(seed), (n_req, 8), 0, cfg.vocab_size))
        rids = [eng.submit(p, NEW_TOKENS) for p in prompts]
        tok0 = eng.total_decode_tokens + eng.total_prefill_tokens
        t0 = time.perf_counter()
        mets = eng.run(max_rounds=2000)
        wall = time.perf_counter() - t0
        for m in mets:
            assert m.peak_device_bytes <= DEVICE_BUDGET, (
                m.round_index, m.peak_device_bytes)
        assert all(eng.result(r) for r in rids)
        tokens = eng.total_decode_tokens + eng.total_prefill_tokens - tok0
        return tokens, wall

    # warm-up at the largest scale prices every padded shape the ladder
    # will touch (slots never shrink) plus the prefill cohort shapes
    drain(max(bursts), seed=0)
    scales = []
    for n_req in bursts:
        tokens, wall = drain(n_req, seed=1 + n_req)
        scales.append({
            "requests": n_req,
            "padded_slots": eng.padded_slots,
            "tokens": tokens,
            "tokens_per_s": round(tokens / wall, 1),
        })
    eng.check_invariants()

    # one decode compile per distinct padded shape over the whole run:
    # the warm-up landed the high-water shape, later bursts reuse it
    assert eng.decode_compile_count == 1, eng.decode_compile_count
    assert eng.padded_slots == _pow2(max(bursts))

    report = {
        "device_budget_bytes": DEVICE_BUDGET,
        "decode_compiles": eng.decode_compile_count,
        "prefill_compiles": eng.prefill_compile_count,
        "scales": scales,
    }
    for s in scales:
        csv(f"serving_compiled/tokens_per_s@{s['requests']}", 0.0,
            f"padded_slots={s['padded_slots']};tps={s['tokens_per_s']}")
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
