"""Paper Fig. 2 / Section 8.1 — the warm-up memory tracer: non-model
footprint across moments, peak, margin space, and the chunkable budget it
unlocks vs the static 20% partition."""

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine


def main():
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=4, param_dtype="float32", compute_dtype="float32")
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=8_000_000)
    eng.step(lm_batch(cfg, 4, 64))
    tr = eng.tracer
    nm = [m.nonmodel_bytes for m in tr.moments]
    static_budget = int(0.2 * tr.device_total_bytes)
    dynamic_min = min(tr.chunkable_memory(i) for i in range(len(nm)))
    csv("tracer/moments", 0.0, f"n={len(nm)}")
    csv("tracer/peak_nonmodel_MB", 0.0, f"{tr.peak_nonmodel_bytes/1e6:.2f}")
    csv("tracer/chunkable_min_MB", 0.0, f"{dynamic_min/1e6:.2f}")
    csv("tracer/static20_MB", 0.0, f"{static_budget/1e6:.2f}")
    csv("tracer/unlocked_vs_static", 0.0,
        f"x{dynamic_min/max(static_budget,1):.2f}")
    assert dynamic_min > static_budget  # the tracer buys real budget


if __name__ == "__main__":
    main()
