"""Paper Fig. 13 — maximal trainable model scale per system.

Empirical miniature: with a fixed simulated device budget (and unbounded
host), find the largest GPT-ladder depth each strategy trains:

  patrickstar   chunked, dynamic eviction (the engine)
  static        ZeRO-Offload-style: ALL OS on host, params must fit the
                device working set statically (engine with eviction
                disabled-ish: device budget must hold ALL param chunks)
  device-only   PyTorch-style: all 4 streams resident on device

Analytic extrapolation to the paper's testbeds is printed alongside
(model data = 14M chunked vs 18M static; GPU must hold param fp16 +
peak non-model for static)."""

import jax
import numpy as np

from benchmarks.common import csv, lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.manager import OutOfMemory


def _try_train(num_layers, device_bytes, mode):
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=num_layers, param_dtype="float32", compute_dtype="float32")
    try:
        if mode == "patrickstar":
            eng = PatrickStarEngine(model_class(cfg), cfg,
                                    device_memory_bytes=device_bytes)
        elif mode == "static":
            # all OS pinned host; params must ALL fit on device at once
            eng = PatrickStarEngine(model_class(cfg), cfg,
                                    device_memory_bytes=device_bytes,
                                    device_aware_placement=False)
            need = eng.cmap.capacity * 4
            if need > device_bytes * 0.8:  # 20% headroom for non-model
                raise OutOfMemory("static partition: params exceed device")
        else:  # device-only
            eng = PatrickStarEngine(model_class(cfg), cfg,
                                    device_memory_bytes=device_bytes)
            need = eng.cmap.capacity * 4 * 4  # all four streams
            if need > device_bytes:
                raise OutOfMemory("all streams exceed device")
        eng.step(lm_batch(cfg, 2, 32))
        return True
    except OutOfMemory:
        return False


def max_layers(device_bytes, mode):
    best = 0
    for layers in (1, 2, 4, 6, 8, 12, 16, 24, 32):
        if _try_train(layers, device_bytes, mode):
            best = layers
        else:
            break
    return best


def main():
    budget = 3_000_000  # simulated device bytes
    results = {m: max_layers(budget, m) for m in
               ("patrickstar", "static", "device-only")}
    for mode, layers in results.items():
        csv(f"model_scale/{mode}", 0.0, f"max_layers={layers}")
    assert results["patrickstar"] >= results["static"] >= results["device-only"]
    # analytic paper-testbed reproduction (YARD: 8x32GB V100 + 240GB CPU).
    # Paper Sec. 9.2.1: chunkable space = 32*20%*8 + 240 = 291.2 GB at 86%
    # utilization over 14 bytes/param -> 18B, the reported maximum.
    gpu, cpu, n_gpu = 32.0, 240.0, 8
    chunkable = gpu * 0.2 * n_gpu + cpu
    ps_params = chunkable * 0.86 / 14
    csv("model_scale/analytic_patrickstar_B", 0.0,
        f"params={ps_params:.1f}B (paper measured: 18B on YARD)")
    # ZeRO-Offload-style static partition: OS+grads (16 bytes/param) must
    # fit CPU *and* param fp16 + peak non-model must fit each GPU; the
    # paper measures 4B for DeepSpeed-DP on 8 GPUs (framework buffers).
    static_theoretical = cpu / 16
    csv("model_scale/analytic_static_B", 0.0,
        f"params={static_theoretical:.1f}B theoretical; paper measured 4B")
    csv("model_scale/analytic_ratio", 0.0,
        f"x{ps_params/4:.2f} vs measured DeepSpeed-DP "
        f"(paper: 3x DP / 2.25x vs +MP)")


if __name__ == "__main__":
    main()
