"""Paper Section 9 "larger batch sizes" — the activation chunk stream's
batch-headroom win, measured directly.

Binary-searches the largest trainable batch size at a FIXED device
budget, twice: with the activation stream ON (checkpointed layer inputs
live as chunks in the unified pool, spillable to host mid-step) and OFF
(saved inputs sit unmanaged on the device, outside the chunk planner's
reach).  Both engines run under ``strict_device_budget``: a post-warm-up
moment whose non-model footprint leaves less device memory than one
operator's working set raises OutOfMemory instead of silently clamping —
the honest "does this batch fit" signal.

Also asserts the act stream is a pure *placement* change: per-step losses
with the stream on vs off agree to <= 1e-6 at a common feasible batch.

This is the repo's first direct reproduction of the paper's claim that
chunk-based memory management trains "larger batch sizes" on the same
hardware (Fig. 10's batch axis): the acceptance bar is a >= 1.5x larger
maximum batch with the act stream enabled.
"""

import argparse
import json

from benchmarks.common import lm_batch
from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.memory import OutOfMemory

SEQ = 64


def _cfg(num_layers):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=num_layers, param_dtype="float32",
        compute_dtype="float32")


def _make_engine(cfg, budget, manage_activations, strict=True):
    return PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=budget,
        manage_activations=manage_activations, strict_device_budget=strict)


def trainable(cfg, budget, batch_size, manage_activations, steps=2):
    """True iff `steps` full iterations fit the strict device budget
    (step 1 is the warm-up; step 2 runs under the traced profile, where
    the strict feasibility check first applies)."""
    try:
        eng = _make_engine(cfg, budget, manage_activations)
        batch = lm_batch(cfg, batch_size, SEQ)
        for _ in range(steps):
            eng.step(batch)
        eng.pool.check_invariants()
        assert eng.pool.peak_device_bytes <= budget
        return True
    except OutOfMemory:
        return False


def max_trainable_batch(cfg, budget, manage_activations, cap=4096):
    if not trainable(cfg, budget, 1, manage_activations):
        return 0
    lo, hi = 1, 2
    while hi <= cap and trainable(cfg, budget, hi, manage_activations):
        lo, hi = hi, hi * 2
    if hi > cap:
        return lo
    while hi - lo > 1:  # lo trainable, hi not
        mid = (lo + hi) // 2
        if trainable(cfg, budget, mid, manage_activations):
            lo = mid
        else:
            hi = mid
    return lo


def loss_parity(cfg, budget, batch_size, steps=3):
    """The act stream changes WHERE activations live, never the math."""
    losses = {}
    for on in (True, False):
        eng = _make_engine(cfg, budget, on, strict=False)
        batch = lm_batch(cfg, batch_size, SEQ)
        losses[on] = [eng.step(batch).loss for _ in range(steps)]
    diffs = [abs(a - b) for a, b in zip(losses[True], losses[False])]
    return losses, max(diffs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer layers + smaller cap for CI")
    ap.add_argument("--budget", type=int, default=6_000_000)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()
    layers = 4 if args.smoke else args.layers
    cap = 512 if args.smoke else 4096
    cfg = _cfg(layers)

    b_on = max_trainable_batch(cfg, args.budget, True, cap=cap)
    b_off = max_trainable_batch(cfg, args.budget, False, cap=cap)
    ratio = b_on / b_off if b_off else float("inf")

    common = max(min(b_on, b_off), 1)
    losses, max_diff = loss_parity(cfg, args.budget, common)

    report = {
        "device_budget_bytes": args.budget,
        "num_layers": layers,
        "seq_len": SEQ,
        "max_batch_act_on": b_on,
        "max_batch_act_off": b_off,
        "batch_ratio": ratio,
        "parity_batch": common,
        "losses_act_on": losses[True],
        "losses_act_off": losses[False],
        "max_per_step_loss_diff": max_diff,
    }
    print(json.dumps(report, indent=2))

    # acceptance: the act stream buys >= 1.5x batch headroom at equal
    # budget, and per-step losses agree (placement-only change)
    assert b_off >= 1, "baseline cannot train at all; budget too small"
    assert ratio >= 1.5, (b_on, b_off)
    assert max_diff <= 1e-6, max_diff


if __name__ == "__main__":
    main()
