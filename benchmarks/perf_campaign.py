"""§Perf hillclimb: three pairs, hypothesis-driven option ladder.

Each iteration re-lowers + compiles the production-mesh step and records
memory_analysis + HLO collectives + analytical terms."""
import json, pathlib, time, traceback

PAIRS = {
    # worst memory / useful-ratio pair
    "xlstm-1.3b__train_4k": [
        ("baseline", {}),
        ("inner_remat", {"inner_remat": True}),
        ("inner_remat+accum8", {"inner_remat": True, "accum_steps": 8}),
        ("inner_remat+accum8+xent2k", {"inner_remat": True, "accum_steps": 8,
                                       "xent_block": 2048}),
        ("inner_remat+accum16+xent2k", {"inner_remat": True,
                                        "accum_steps": 16,
                                        "xent_block": 2048}),
    ],
    # most collective-bound pair (EP MoE psum payloads)
    "deepseek-v2-lite-16b__train_4k": [
        ("baseline", {}),
        ("combine_first", {"moe_combine_first": True}),
        ("combine_first+accum8", {"moe_combine_first": True, "accum_steps": 8,
                                  "inner_remat": True}),
        ("cf+accum8+xent2k", {"moe_combine_first": True, "accum_steps": 8,
                              "inner_remat": True, "xent_block": 2048}),
    ],
    # paper-representative dense pair
    "deepseek-7b__train_4k": [
        ("baseline", {}),
        ("accum8", {"accum_steps": 8, "inner_remat": True}),
        ("accum8+xent2k", {"accum_steps": 8, "inner_remat": True,
                           "xent_block": 2048}),
        ("accum8+xent2k+dots", {"accum_steps": 8, "inner_remat": True,
                                "xent_block": 2048, "remat": "dots"}),
    ],
}

def main():
    from repro.launch.dryrun import dryrun_one
    from repro.runtime.step import RuntimeOptions
    out = pathlib.Path("results/perf"); out.mkdir(parents=True, exist_ok=True)
    for pair, ladder in PAIRS.items():
        arch, shape = pair.split("__")
        for tag, kw in ladder:
            f = out / f"{pair}__{tag}.json"
            if f.exists() and json.loads(f.read_text()).get("status") == "ok":
                print(f"{pair} {tag}: cached", flush=True); continue
            t0 = time.time()
            try:
                rec = dryrun_one(arch, shape, multi_pod=False,
                                 options=RuntimeOptions(**kw), verbose=False)
                rec["perf_tag"] = tag
                rec["options"] = kw
            except Exception as e:
                traceback.print_exc(limit=4)
                rec = {"status": "error", "error": f"{type(e).__name__}: {str(e)[:400]}",
                       "perf_tag": tag}
            f.write_text(json.dumps(rec, indent=1))
            print(f"{pair} {tag}: {rec['status']} ({time.time()-t0:.0f}s) "
                  f"perdev={rec.get('per_device_bytes',0)/1e9:.2f}GB "
                  f"coll={rec.get('collective_link_bytes',0):.3g}", flush=True)

if __name__ == "__main__":
    main()
