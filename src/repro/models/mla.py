"""Multi-head Latent Attention (DeepSeek-V2) — arXiv:2405.04434.

KV is compressed into a per-token latent ``c_kv`` of ``kv_lora_rank``
dims plus one shared RoPE key of ``qk_rope_dim`` dims; per-head keys and
values are up-projections of the latent.  The decode path uses the
*absorbed* formulation: queries are mapped into latent space
(q_nope @ W_uk) so the cache stays compressed — [B, S, kv_lora+rope]
instead of [B, S, H, 2*dh] — which is why long-context MLA serving is
memory-cheap.

TP: heads shard over the model axis (wq/w_uk/w_uv/wo); the latent
projections (w_dkv, w_krope) and the latent cache are replicated (they
are head-independent and small).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models.layers import AxisCtx


def _mla_dims(cfg: MoEConfig, tp: int):
    if cfg.n_heads % tp != 0:
        raise ValueError(f"MLA heads {cfg.n_heads} % tp {tp} != 0")
    return cfg.n_heads // tp, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim


def init_mla(key, cfg: MoEConfig, tp: int, dtype) -> dict:
    d, r = cfg.d_model, cfg.kv_lora_rank
    h_l, nope, rope, vd = _mla_dims(cfg, tp)
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], (d, h_l * (nope + rope)), dtype=dtype),
        "w_dkv": L.dense_init(ks[1], (d, r), dtype=dtype),
        "w_krope": L.dense_init(ks[2], (d, rope), dtype=dtype),
        "kv_norm": jnp.ones((r,), dtype),
        "w_uk": L.dense_init(ks[3], (r, h_l * nope), dtype=dtype),
        "w_uv": L.dense_init(ks[4], (r, h_l * vd), dtype=dtype),
        "wo": L.dense_init(ks[5], (h_l * vd, d), dtype=dtype),
    }


def mla_tp_axes() -> dict:
    return {"wq": 1, "w_dkv": None, "w_krope": None, "kv_norm": None,
            "w_uk": 1, "w_uv": 1, "wo": 0}


def _latent(p, x, cfg, positions):
    """-> (c_kv [B,S,r] normed, k_pe [B,S,1,rope] roped)."""
    c = L.rms_norm(L.matmul(x, p["w_dkv"]), p["kv_norm"])
    k_pe = L.matmul(x, p["w_krope"])[:, :, None, :]
    k_pe = L.apply_rope(k_pe, positions, getattr(cfg, "rope_theta", 10000.0))
    return c, k_pe


def _queries(p, x, cfg, ctx, positions):
    b, s, _ = x.shape
    h_l, nope, rope, _ = _mla_dims(cfg, ctx.tp)
    q = L.matmul(x, p["wq"]).reshape(b, s, h_l, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = L.apply_rope(q_pe, positions, getattr(cfg, "rope_theta", 10000.0))
    return q_nope, q_pe


def mla_fwd(p, x, cfg: MoEConfig, ctx: AxisCtx, *, positions=None):
    """Training forward: materialize per-head K/V from the latent."""
    b, s, _ = x.shape
    h_l, nope, rope, vd = _mla_dims(cfg, ctx.tp)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    c, k_pe = _latent(p, x, cfg, positions)
    q_nope, q_pe = _queries(p, x, cfg, ctx, positions)
    k_nope = L.matmul(c, p["w_uk"]).reshape(b, s, h_l, nope)
    v = L.matmul(c, p["w_uv"]).reshape(b, s, h_l, vd)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, h_l, rope))], axis=-1)
    scale = 1.0 / math.sqrt(nope + rope)
    # v head dim may differ from qk dim; attention_core supports it since
    # the score einsum only uses k's dim.
    out = L.attention_core(q, k, v, ctx, causal=True, scale=scale)
    y = L.matmul(out.reshape(b, s, -1), p["wo"], jnp.float32)
    return ctx.psum_model(y).astype(x.dtype)


def mla_init_cache(cfg: MoEConfig, batch: int, max_len: int, dtype,
                   tp: int = 1) -> dict:
    """The latent cache is head-independent, so it shards over the model
    axis by SEQUENCE chunks (tp chunks of ceil(S/tp)) instead of being
    replicated per head-rank — decode combines the per-chunk partial
    online-softmax with an exp-weighted psum."""
    c_l = -(-max_len // max(tp, 1))
    return {
        "c": jnp.zeros((batch, c_l, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, c_l, cfg.qk_rope_dim), dtype),
    }


def mla_prefill(p, x, cfg: MoEConfig, ctx: AxisCtx):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    c, k_pe = _latent(p, x, cfg, positions)
    # full-sequence attention as in training
    y = mla_fwd(p, x, cfg, ctx, positions=positions)
    # keep only this rank's sequence chunk of the latent cache
    tp = max(ctx.tp, 1)
    c_l = -(-s // tp)
    pad = c_l * tp - s
    kp = k_pe[:, :, 0, :]
    if pad:
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad), (0, 0)))
    seq_idx = ctx.model_rank()
    idx = jnp.arange(c_l) * tp + seq_idx  # strided slot ownership
    c = jnp.take(c, idx, axis=1)
    kp = jnp.take(kp, idx, axis=1)
    return y, {"c": c, "k_pe": kp}


def mla_decode(p, x, cache, pos, cfg: MoEConfig, ctx: AxisCtx):
    """Absorbed single-token decode against the (sequence-sharded)
    compressed cache: every rank scores ALL heads against its latent
    chunk; partials combine with an exp-weighted psum; each rank then
    projects its own head slice (w_uv/wo are head-sharded)."""
    b = x.shape[0]
    h_l, nope, rope, vd = _mla_dims(cfg, ctx.tp)
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    tp = max(ctx.tp, 1)
    positions = jnp.broadcast_to(jnp.asarray(pos)[None], (b, 1))
    c_t, kpe_t = _latent(p, x, cfg, positions)  # [B,1,r], [B,1,1,rope]

    c_l = cache["c"].shape[1]
    seq_idx = ctx.model_rank()
    owner = jnp.mod(pos, tp)  # strided slot ownership
    lslot = pos // tp
    mine = owner == seq_idx
    old_c = jax.lax.dynamic_slice(cache["c"], (0, lslot, 0), c_t.shape)
    old_k = jax.lax.dynamic_slice(cache["k_pe"], (0, lslot, 0),
                                  (b, 1, rope))
    cache_c = jax.lax.dynamic_update_slice(
        cache["c"], jnp.where(mine, c_t.astype(cache["c"].dtype), old_c),
        (0, lslot, 0))
    cache_kpe = jax.lax.dynamic_update_slice(
        cache["k_pe"],
        jnp.where(mine, kpe_t[:, :, 0, :].astype(cache["k_pe"].dtype), old_k),
        (0, lslot, 0))

    q_nope, q_pe = _queries(p, x, cfg, ctx, positions)  # [B,1,h_l,*]
    w_uk = p["w_uk"].reshape(r, h_l, nope)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    if tp > 1:
        # all heads on every rank (tiny: [B,1,H,r])
        q_abs = jax.lax.all_gather(q_abs, ctx.model_axis, axis=2, tiled=True)
        q_pe = jax.lax.all_gather(q_pe, ctx.model_axis, axis=2, tiled=True)
    scores = jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(cache_c.dtype), cache_c,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bqhp,bsp->bhqs", q_pe.astype(cache_kpe.dtype),
                         cache_kpe, preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(nope + rope)
    gslot = jnp.arange(c_l) * tp + seq_idx
    scores = jnp.where((gslot <= pos)[None, None, None, :], scores, L.NEG_INF)
    # partial online softmax over my chunk, combined across ranks
    m_loc = jnp.max(scores, axis=-1)  # [B,H,1]
    w = jnp.exp(scores - m_loc[..., None])
    l_loc = jnp.sum(w, axis=-1)
    acc = jnp.einsum("bhqs,bsr->bhqr", w.astype(cache_c.dtype), cache_c,
                     preferred_element_type=jnp.float32)
    if tp > 1:
        m_star = jax.lax.pmax(m_loc, ctx.model_axis)
        sc = jnp.exp(m_loc - m_star)
        l_comb = jax.lax.psum(l_loc * sc, ctx.model_axis)
        acc = jax.lax.psum(acc * sc[..., None], ctx.model_axis)
    else:
        l_comb = l_loc
    latent = (acc / jnp.maximum(l_comb[..., None], 1e-30)).transpose(0, 2, 1, 3)
    if tp > 1:  # [B,1,H,r] -> my head slice
        latent = jax.lax.dynamic_slice_in_dim(
            latent, ctx.model_rank() * h_l, h_l, axis=2)
    w_uv = p["w_uv"].reshape(r, h_l, vd)
    out = jnp.einsum("bqhr,rhv->bqhv", latent.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    y = L.matmul(out.reshape(b, 1, -1).astype(x.dtype), p["wo"], jnp.float32)
    y = ctx.psum_model(y).astype(x.dtype)
    return y, {"c": cache_c, "k_pe": cache_kpe}
