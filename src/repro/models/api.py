"""Model API: how architectures plug into the chunked runtime.

A model is a **stem** plus an ordered list of **block groups**:

* The *stem* holds everything used at step scope: token embedding / LM
  head (vocab-parallel), final norm, modality projectors, and any params
  **shared across layers** (e.g. Zamba2's shared attention block — the
  paper's refcount>1 tensors).  Stem chunks are fetched once per step.
* Each *block group* is a stack of ``length`` structurally identical
  layers executed with ``jax.lax.scan``; its params are stored stacked
  ``[L, ...]`` and chunk-managed per layer, so the distributed runtime can
  all-gather exactly one layer's communication groups inside the scan body
  (PatrickStar's per-operator chunk fetch, Section 6.2/7).

The runtime (``launch/train.py``) owns chunking/gathering; models only
describe structure and pure per-layer math.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.layers import AxisCtx


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    """A scanned stack of identical layers."""

    name: str
    length: int
    # init_layer(key) -> TP-local params pytree for ONE layer
    init_layer: Callable[[jax.Array], Any]
    # apply(params, x, extras, ctx) -> x            (training / full-seq)
    apply: Callable[[Any, jax.Array, Any, AxisCtx], jax.Array]
    # init_cache(batch, max_len) -> ONE layer's decode cache
    init_cache: Callable[[int, int], Any] | None = None
    # prefill(params, x, extras, ctx) -> (x, cache)
    prefill: Callable[..., tuple[jax.Array, Any]] | None = None
    # decode(params, x, cache, pos, extras, ctx) -> (x, cache)
    decode: Callable[..., tuple[jax.Array, Any]] | None = None


class Model:
    """Base class; concrete architectures override the hooks below."""

    def __init__(self, cfg: Any, ctx: AxisCtx):
        self.cfg = cfg
        self.ctx = ctx

    # ----------------------------------------------------------- structure
    def init_stem(self, key: jax.Array) -> Any:
        raise NotImplementedError

    def groups(self) -> list[BlockGroup]:
        raise NotImplementedError

    # ------------------------------------------------------------- forward
    def embed(self, stem: Any, batch: dict) -> tuple[jax.Array, Any]:
        """-> (x [B,S,d], extras) — extras are scan-invariant inputs that
        block groups may consume (original embeddings, encoder output,
        shared-block params...)."""
        raise NotImplementedError

    def between_groups(self, name: str, x: jax.Array, extras: Any,
                       stem: Any, batch: dict) -> tuple[jax.Array, Any]:
        """Hook run before group ``name`` (e.g. enc->dec handoff)."""
        return x, extras

    def head_loss(self, stem: Any, x: jax.Array, batch: dict) -> jax.Array:
        """Final norm + LM head + masked mean loss (scalar, LOCAL batch
        sum / GLOBAL token count; the runtime psums across dp)."""
        raise NotImplementedError

    # ------------------------------------------------------------- serving
    def embed_decode(self, stem: Any, token: jax.Array, pos: jax.Array,
                     extras: Any) -> jax.Array:
        """Embed a single decode token -> [B,1,d]."""
        raise NotImplementedError

    def head_logits(self, stem: Any, x: jax.Array) -> jax.Array:
        """-> vocab-LOCAL logits (fp32)."""
        raise NotImplementedError

    def decode_extras(self, stem: Any, x: jax.Array) -> Any:
        """extras for decode-time group applies (default: none)."""
        return None

    # ------------------------------------------------------------ metadata
    @property
    def supports_decode(self) -> bool:
        # encoder-style groups (no cache) are skipped at decode time; the
        # model decodes iff at least one group has a decode step
        return any(g.decode is not None for g in self.groups())

    def init_params(self, key: jax.Array) -> dict:
        """Full (TP-local) param tree: {"stem": ..., groups: {name: stacked}}."""
        keys = jax.random.split(key, 1 + len(self.groups()))
        params = {"stem": self.init_stem(keys[0])}
        groups = {}
        for i, g in enumerate(self.groups()):
            lkeys = jax.random.split(keys[1 + i], g.length)
            groups[g.name] = jax.vmap(g.init_layer)(lkeys)
        params["groups"] = groups
        return params

    def param_specs(self) -> dict:
        """ShapeDtypeStructs of the TP-local param tree (no allocation)."""
        return jax.eval_shape(lambda k: self.init_params(k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(tree: Any) -> int:
    import numpy as np

    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def masked_mean_loss(per_tok_loss: jax.Array, mask: jax.Array | None,
                     global_tokens: float) -> jax.Array:
    """Local loss sum scaled by the GLOBAL token count, so that psum over
    the dp axes yields the true global mean (and grads are correctly
    scaled without a later divide)."""
    if mask is not None:
        per_tok_loss = per_tok_loss * mask
    return jnp.sum(per_tok_loss) / global_tokens
