"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with ONE
globally-shared attention+MLP block applied every ``shared_interval``
layers.

The shared block's weights live in the STEM (fetched once per step) —
these are exactly the paper's shared-parameter tensors whose chunks are
referenced by multiple operators (refcount > 1, Section 6.2).  It runs on
``concat(hidden, original_embedding)`` (2*d_model wide, as in Zamba) and
each unit owns a small projection back to d_model.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import HybridConfig, dtype_of
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.api import BlockGroup
from repro.models.layers import AxisCtx, all_axes, vary_tree
from repro.models.transformer import TransformerLM


def _shared_cfg(cfg: HybridConfig):
    """The shared attention block operates at 2*d_model width."""
    return cfg.replace(d_model=2 * cfg.d_model, d_ff=cfg.d_ff,
                       sliding_window=None)


class ZambaLM(TransformerLM):
    cfg: HybridConfig

    # ------------------------------------------------------------------ stem
    def init_stem(self, key) -> dict:
        k1, k2, k3 = jax.random.split(key, 3)
        stem = super().init_stem(k1)
        scfg = _shared_cfg(self.cfg)
        stem["shared_attn"] = {
            "attn": L.init_attention(k2, scfg, self.ctx.tp, self.dtype),
            "mlp": L.init_mlp(k3, scfg, self.ctx.tp, self.dtype),
            "norm_attn": jnp.ones((scfg.d_model,), self.dtype),
            "norm_mlp": jnp.ones((scfg.d_model,), self.dtype),
        }
        return stem

    # ------------------------------------------------------------------ unit
    def _init_unit(self, key):
        cfg = self.cfg
        ku, kp = jax.random.split(key)
        mk = jax.random.split(ku, cfg.shared_interval)

        def one_mamba(k):
            return {"norm": jnp.ones((cfg.d_model,), self.dtype),
                    "cell": S.init_mamba2(k, cfg, self.ctx.tp, self.dtype)}

        return {
            "mamba": jax.vmap(one_mamba)(mk),
            # per-unit projection of the shared block's 2d output back to d
            "w_proj": L.dense_init(kp, (2 * cfg.d_model, cfg.d_model),
                                   dtype=self.dtype),
        }

    def _shared_block(self, sp, x2, ctx, *, mode, cache=None, pos=None):
        """x2: [B,S,2d]. Returns (out [B,S,2d], cache)."""
        scfg = _shared_cfg(self.cfg)
        h = L.rms_norm(x2, sp["norm_attn"])
        if mode == "train":
            a = L.attention_fwd(sp["attn"], h, scfg, ctx)
            new_cache = None
        elif mode == "prefill":
            a, new_cache = L.attention_prefill(sp["attn"], h, scfg, ctx)
        else:
            a, new_cache = L.attention_decode(sp["attn"], h, cache, pos, scfg, ctx)
        x2 = x2 + a
        h = L.rms_norm(x2, sp["norm_mlp"])
        x2 = x2 + L.mlp_fwd(sp["mlp"], h, scfg, ctx)
        return x2, new_cache

    def _apply_unit(self, p, x, extras, ctx, *, mode, cache=None, pos=None):
        cfg = self.cfg
        stem_shared, x0 = extras["shared_attn"], extras["x0"]
        # shared attention block first (Zamba puts attention between groups)
        x2 = jnp.concatenate([x, x0], axis=-1)
        x2, attn_cache = self._shared_block(
            stem_shared, x2, ctx, mode=mode,
            cache=None if mode != "decode" else cache["attn"], pos=pos)
        x = x + L.matmul(x2, p["w_proj"], jnp.float32).astype(x.dtype)

        va = all_axes(ctx)
        if mode == "train":
            def body(cx, mp):
                h = L.rms_norm(cx, mp["norm"])
                y, _ = S.mamba2_fwd(mp["cell"], h, cfg, ctx)
                return vary_tree(cx + y, va), None
            x, _ = jax.lax.scan(body, vary_tree(x, va), p["mamba"])
            return x, 0.0
        if mode == "prefill":
            def body(cx, mp):
                h = L.rms_norm(cx, mp["norm"])
                y, (state, convs) = S.mamba2_fwd(mp["cell"], h, cfg, ctx)
                return vary_tree(cx + y, va), vary_tree(
                    {"state": state, "conv_x": convs["x"],
                     "conv_B": convs["B"], "conv_C": convs["C"]}, va)
            x, mcaches = jax.lax.scan(body, vary_tree(x, va), p["mamba"])
            return x, {"attn": attn_cache, "mamba": mcaches}
        # decode
        def body(cx, inp):
            mp, mc = inp
            h = L.rms_norm(cx, mp["norm"])
            y, mc2 = S.mamba2_decode(mp["cell"], h, mc, cfg, ctx)
            return vary_tree(cx + y, va), vary_tree(mc2, va)
        x, mcaches = jax.lax.scan(body, vary_tree(x, va), (p["mamba"], cache["mamba"]))
        return x, {"attn": attn_cache, "mamba": mcaches}

    # --------------------------------------------------------------- plumbing
    def embed(self, stem, batch):
        x, _ = super().embed(stem, batch)
        return x, {"shared_attn": stem["shared_attn"], "x0": x}

    def embed_decode(self, stem, token, pos, extras):
        x = super().embed_decode(stem, token, pos, extras)
        return x

    def decode_extras(self, stem, x):
        return {"shared_attn": stem["shared_attn"], "x0": x}

    def _unit_init_cache(self, batch, max_len):
        cfg = self.cfg
        scfg = _shared_cfg(cfg)
        mc = S.mamba2_init_cache(cfg, batch, self.ctx.tp,
                                 dtype_of(cfg.compute_dtype))
        mc = jax.tree.map(lambda t: jnp.broadcast_to(
            t[None], (cfg.shared_interval,) + t.shape), mc)
        return {
            "attn": L.attention_init_cache(scfg, batch, max_len, self.ctx.tp,
                                           dtype_of(cfg.compute_dtype)),
            "mamba": mc,
        }

    # ----------------------------------------------------- tail mamba layers
    def _init_tail_layer(self, key):
        cfg = self.cfg
        return {"norm": jnp.ones((cfg.d_model,), self.dtype),
                "cell": S.init_mamba2(key, cfg, self.ctx.tp, self.dtype)}

    def _tail_apply(self, p, x, extras, ctx):
        h = L.rms_norm(x, p["norm"])
        y, _ = S.mamba2_fwd(p["cell"], h, self.cfg, ctx)
        return x + y, 0.0

    def _tail_prefill(self, p, x, extras, ctx):
        h = L.rms_norm(x, p["norm"])
        y, (state, convs) = S.mamba2_fwd(p["cell"], h, self.cfg, ctx)
        return x + y, {"state": state, "conv_x": convs["x"],
                       "conv_B": convs["B"], "conv_C": convs["C"]}

    def _tail_decode(self, p, x, cache, pos, extras, ctx):
        h = L.rms_norm(x, p["norm"])
        y, c2 = S.mamba2_decode(p["cell"], h, cache, self.cfg, ctx)
        return x + y, c2

    def groups(self) -> list[BlockGroup]:
        cfg = self.cfg
        out = [BlockGroup(
            name="units",
            length=cfg.num_units,
            init_layer=self._init_unit,
            apply=lambda p, x, e, ctx: self._apply_unit(p, x, e, ctx, mode="train"),
            init_cache=self._unit_init_cache,
            prefill=lambda p, x, e, ctx: self._apply_unit(p, x, e, ctx, mode="prefill"),
            decode=lambda p, x, c, pos, e, ctx: self._apply_unit(
                p, x, e, ctx, mode="decode", cache=c, pos=pos),
        )]
        if cfg.tail_layers:
            out.append(BlockGroup(
                name="tail",
                length=cfg.tail_layers,
                init_layer=self._init_tail_layer,
                apply=self._tail_apply,
                init_cache=lambda b, m: S.mamba2_init_cache(
                    cfg, b, self.ctx.tp, dtype_of(cfg.compute_dtype)),
                prefill=self._tail_prefill,
                decode=self._tail_decode,
            ))
        return out


def _zamba_tp_axes(self) -> dict:
    from repro.models.transformer import _stem_tp_axes
    cfg = self.cfg
    scfg = _shared_cfg(cfg)
    stem = _stem_tp_axes(cfg)
    stem["shared_attn"] = {
        "attn": L.attention_tp_axes(scfg, self.ctx.tp),
        "mlp": L.mlp_tp_axes(scfg),
        "norm_attn": None, "norm_mlp": None,
    }
    unit = {"mamba": {"norm": None, "cell": S.mamba2_tp_axes()},
            "w_proj": None}
    groups = {"units": unit}
    if cfg.tail_layers:
        groups["tail"] = {"norm": None, "cell": S.mamba2_tp_axes()}
    return {"stem": stem, "groups": groups}


ZambaLM.tp_axes = _zamba_tp_axes
