"""Common model layers, written for shard_map SPMD execution.

Conventions
-----------
* All functions are pure; params are plain dicts of jax arrays.
* Tensor-parallel (TP) sharding is *explicit*: param shapes passed in are
  the TP-LOCAL shards, and layers perform the required ``psum`` over the
  model axis themselves, driven by :class:`AxisCtx`.  With ``tp == 1`` the
  ctx degenerates and no collectives are emitted.
* Attention/MLP follow the Megatron pattern: column-parallel in
  (q/k/v, up/gate), row-parallel out (o, down) with one psum per block.
* Activations stay in the compute dtype (bf16 by default); matmuls
  accumulate in fp32 via ``preferred_element_type``.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    """Mesh axis context available inside shard_map."""

    model_axis: str | None = None
    tp: int = 1
    data_axis: str | None = None
    dp: int = 1
    pod_axis: str | None = None
    pods: int = 1
    # implementation switches
    attn_impl: str = "auto"  # "naive" | "scan" | "auto"
    attn_block: int = 512  # kv block for the scan/flash impl
    # §Perf: checkpoint inner sequence scans (SSD/mLSTM/sLSTM/flash-scan
    # bodies) so their backward recomputes per-step intermediates instead
    # of storing them — the "memory term" hillclimb
    inner_remat: bool = False
    # §Perf: MoE combines expert outputs BEFORE the model-axis psum
    # ([T,d] instead of [E,C,d] payload) — the "collective term" hillclimb
    moe_combine_first: bool = False
    # §Perf: compute the vocab-parallel cross-entropy blockwise over the
    # sequence (fp32 logits live range / n_blocks) — 0 disables
    xent_block: int = 0

    # NOTE: collectives are gated on axis PRESENCE, not axis size — with
    # shard_map's check_vma=True, a psum over a size-1 mesh axis is a
    # typing no-op that marks the value invariant over that axis (and the
    # transpose machinery needs it for correct gradients).
    def psum_model(self, x):
        return jax.lax.psum(x, self.model_axis) if self.model_axis else x

    def pmax_model(self, x):
        return jax.lax.pmax(x, self.model_axis) if self.model_axis else x

    def model_rank(self):
        if self.model_axis:
            return jax.lax.axis_index(self.model_axis)
        return jnp.int32(0)

    def dp_axes(self) -> tuple[str, ...]:
        axes = []
        if self.pod_axis:
            axes.append(self.pod_axis)
        if self.data_axis:
            axes.append(self.data_axis)
        return tuple(axes)


# ---------------------------------------------------------------------------
# varying-manual-axes (vma) helpers for shard_map's check_vma=True
# ---------------------------------------------------------------------------


def all_axes(ctx: "AxisCtx") -> tuple[str, ...]:
    return tuple(a for a in (ctx.pod_axis, ctx.data_axis, ctx.model_axis) if a)


def shard_map_compat(fn, *, mesh, in_specs, out_specs, check_vma=True):
    """jax >= 0.6 spells this ``jax.shard_map(check_vma=...)``; older
    releases only have ``jax.experimental.shard_map.shard_map`` with
    ``check_rep``.  The vma helpers below already degrade to no-ops
    there.  check_rep maps from check_vma: replication checking is what
    gives the legacy psum its correct (identity-style) transpose in
    training; serve paths that ask for check_vma=False get it off."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)


def replicated_loss_compat(x, tp: int):
    """Gradient-correctness shim for model-axis-replicated losses on jax
    without the vma type system (legacy ``experimental.shard_map``).

    A TP step computes the SAME total loss redundantly on every model
    rank (activations are psum-combined, so each rank's scalar is the
    full loss).  Under vma-typed jax the pcast/psum transpose rules know
    the value is one invariant object and gradients come out right.  The
    legacy transpose machinery instead differentiates each rank's copy
    with cotangent 1 — the effective objective is ``tp * loss`` and every
    gradient leaf (sharded and replicated alike) is tp-times too large.
    Scaling the loss cotangent by ``1/tp`` on that path makes the
    per-rank redundant copies sum to the true gradient; on vma-typed jax
    (where ``jax.shard_map`` exists) this is the identity."""
    if tp <= 1 or hasattr(jax, "shard_map"):
        return x

    @jax.custom_vjp
    def _once(y):
        return y

    def fwd(y):
        return y, None

    def bwd(_, g):
        return (g / tp,)

    _once.defvjp(fwd, bwd)
    return _once(x)


def vary_to(x, axes: tuple[str, ...]):
    """pcast ``x`` to varying over ``axes`` (idempotent, typing-only)."""
    if not axes or not hasattr(x, "dtype"):
        return x
    try:
        vma = jax.typeof(x).vma
    except Exception:
        return x
    missing = tuple(a for a in axes if a not in vma)
    if not missing:
        return x
    return jax.lax.pcast(x, missing, to="varying")


def vary_tree(tree, axes: tuple[str, ...]):
    """Stabilize a scan carry's vma type: cast every leaf to varying over
    ``axes``.  Values are unchanged; the pcast transpose (psum over the
    added axes) is exactly the correct gradient rule for an invariant
    value consumed by device-varying computation."""
    if not axes:
        return tree
    return jax.tree.map(lambda t: vary_to(t, axes), tree)


# ---------------------------------------------------------------------------
# initializers / numerics helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def matmul(x, w, ctx_dtype=None):
    out = jnp.einsum("...d,df->...f", x, w, preferred_element_type=jnp.float32)
    return out.astype(ctx_dtype or x.dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def squared_relu(x):
    r = jnp.maximum(x, 0)
    return r * r


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "relu2": squared_relu,
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # [head_dim//2]


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores (pure math on [B, S, H, Dh] tensors)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def naive_attention(q, k, v, *, causal: bool, window: int | None = None,
                    q_offset: int | jax.Array = 0, kv_len: jax.Array | None = None,
                    scale: float | None = None):
    """Reference attention. q: [B,Sq,H,D], k/v: [B,Sk,KV,D] (KV divides H)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    qpos = jnp.arange(sq) + q_offset  # [Sq]
    kpos = jnp.arange(sk)  # [Sk]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v, preferred_element_type=jnp.float32).astype(q.dtype)


def scan_attention(q, k, v, *, causal: bool, window: int | None = None,
                   q_offset: int | jax.Array = 0, kv_len: jax.Array | None = None,
                   scale: float | None = None, block: int = 512,
                   vary_axes: tuple = (), inner_remat: bool = False):
    """Online-softmax (flash-style) attention as a jnp scan over KV blocks.

    Linear memory in KV length — this is what the big dry-run shapes lower
    (the Pallas flash kernel implements the same schedule for real TPUs;
    ``kernels/flash_attention/ref.py`` cross-checks both against
    :func:`naive_attention`).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from the qk dim (e.g. MLA)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    rep = h // kvh
    nblk = -(-sk // block)
    pad = nblk * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, kvh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block, kvh, dv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq) + q_offset
    q32 = q.astype(jnp.float32) * scale

    def body(carry, inp):
        acc, m, l = carry
        blk_idx, kblk, vblk = inp  # kblk: [B, block, KV, D]
        if rep != 1:
            kblk = jnp.repeat(kblk, rep, axis=2)
            vblk = jnp.repeat(vblk, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q32, kblk.astype(jnp.float32))
        kpos = blk_idx * block + jnp.arange(block)
        mask = jnp.ones((sq, block), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        mask &= kpos[None, :] < (sk if kv_len is None else kv_len)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    carry0 = vary_tree((acc0, m0, l0), vary_axes)
    vbody = lambda c, i: (vary_tree(body(c, i)[0], vary_axes), None)
    if inner_remat:
        vbody = jax.checkpoint(vbody)
    (acc, m, l), _ = jax.lax.scan(
        vbody, carry0, (jnp.arange(nblk), kb, vb)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_core(q, k, v, ctx: AxisCtx, **kw):
    impl = ctx.attn_impl
    if impl == "auto":
        impl = "scan" if (k.shape[1] > 2048 or q.shape[1] > 2048) else "naive"
    if impl == "scan":
        return scan_attention(q, k, v, block=ctx.attn_block,
                              vary_axes=all_axes(ctx),
                              inner_remat=ctx.inner_remat, **kw)
    return naive_attention(q, k, v, **kw)


# ---------------------------------------------------------------------------
# GQA attention block (column/row parallel over the model axis)
# ---------------------------------------------------------------------------


def gqa_shapes(d_model: int, n_heads: int, n_kv: int, head_dim: int, tp: int):
    """TP-local head counts.

    Query heads divide over tp; KV heads divide when possible, otherwise
    are replicated (GQA with few KV heads).  When even the query heads do
    not divide (e.g. whisper's 20 heads on a 16-way model axis) the whole
    attention block is replicated across the model axis — correct, at the
    cost of redundant attention compute; the MLP still TP-shards.  The
    third return value says whether attention is replicated (no out-psum,
    all params TP-axis None).
    """
    if n_heads % tp != 0:
        return n_heads, n_kv, True
    h_local = n_heads // tp
    kv_local = n_kv // tp if n_kv % tp == 0 else n_kv
    return h_local, kv_local, False


def init_attention(key, cfg, tp: int, dtype=jnp.float32) -> dict:
    """cfg needs: d_model, n_heads, n_kv_heads, head_dim, qk_norm, qkv_bias."""
    d, hd = cfg.d_model, cfg.head_dim
    h_l, kv_l, _ = gqa_shapes(d, cfg.n_heads, cfg.n_kv_heads, hd, tp)
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h_l * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kv_l * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kv_l * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h_l * hd, d), dtype=dtype),
    }
    if getattr(cfg, "qkv_bias", False):
        p["bq"] = jnp.zeros((h_l * hd,), dtype)
        p["bk"] = jnp.zeros((kv_l * hd,), dtype)
        p["bv"] = jnp.zeros((kv_l * hd,), dtype)
    if getattr(cfg, "qk_norm", False):
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_tp_axes(cfg, tp: int = 1) -> dict:
    """Which axis of each param is TP-sharded (None = replicated)."""
    _, _, replicated = gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, tp)
    kv_repl = replicated or (tp > 1 and cfg.n_kv_heads % tp != 0)
    if replicated:
        axes = {"wq": None, "wk": None, "wv": None, "wo": None}
    else:
        axes = {"wq": 1, "wk": None if kv_repl else 1,
                "wv": None if kv_repl else 1, "wo": 0}
    if getattr(cfg, "qkv_bias", False):
        axes.update({"bq": None if replicated else 0,
                     "bk": None if kv_repl else 0,
                     "bv": None if kv_repl else 0})
    if getattr(cfg, "qk_norm", False):
        axes.update({"q_norm": None, "k_norm": None})
    return axes


def _project_qkv(p, x, cfg, ctx: AxisCtx, positions):
    b, s, d = x.shape
    hd = cfg.head_dim
    h_l, kv_l, _ = gqa_shapes(d, cfg.n_heads, cfg.n_kv_heads, hd, ctx.tp)
    q = matmul(x, p["wq"])
    k = matmul(x, p["wk"])
    v = matmul(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h_l, hd)
    k = k.reshape(b, s, kv_l, hd)
    v = v.reshape(b, s, kv_l, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    theta = getattr(cfg, "rope_theta", 10000.0)
    if getattr(cfg, "use_rope", True):
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v



def _align_kv(k, v, cfg, ctx: AxisCtx):
    """Select the kv heads matching this rank's local q-head slice.

    When kv heads are replicated (kv %% tp != 0) but q heads are sharded,
    the naive GQA repeat pairs local q head i with kv head i — wrong.
    Pick kv head (global_q_idx * KV) // H per local q head instead."""
    H, KV = cfg.n_heads, cfg.n_kv_heads
    h_l, kv_l, replicated = gqa_shapes(cfg.d_model, H, KV, cfg.head_dim, ctx.tp)
    if ctx.tp <= 1 or replicated or KV % ctx.tp == 0:
        return k, v
    rank = ctx.model_rank()
    qidx = rank * h_l + jnp.arange(h_l)
    kvidx = (qidx * KV) // H
    return jnp.take(k, kvidx, axis=2), jnp.take(v, kvidx, axis=2)

def attention_fwd(p, x, cfg, ctx: AxisCtx, *, positions=None, causal=True):
    """Full-sequence attention (training / prefill). x: [B, S, d]."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    ka, va = _align_kv(k, v, cfg, ctx)
    window = getattr(cfg, "sliding_window", None)
    out = attention_core(q, ka, va, ctx, causal=causal, window=window)
    out = out.reshape(b, s, -1)
    y = matmul(out, p["wo"], jnp.float32)
    if not gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd_r := cfg.head_dim, ctx.tp)[2]:
        y = ctx.psum_model(y)
    return y.astype(x.dtype)


def attention_prefill(p, x, cfg, ctx: AxisCtx, *, positions=None):
    """Prefill returning output and the KV cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    ka, va = _align_kv(k, v, cfg, ctx)
    window = getattr(cfg, "sliding_window", None)
    out = attention_core(q, ka, va, ctx, causal=True, window=window)
    out = out.reshape(b, s, -1)
    y = matmul(out, p["wo"], jnp.float32)
    if not gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, ctx.tp)[2]:
        y = ctx.psum_model(y)
    cache = _prefill_cache(k, v, s, cfg, ctx)
    return y.astype(x.dtype), cache


def _prefill_cache(k, v, s, cfg, ctx: AxisCtx):
    """Slice the freshly computed K/V into this rank's cache layout."""
    mode, kv_l, seq_shards = decode_cache_plan(cfg, ctx.tp)
    window = getattr(cfg, "sliding_window", None)
    if mode == "tp":
        if window and s > window:
            k = k[:, -window:]
            v = v[:, -window:]
        return {"k": k, "v": v}
    # distributed layout: pad seq (or window ring) to seq_shards chunks,
    # keep my (kv group, seq chunk)
    rank = ctx.model_rank()
    kv_grp = rank // seq_shards
    seq_idx = rank % seq_shards
    # wk/wv are replicated in dist mode -> k holds all KV heads
    k_my = jax.lax.dynamic_slice_in_dim(k, kv_grp * kv_l, kv_l, axis=2)
    v_my = jax.lax.dynamic_slice_in_dim(v, kv_grp * kv_l, kv_l, axis=2)
    ring = min(s, window) if window else s
    c_l = -(-ring // seq_shards)
    pad = c_l * seq_shards - ring
    if window and s > window:
        # keep the last `ring` positions, laid out at slot = pos % ring:
        # cache[i] holds position from last_ring[(i - s) mod ring]
        k_my = k_my[:, -ring:]
        v_my = v_my[:, -ring:]
        perm = jnp.mod(jnp.arange(ring) - s, ring)
        k_my = jnp.take(k_my, perm, axis=1)
        v_my = jnp.take(v_my, perm, axis=1)
    if pad:
        k_my = jnp.pad(k_my, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_my = jnp.pad(v_my, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # strided slot ownership: rank keeps slots seq_idx, seq_idx+shards, ...
    idx = jnp.arange(c_l) * seq_shards + seq_idx
    k_chunk = jnp.take(k_my, idx, axis=1)
    v_chunk = jnp.take(v_my, idx, axis=1)
    return {"k": k_chunk, "v": v_chunk}


def decode_cache_plan(cfg, tp: int):
    """How the decode KV cache distributes over the model axis.

    Returns (mode, kv_local, seq_shards):
      mode "tp":   kv heads divide tp — each rank stores kv/tp heads, full
                   sequence (classic TP cache).
      mode "dist": kv heads do NOT divide tp.  Replicating the cache
                   across the model axis would cost tp x the ideal HBM
                   (e.g. 77 GB/chip for qwen2.5-3b @ decode_32k), so the
                   cache is sharded over (kv-head groups x sequence
                   chunks): g = gcd(kv, tp) head groups, tp/g sequence
                   chunks; rank r holds kv/g heads of group r // (tp/g)
                   and sequence chunk r % (tp/g).  Decode combines the
                   per-rank partial attention with an exp-weighted psum
                   (distributed online softmax).
    """
    kv = cfg.n_kv_heads
    if tp <= 1 or kv % tp == 0:
        return "tp", max(kv // max(tp, 1), 1) if tp > 1 else kv, 1
    g = math.gcd(kv, tp)
    return "dist", kv // g, tp // g


def attention_init_cache(cfg, batch: int, max_len: int, tp: int, dtype) -> dict:
    window = getattr(cfg, "sliding_window", None)
    cache_len = min(max_len, window) if window else max_len
    mode, kv_l, seq_shards = decode_cache_plan(cfg, tp)
    if mode == "tp":
        _, kv_l, _ = gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, tp)
    cache_len = -(-cache_len // seq_shards)  # per-rank seq chunk
    z = jnp.zeros((batch, cache_len, kv_l, cfg.head_dim), dtype)
    return {"k": z, "v": z}


def attention_decode(p, x, cache, pos, cfg, ctx: AxisCtx):
    """Single-token decode. x: [B, 1, d]; pos: scalar int (current index);
    cache k/v: [B, C, KV_l, hd] (C covers the window for SWA, else the max
    length; divided by seq_shards in distributed-cache mode)."""
    mode, kv_l, seq_shards = decode_cache_plan(cfg, ctx.tp)
    if mode == "dist":
        return _attention_decode_dist(p, x, cache, pos, cfg, ctx,
                                      kv_l, seq_shards)
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos)[None], (b, 1))
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    window = getattr(cfg, "sliding_window", None)
    cache_len = cache["k"].shape[1]
    slot = jnp.mod(pos, cache_len) if window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    if window:
        # ring buffer: positions of slots = pos - ((slot - j) mod C)
        j = jnp.arange(cache_len)
        slot_pos = pos - jnp.mod(slot - j, cache_len)
        valid = (slot_pos >= 0) & (slot_pos > pos - window)
        out = _decode_attend(q, ck, cv, valid)
    else:
        kv_len = pos + 1
        out = _decode_attend(q, ck, cv, jnp.arange(cache_len) < kv_len)
    out = out.reshape(b, 1, -1)
    y = matmul(out, p["wo"], jnp.float32)
    if not gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, ctx.tp)[2]:
        y = ctx.psum_model(y)
    return y.astype(x.dtype), {"k": ck, "v": cv}


def _dist_slot_validity(pos, cache_len_local, seq_idx, window, seq_shards):
    """Global slot positions for this rank's cache chunk + validity mask.

    Slot ownership is STRIDED (round-robin): global slot s lives on rank
    s % seq_shards at local index s // seq_shards — so a prefill cache can
    grow to a longer decode horizon by appending local slots, with no
    cross-rank reshuffle.  For SWA the global slot array is a ring over
    the window."""
    j = jnp.arange(cache_len_local)
    gslot = j * seq_shards + seq_idx
    if window:
        ring = seq_shards * cache_len_local
        cur = jnp.mod(pos, ring)
        slot_pos = pos - jnp.mod(cur - gslot, ring)
        valid = (slot_pos >= 0) & (slot_pos > pos - window)
    else:
        valid = gslot <= pos
    return gslot, valid


def _attention_decode_dist(p, x, cache, pos, cfg, ctx: AxisCtx, kv_l, seq_shards):
    """Distributed-cache decode: cache sharded (kv-group x seq-chunk) over
    the model axis; partial online-softmax combined with an exp-weighted
    psum.  Requires wk/wv to hold ALL kv heads on every rank (they are
    replicated whenever kv %% tp != 0, see gqa_shapes/attention_tp_axes)."""
    b = x.shape[0]
    hd = cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    g = KV // kv_l  # head groups
    rank = ctx.model_rank()
    kv_grp = rank // seq_shards
    seq_idx = rank % seq_shards
    positions = jnp.broadcast_to(jnp.asarray(pos)[None], (b, 1))
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    h_local, _, replicated = gqa_shapes(cfg.d_model, H, KV, hd, ctx.tp)
    # 1. full query heads on every rank
    if replicated:
        q_full = q  # [B,1,H,hd]
    else:
        qg = jax.lax.all_gather(q, ctx.model_axis, axis=2, tiled=True)
        q_full = qg  # [B,1,H,hd]
    hg = H // g  # q heads per kv group
    q_grp = jax.lax.dynamic_slice_in_dim(q_full, kv_grp * hg, hg, axis=2)
    # 2. my kv-head slice of the new token (wk/wv replicated -> k has all KV)
    k_my = jax.lax.dynamic_slice_in_dim(k, kv_grp * kv_l, kv_l, axis=2)
    v_my = jax.lax.dynamic_slice_in_dim(v, kv_grp * kv_l, kv_l, axis=2)
    # 3. write into my chunk if my seq chunk owns the slot
    window = getattr(cfg, "sliding_window", None)
    cache_len = cache["k"].shape[1]
    ring = seq_shards * cache_len
    gslot_new = jnp.mod(pos, ring) if window else pos
    owner = jnp.mod(gslot_new, seq_shards)  # strided ownership
    lslot = gslot_new // seq_shards
    mine = owner == seq_idx
    # conditional write without copying the whole cache: read the old
    # slot (tiny), select, and write back unconditionally — keeps the
    # cache update a single dynamic-update-slice chain XLA can alias.
    old_k = jax.lax.dynamic_slice(cache["k"], (0, lslot, 0, 0), k_my.shape)
    old_v = jax.lax.dynamic_slice(cache["v"], (0, lslot, 0, 0), v_my.shape)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], jnp.where(mine, k_my.astype(cache["k"].dtype), old_k),
        (0, lslot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], jnp.where(mine, v_my.astype(cache["v"].dtype), old_v),
        (0, lslot, 0, 0))
    # 4. partial attention of my group's q heads over my (heads, seq) chunk
    gslot, valid = _dist_slot_validity(pos, cache_len, seq_idx, window, seq_shards)
    kk, vv = ck, cv
    if kv_l != hg:
        rep = hg // kv_l
        kk = jnp.repeat(kk, rep, axis=2)
        vv = jnp.repeat(vv, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q_grp.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    m_loc = jnp.max(logits, axis=-1)  # [B,hg,1]
    w = jnp.exp(logits - m_loc[..., None])
    l_loc = jnp.sum(w, axis=-1)
    acc_loc = jnp.einsum("bhqk,bkhd->bhqd", w, vv.astype(jnp.float32))
    # 5. pad partials to all H heads at this group's range and psum-combine
    def pad_heads(t):
        z = jnp.zeros(t.shape[:1] + (H,) + t.shape[2:], t.dtype)
        return jax.lax.dynamic_update_slice_in_dim(z, t, kv_grp * hg, axis=1)
    m_pad = pad_heads(jnp.where(l_loc > 0, m_loc, NEG_INF))
    m_star = jax.lax.pmax(m_pad, ctx.model_axis)
    scale_ = jnp.exp(m_pad - m_star)
    l_comb = jax.lax.psum(pad_heads(l_loc) * scale_, ctx.model_axis)
    acc_comb = jax.lax.psum(pad_heads(acc_loc) * scale_[..., None],
                            ctx.model_axis)
    out_full = acc_comb / jnp.maximum(l_comb[..., None], 1e-30)  # [B,H,1,hd]
    # 6. output projection with my wo slice
    if replicated:
        out = out_full.transpose(0, 2, 1, 3).reshape(b, 1, H * hd)
        y = matmul(out.astype(x.dtype), p["wo"], jnp.float32)
    else:
        my = jax.lax.dynamic_slice_in_dim(out_full, rank * h_local, h_local,
                                          axis=1)
        out = my.transpose(0, 2, 1, 3).reshape(b, 1, h_local * hd)
        y = ctx.psum_model(matmul(out.astype(x.dtype), p["wo"], jnp.float32))
    return y.astype(x.dtype), {"k": ck, "v": cv}


def _decode_attend(q, k, v, valid_mask):
    """q: [B,1,H,D]; k/v: [B,C,KV,D]; valid_mask: [C] bool."""
    b, _, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(d)
    logits = jnp.where(valid_mask[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v, preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (gated / plain), column+row parallel
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, tp: int, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if f % tp != 0:
        raise ValueError(f"d_ff={f} not divisible by tp={tp}")
    f_l = f // tp
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f_l), dtype=dtype),
         "w_down": dense_init(ks[1], (f_l, d), dtype=dtype)}
    if getattr(cfg, "gated_mlp", True):
        p["w_gate"] = dense_init(ks[2], (d, f_l), dtype=dtype)
    return p


def mlp_tp_axes(cfg) -> dict:
    axes = {"w_up": 1, "w_down": 0}
    if getattr(cfg, "gated_mlp", True):
        axes["w_gate"] = 1
    return axes


def mlp_fwd(p, x, cfg, ctx: AxisCtx):
    act = ACTIVATIONS[getattr(cfg, "activation", "silu")]
    up = matmul(x, p["w_up"])
    if "w_gate" in p:
        h = act(matmul(x, p["w_gate"])) * up
    else:
        h = act(up)
    return ctx.psum_model(matmul(h, p["w_down"], jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# vocab-parallel embedding / head / cross-entropy
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, tp: int, dtype=jnp.float32) -> dict:
    if vocab % tp != 0:
        vocab_l = -(-vocab // tp)
    else:
        vocab_l = vocab // tp
    return {"table": dense_init(key, (vocab_l, d_model), in_axis=1, dtype=dtype)}


def embedding_tp_axes() -> dict:
    return {"table": 0}


def embed_lookup(p, ids, vocab: int, ctx: AxisCtx):
    """Vocab-parallel lookup: one-hot over the local vocab shard, psum."""
    table = p["table"]
    vocab_l = table.shape[0]
    start = ctx.model_rank() * vocab_l
    local_ids = ids - start
    in_range = (local_ids >= 0) & (local_ids < vocab_l)
    safe = jnp.where(in_range, local_ids, 0)
    emb = jnp.take(table, safe, axis=0)
    emb = jnp.where(in_range[..., None], emb, 0)
    return ctx.psum_model(emb.astype(jnp.float32)).astype(table.dtype)


def lm_logits_local(p, x, ctx: AxisCtx):
    """Tied head: x @ table^T -> logits over the LOCAL vocab shard."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"], preferred_element_type=jnp.float32
    )


def greedy_token(local_logits, vocab: int, ctx: AxisCtx):
    """Argmax across vocab-parallel logits. local_logits: [B,1,V_local].

    Shared by the compiled decode step and the eager serving engine so
    both planes resolve ties identically (max first, then the lowest
    global token id): token-for-token parity between them must not hinge
    on two argmax implementations agreeing.  With ``tp == 1`` the psum /
    pmax degenerate and this is a plain masked argmax."""
    vl = local_logits.shape[-1]
    start = ctx.model_rank() * vl
    gid = start + jnp.arange(vl)
    ll = jnp.where(gid < vocab, local_logits, -jnp.inf)
    lmax = jnp.max(ll, axis=-1)
    lidx = jnp.argmax(ll, axis=-1) + start
    gmax = ctx.pmax_model(lmax)
    cand = jnp.where(lmax >= gmax, lidx, vocab + 1)
    if ctx.model_axis:
        cand = -jax.lax.pmax(-cand, ctx.model_axis)  # pmin
    return cand[..., 0].astype(jnp.int32)  # [B]


def vocab_parallel_xent(local_logits, labels, vocab: int, ctx: AxisCtx, *, mask=None):
    """Cross-entropy over a vocab-sharded logits tensor without gathering.

    local_logits: [..., V_local] fp32; labels: [...] int32 (global ids).
    Returns per-position loss [...]; psum over model is internal.
    """
    vocab_l = local_logits.shape[-1]
    start = ctx.model_rank() * vocab_l
    # mask padded vocab rows (vocab not divisible by tp)
    gid = start + jnp.arange(vocab_l)
    local_logits = jnp.where(gid < vocab, local_logits, NEG_INF)
    local_max = jax.lax.stop_gradient(jnp.max(local_logits, axis=-1))
    gmax = ctx.pmax_model(local_max)  # stop-grad'd: max-shift only
    z = jnp.sum(jnp.exp(local_logits - gmax[..., None]), axis=-1)
    gz = ctx.psum_model(z)
    lse = jnp.log(gz) + gmax
    local_labels = labels - start
    in_range = (local_labels >= 0) & (local_labels < vocab_l)
    safe = jnp.where(in_range, local_labels, 0)
    picked = jnp.take_along_axis(local_logits, safe[..., None], axis=-1)[..., 0]
    picked = jnp.where(in_range, picked, 0.0)
    target_logit = ctx.psum_model(picked)
    loss = lse - target_logit
    if mask is not None:
        loss = loss * mask
    return loss


def blockwise_xent_sum(table_p, x, labels, vocab: int, ctx: AxisCtx,
                       block: int, mask=None):
    """Sum of vocab-parallel xent over [B,S] positions, computed in
    sequence blocks so the fp32 [tokens, V_local] logits never fully
    materialize (§Perf memory-term optimization for the LM head)."""
    b, s, d = x.shape
    nb = -(-s // block)
    pad = nb * block - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        pm = jnp.pad(jnp.ones((b, s), jnp.float32) if mask is None else mask,
                     ((0, 0), (0, pad)))
    else:
        pm = jnp.ones((b, s), jnp.float32) if mask is None else mask
    xb = x.reshape(b, nb, block, d).transpose(1, 0, 2, 3)
    lb = labels.reshape(b, nb, block).transpose(1, 0, 2)
    mb = pm.reshape(b, nb, block).transpose(1, 0, 2)
    va = all_axes(ctx)

    def body(acc, inp):
        xi, li, mi = inp
        logits = lm_logits_local(table_p, xi, ctx)
        per_tok = vocab_parallel_xent(logits, li, vocab, ctx, mask=mi)
        return vary_to(acc + jnp.sum(per_tok), va), None

    body = jax.checkpoint(body)
    acc, _ = jax.lax.scan(body, vary_to(jnp.float32(0.0), va), (xb, lb, mb))
    return acc
