"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

All three are *sub-quadratic*: training/prefill uses a chunkwise-parallel
scan (intra-chunk quadratic of length ``chunk_len``, inter-chunk state
recurrence under ``jax.lax.scan``), decode is an O(1)-per-token state
update — which is what makes the ``long_500k`` shape feasible.

TP layouts:
  * Mamba2: inner channels (= heads) shard over the model axis; the
    head-shared B/C projections are replicated; out-proj row-parallel
    (psum).
  * mLSTM: q/k are replicated (full key dim per head is needed for
    scores), v/output channels shard; out-proj row-parallel (psum).
  * sLSTM: fully replicated (tiny params, dense recurrent coupling R
    prevents clean sharding) — grads agree across ranks by construction.

Numerics: gates and state updates run in fp32.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AxisCtx


def _chunk(x, q):
    """[B, S, ...] -> [B, nc, q, ...] (S % q == 0 enforced by caller pad)."""
    b, s = x.shape[:2]
    return x.reshape(b, s // q, q, *x.shape[2:])


def _pad_to(x, q):
    s = x.shape[1]
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, pad


# ===========================================================================
# Mamba2 / SSD
# ===========================================================================


def init_mamba2(key, cfg, tp: int, dtype) -> dict:
    """cfg needs: d_model, d_inner, mamba_heads, mamba_headdim, ssm_state,
    conv_kernel."""
    d, di = cfg.d_model, cfg.d_inner
    nh, ds, k = cfg.mamba_heads, cfg.ssm_state, cfg.conv_kernel
    if di % tp != 0 or nh % tp != 0:
        raise ValueError(f"mamba d_inner={di}/heads={nh} not divisible by tp={tp}")
    di_l, nh_l = di // tp, nh // tp
    ks = jax.random.split(key, 9)
    return {
        "w_z": L.dense_init(ks[0], (d, di_l), dtype=dtype),
        "w_x": L.dense_init(ks[1], (d, di_l), dtype=dtype),
        "w_B": L.dense_init(ks[2], (d, ds), dtype=dtype),
        "w_C": L.dense_init(ks[3], (d, ds), dtype=dtype),
        "w_dt": L.dense_init(ks[4], (d, nh_l), dtype=dtype),
        "conv_x": (jax.random.normal(ks[5], (k, di_l)) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (k, ds)) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (k, ds)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((nh_l,), jnp.float32),
        "D": jnp.ones((nh_l,), jnp.float32),
        "dt_bias": jnp.zeros((nh_l,), jnp.float32),
        "norm": jnp.ones((di_l,), dtype),
        "w_out": L.dense_init(ks[8], (di_l, d), dtype=dtype),
    }


def mamba2_tp_axes() -> dict:
    return {"w_z": 1, "w_x": 1, "w_B": None, "w_C": None, "w_dt": 1,
            "conv_x": 1, "conv_B": None, "conv_C": None,
            "A_log": 0, "D": 0, "dt_bias": 0, "norm": 0, "w_out": 0}


def _causal_conv(x, kernel, carry=None):
    """Depthwise causal conv. x: [B,S,C]; kernel: [K,C].
    carry: [B,K-1,C] previous inputs (decode) or None (zeros)."""
    k = kernel.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * kernel[i] for i in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else carry
    return jax.nn.silu(out), new_carry


def _ssd_chunk_scan(xh, bt, ct, la, dt, state0, vary_axes=(),
                    inner_remat=False):
    """Chunkwise SSD.

    xh: [B,nc,q,nh,dh]  inputs per head
    bt/ct: [B,nc,q,ds]  input/output projections (shared across heads)
    la: [B,nc,q,nh]     per-step log decay (cumulative within chunk done here)
    dt: [B,nc,q,nh]     step sizes
    state0: [B,nh,dh,ds]
    -> y [B,nc,q,nh,dh], state_out
    """
    lac = jnp.cumsum(la, axis=2)  # cumulative log decay within chunk
    # intra-chunk: scores[t,s] = (C_t.B_s) * exp(lac_t - lac_s) * dt_s, s<=t
    q = xh.shape[2]
    mask = jnp.tril(jnp.ones((q, q), bool))
    cb = jnp.einsum("bnts,bnqs->bntq", ct, bt)  # [B,nc,q(t),q(s)] wait dims
    # ct: [B,nc,q,ds]; bt: [B,nc,q,ds] -> scores over (t,s)
    decay = lac[:, :, :, None, :] - lac[:, :, None, :, :]  # [B,nc,t,s,nh]
    w = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    w = w * dt[:, :, None, :, :]  # weight by dt_s
    y_intra = jnp.einsum("bnts,bntsh,bnshd->bnthd", cb, w, xh)
    # inter-chunk state carry
    # state contribution of chunk: sum_s exp(laQ - lac_s) * dt_s * x_s B_s^T
    laq = lac[:, :, -1:, :]  # [B,nc,1,nh]
    w_state = jnp.exp(laq - lac) * dt  # [B,nc,q,nh]
    chunk_state = jnp.einsum("bnsh,bnshd,bnse->bnhde",
                             w_state, xh, bt)  # [B,nc,nh,dh,ds]
    chunk_decay = jnp.exp(laq[:, :, 0, :])  # [B,nc,nh]

    def step(state, inp):
        cs, cd, ct_c, lac_c = inp  # per-chunk tensors (nc axis scanned)
        # output from incoming state: y_t += exp(lac_t) * C_t . state
        y_in = jnp.einsum("bts,bhds,bth->bthd", ct_c, state, jnp.exp(lac_c))
        state = state * cd[:, :, None, None] + cs
        return state, y_in

    xs = (
        chunk_state.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        ct.transpose(1, 0, 2, 3),
        lac.transpose(1, 0, 2, 3),
    )
    from repro.models.layers import vary_tree
    vstep = lambda c, i: ((lambda st, y: (vary_tree(st, vary_axes), y))(*step(c, i)))
    if inner_remat:
        vstep = jax.checkpoint(vstep)
    state_out, y_inter = jax.lax.scan(vstep, vary_tree(state0, vary_axes), xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # [B,nc,q,nh,dh]
    return y_intra + y_inter, state_out


def mamba2_fwd(p, x, cfg, ctx: AxisCtx, state0=None, conv_carries=None):
    """x: [B,S,d] -> (y [B,S,d], (state, conv_carries))."""
    b, s, d = x.shape
    nh_l = p["A_log"].shape[0]
    dh, ds = cfg.mamba_headdim, cfg.ssm_state
    q = min(cfg.chunk_len, s)
    z = jax.nn.silu(L.matmul(x, p["w_z"]))
    xr = L.matmul(x, p["w_x"])
    br = L.matmul(x, p["w_B"])
    cr = L.matmul(x, p["w_C"])
    cc = conv_carries or {"x": None, "B": None, "C": None}
    xc, cx = _causal_conv(xr, p["conv_x"], cc["x"])
    bc, cb_ = _causal_conv(br, p["conv_B"], cc["B"])
    ccv, ccc = _causal_conv(cr, p["conv_C"], cc["C"])
    dt = jax.nn.softplus(
        L.matmul(x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )  # [B,S,nh]
    a = -jnp.exp(p["A_log"])  # [nh]
    la = dt * a  # log decay per step

    xc, pad = _pad_to(xc, q)
    bc, _ = _pad_to(bc, q)
    ccv, _ = _pad_to(ccv, q)
    la_p, _ = _pad_to(la, q)
    dt_p, _ = _pad_to(dt, q)
    sp = xc.shape[1]
    xh = _chunk(xc, q).reshape(b, sp // q, q, nh_l, dh).astype(jnp.float32)
    bt = _chunk(bc, q).astype(jnp.float32)
    ct = _chunk(ccv, q).astype(jnp.float32)
    lac = _chunk(la_p, q)
    dtc = _chunk(dt_p, q)
    if state0 is None:
        state0 = jnp.zeros((b, nh_l, dh, ds), jnp.float32)
    from repro.models.layers import all_axes
    y, state = _ssd_chunk_scan(xh, bt, ct, lac, dtc, state0,
                               vary_axes=all_axes(ctx),
                               inner_remat=ctx.inner_remat)
    y = y.reshape(b, sp, nh_l * dh)[:, :s]
    y = y + (xc.astype(jnp.float32).reshape(b, sp, nh_l, dh)
             * p["D"][None, None, :, None]).reshape(b, sp, -1)[:, :s]
    y = (y.astype(x.dtype)) * z
    y = L.rms_norm(y, p["norm"])
    out = ctx.psum_model(L.matmul(y, p["w_out"], jnp.float32)).astype(x.dtype)
    return out, (state, {"x": cx, "B": cb_, "C": ccc})


def mamba2_init_cache(cfg, batch: int, tp: int, dtype) -> dict:
    nh_l = cfg.mamba_heads // tp
    di_l = cfg.d_inner // tp
    k = cfg.conv_kernel
    return {
        "state": jnp.zeros((batch, nh_l, cfg.mamba_headdim, cfg.ssm_state), jnp.float32),
        "conv_x": jnp.zeros((batch, k - 1, di_l), dtype),
        "conv_B": jnp.zeros((batch, k - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, k - 1, cfg.ssm_state), dtype),
    }


def mamba2_decode(p, x, cache, cfg, ctx: AxisCtx):
    """Single-token state update. x: [B,1,d]."""
    carries = {"x": cache["conv_x"], "B": cache["conv_B"], "C": cache["conv_C"]}
    y, (state, cc) = mamba2_fwd(p, x, cfg, ctx, state0=cache["state"],
                                conv_carries=carries)
    return y, {"state": state, "conv_x": cc["x"], "conv_B": cc["B"],
               "conv_C": cc["C"]}


# ===========================================================================
# mLSTM (xLSTM's matrix-memory cell), chunkwise-parallel
# ===========================================================================


def init_mlstm(key, cfg, tp: int, dtype) -> dict:
    """cfg needs: d_model, d_inner, n_heads (mLSTM heads), conv_kernel."""
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = di // nh
    dv_l = dh // tp if dh % tp == 0 else dh  # shard v-dim; replicate if small
    ks = jax.random.split(key, 8)
    return {
        "w_up": L.dense_init(ks[0], (d, di), dtype=dtype),        # replicated
        "w_q": L.dense_init(ks[1], (di, nh * dh), dtype=dtype),   # replicated
        "w_k": L.dense_init(ks[2], (di, nh * dh), dtype=dtype),   # replicated
        "w_v": L.dense_init(ks[3], (di, nh * dv_l), dtype=dtype),  # sharded
        "w_i": L.dense_init(ks[4], (di, nh), dtype=jnp.float32),
        "w_f": L.dense_init(ks[5], (di, nh), dtype=jnp.float32),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),
        "norm": jnp.ones((nh * dv_l,), dtype),
        "w_gate": L.dense_init(ks[6], (d, nh * dv_l), dtype=dtype),  # sharded
        "w_down": L.dense_init(ks[7], (nh * dv_l, d), dtype=dtype),  # row
    }


def mlstm_tp_axes(cfg, tp: int) -> dict:
    dh = cfg.d_inner // cfg.n_heads
    sharded = dh % tp == 0 and tp > 1
    ax = 1 if sharded else None
    return {"w_up": None, "w_q": None, "w_k": None, "w_v": ax,
            "w_i": None, "w_f": None, "f_bias": None,
            "norm": 0 if sharded else None, "w_gate": ax,
            "w_down": 0 if sharded else None}


def _mlstm_sharded(cfg, tp):
    dh = cfg.d_inner // cfg.n_heads
    return dh % tp == 0 and tp > 1


def _mlstm_chunk_scan(qh, kh, vh, li, lf, carry, vary_axes=(),
                      inner_remat=False):
    """Stabilized chunkwise mLSTM.

    qh/kh: [B,nc,q,nh,dk]; vh: [B,nc,q,nh,dv]; li/lf: [B,nc,q,nh] (log
    input gate, log forget gate).  carry = (S [B,nh,dk,dv], n [B,nh,dk],
    m [B,nh]) with true values S*exp(m), n*exp(m).
    """
    b, nc, q, nh, dk = qh.shape
    mask = jnp.tril(jnp.ones((q, q), bool))
    F = jnp.cumsum(lf, axis=2)  # [B,nc,q,nh] cumulative log forget in chunk

    def step(c, inp):
        S, n, m = c
        qc, kc, vc, lic, Fc = inp  # [B,q,nh,dk] etc (chunk tensors)
        # log weights: intra (t,s): F_t - F_s + i_s ; carry: m + F_t
        logw = Fc[:, :, None, :] - Fc[:, None, :, :] + lic[:, None, :, :]
        logw = jnp.where(mask[None, :, :, None], logw, -jnp.inf)
        logw_c = m[:, None, :] + Fc  # [B,q,nh]
        m_t = jnp.maximum(jnp.max(logw, axis=2), logw_c)  # [B,q,nh]
        w = jnp.exp(logw - m_t[:, :, None, :])  # [B,t,s,nh]
        wc = jnp.exp(logw_c - m_t)  # [B,q,nh]
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) / math.sqrt(dk)
        h = jnp.einsum("btsh,btsh,bshv->bthv", scores, w, vc)
        h = h + wc[..., None] * jnp.einsum("bthd,bhdv->bthv", qc, S) / math.sqrt(dk)
        # normalizer: n_t = sum_s w[t,s] k_s + wc_t * n_carry
        nq = jnp.einsum("btsh,bshd->bthd", w, kc)
        nq = nq + wc[..., None] * n[:, None]
        denom = jnp.abs(jnp.einsum("bthd,bthd->bth", qc, nq)) / math.sqrt(dk)
        denom = jnp.maximum(denom, jnp.exp(-m_t))
        y = h / denom[..., None]
        # update carry to chunk end
        FQ = Fc[:, -1, :]  # [B,nh]
        m_new = jnp.maximum(m + FQ, jnp.max(lic + FQ[:, None] - Fc, axis=1))
        wS = jnp.exp(lic + FQ[:, None] - Fc - m_new[:, None])  # [B,q,nh]
        S_new = S * jnp.exp(m + FQ - m_new)[:, :, None, None] + jnp.einsum(
            "bsh,bshd,bshv->bhdv", wS, kc, vc)
        n_new = n * jnp.exp(m + FQ - m_new)[:, :, None] + jnp.einsum(
            "bsh,bshd->bhd", wS, kc)
        return (S_new, n_new, m_new), y

    xs = tuple(t.transpose(1, 0, *range(2, t.ndim)) for t in (qh, kh, vh, li, F))
    from repro.models.layers import vary_tree
    vstep = lambda c, i: ((lambda st, y: (vary_tree(st, vary_axes), y))(*step(c, i)))
    if inner_remat:
        vstep = jax.checkpoint(vstep)
    carry, ys = jax.lax.scan(vstep, vary_tree(carry, vary_axes), xs)
    return ys.transpose(1, 0, 2, 3, 4), carry


def mlstm_fwd(p, x, cfg, ctx: AxisCtx, carry=None):
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = cfg.d_inner // nh
    dv = p["w_v"].shape[1] // nh
    q = min(cfg.chunk_len, s)
    u = jax.nn.silu(L.matmul(x, p["w_up"]))
    qq = L.matmul(u, p["w_q"]).reshape(b, s, nh, dh)
    kk = L.matmul(u, p["w_k"]).reshape(b, s, nh, dh)
    vv = L.matmul(u, p["w_v"]).reshape(b, s, nh, dv)
    li = L.matmul(u, p["w_i"], jnp.float32)  # log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(
        L.matmul(u, p["w_f"], jnp.float32) + p["f_bias"]
    )  # log forget gate
    qq, pad = _pad_to(qq, q)
    kk, _ = _pad_to(kk, q)
    vv, _ = _pad_to(vv, q)
    li, _ = _pad_to(li, q)
    lf, _ = _pad_to(lf, q)
    sp = qq.shape[1]
    ch = lambda t: _chunk(t.astype(jnp.float32), q)
    if carry is None:
        carry = (jnp.zeros((b, nh, dh, dv), jnp.float32),
                 jnp.zeros((b, nh, dh), jnp.float32),
                 jnp.full((b, nh), -1e30, jnp.float32))
    from repro.models.layers import all_axes
    y, carry = _mlstm_chunk_scan(ch(qq), ch(kk), ch(vv), ch(li), ch(lf), carry,
                                 vary_axes=all_axes(ctx),
                                 inner_remat=ctx.inner_remat)
    y = y.reshape(b, sp, nh * dv)[:, :s].astype(x.dtype)
    y = L.rms_norm(y, p["norm"])
    y = y * jax.nn.silu(L.matmul(x, p["w_gate"]))
    out = L.matmul(y, p["w_down"], jnp.float32)
    if _mlstm_sharded(cfg, ctx.tp):
        out = ctx.psum_model(out)
    return out.astype(x.dtype), carry


def mlstm_init_cache(cfg, batch: int, tp: int) -> tuple:
    nh = cfg.n_heads
    dh = cfg.d_inner // nh
    dv = dh // tp if (dh % tp == 0 and tp > 1) else dh
    return (jnp.zeros((batch, nh, dh, dv), jnp.float32),
            jnp.zeros((batch, nh, dh), jnp.float32),
            jnp.full((batch, nh), -1e30, jnp.float32))


def mlstm_decode(p, x, carry, cfg, ctx: AxisCtx):
    y, carry = mlstm_fwd(p, x, cfg, ctx, carry=carry)
    return y, carry


# ===========================================================================
# sLSTM (scalar-memory cell with recurrent coupling) — strictly sequential
# ===========================================================================


def init_slstm(key, cfg, tp: int, dtype) -> dict:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = di // nh
    ks = jax.random.split(key, 4)
    return {
        # input projections for (z, i, f, o)
        "w_in": L.dense_init(ks[0], (d, 4 * di), dtype=dtype),
        # block-diagonal recurrent weights per head: [nh, dh, 4*dh]
        "r": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) / math.sqrt(dh)).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * di,)), jnp.full((di,), 2.0),
                              jnp.zeros((di,))]).astype(jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_down": L.dense_init(ks[2], (di, d), dtype=dtype),
        # small post-FFN (xLSTM sLSTM block includes an MLP)
        "w_ff_up": L.dense_init(ks[3], (d, int(d * 4 / 3) // 8 * 8), dtype=dtype),
        "w_ff_down": L.dense_init(ks[3], (int(d * 4 / 3) // 8 * 8, d), dtype=dtype),
    }


def slstm_tp_axes() -> dict:
    return {k: None for k in
            ("w_in", "r", "b", "norm", "w_down", "w_ff_up", "w_ff_down")}


def slstm_fwd(p, x, cfg, ctx: AxisCtx, state=None):
    """Sequential scan over time. x: [B,S,d]. sLSTM is replicated over TP."""
    b, s, d = x.shape
    nh = cfg.n_heads
    di = cfg.d_inner
    dh = di // nh
    pre = L.matmul(x, p["w_in"], jnp.float32) + p["b"]  # [B,S,4*di]
    pre = pre.reshape(b, s, 4, nh, dh)
    if state is None:
        state = slstm_init_state(b, nh, dh)

    r = p["r"].astype(jnp.float32)

    def step(st, pre_t):  # pre_t: [B,4,nh,dh]
        c, n, h, m = st
        rec = jnp.einsum("bhd,hdf->bhf", h, r).reshape(b, nh, 4, dh)
        rec = rec.transpose(0, 2, 1, 3)  # [B,4,nh,dh]
        zt, it, ft, ot = [pre_t[:, j] + rec[:, j] for j in range(4)]
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    from repro.models.layers import all_axes, vary_tree
    va = all_axes(ctx)
    vstep = lambda c, i: ((lambda st, y: (vary_tree(st, va), vary_tree(y, va)))(*step(c, i)))
    if ctx.inner_remat:
        vstep = jax.checkpoint(vstep)
    (state), hs = jax.lax.scan(vstep, vary_tree(state, va),
                               pre.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, di).astype(x.dtype)
    y = L.rms_norm(hs, p["norm"])
    out = L.matmul(y, p["w_down"], jnp.float32).astype(x.dtype)
    x = x + out
    # post-FFN
    h2 = jax.nn.gelu(L.matmul(x, p["w_ff_up"]))
    x = x + L.matmul(h2, p["w_ff_down"], jnp.float32).astype(x.dtype)
    return x, state


def slstm_init_state(batch: int, nh: int, dh: int):
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return (z, z, z, jnp.full((batch, nh, dh), -1e30, jnp.float32))


def slstm_decode(p, x, state, cfg, ctx: AxisCtx):
    y, state = slstm_fwd(p, x, cfg, ctx, state=state)
    return y, state
