"""Whisper-large-v3 transformer backbone (arXiv:2212.04356).

Encoder-decoder.  The mel-spectrogram + conv frontend is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
``[B, frames, frontend_dim]``; the stem projects them to d_model and adds
learned positions.  Encoder layers are bidirectional self-attention;
decoder layers are causal self-attention + cross-attention over the
encoder output.  LayerNorm + GELU as in Whisper; decoder positions use
RoPE here instead of Whisper's learned embeddings (noted in DESIGN.md
§Hardware-adaptation #6).

Decode shapes: self-attention KV cache of ``seq_len`` plus a fixed
cross-attention cache over the encoder frames.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import EncDecConfig, dtype_of
from repro.models import layers as L
from repro.models.api import BlockGroup, Model, masked_mean_loss
from repro.models.layers import AxisCtx


def _ln(p, name, x):
    return L.layer_norm(x, p[name], p[name + "_b"])


def _ln_params(d, dtype):
    return jnp.ones((d,), dtype), jnp.zeros((d,), dtype)


def init_cross_attention(key, cfg, tp, dtype):
    """Same weights as self-attention (kv from encoder states)."""
    return L.init_attention(key, cfg, tp, dtype)


def cross_attention_fwd(p, x, enc_kv, cfg, ctx: AxisCtx):
    """x: [B,Sq,d] queries; enc_kv: precomputed {"k","v"} [B,F,KV,hd]."""
    b, sq, _ = x.shape
    hd = cfg.head_dim
    h_l, kv_l, _ = L.gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd, ctx.tp)
    q = L.matmul(x, p["wq"]).reshape(b, sq, h_l, hd)
    out = L.attention_core(q, enc_kv["k"], enc_kv["v"], ctx, causal=False)
    y = L.matmul(out.reshape(b, sq, -1), p["wo"], jnp.float32)
    if not L.gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd, ctx.tp)[2]:
        y = ctx.psum_model(y)
    return y.astype(x.dtype)


def cross_kv(p, enc_out, cfg, ctx: AxisCtx):
    b, f, _ = enc_out.shape
    hd = cfg.head_dim
    _, kv_l, _ = L.gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd, ctx.tp)
    k = L.matmul(enc_out, p["wk"]).reshape(b, f, kv_l, hd)
    v = L.matmul(enc_out, p["wv"]).reshape(b, f, kv_l, hd)
    return {"k": k, "v": v}


class WhisperBackbone(Model):
    cfg: EncDecConfig

    def __init__(self, cfg: EncDecConfig, ctx: AxisCtx):
        super().__init__(cfg, ctx)
        self.dtype = dtype_of(cfg.param_dtype)

    # ------------------------------------------------------------------ stem
    def init_stem(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        w, b = _ln_params(cfg.d_model, self.dtype)
        w2, b2 = _ln_params(cfg.d_model, self.dtype)
        return {
            "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                      self.ctx.tp, self.dtype),
            # stub frontend projection: frame embeddings -> d_model
            "frontend_proj": L.dense_init(ks[1], (cfg.frontend_dim, cfg.d_model),
                                          dtype=self.dtype),
            "enc_pos": (jax.random.normal(ks[2], (cfg.encoder_frames, cfg.d_model))
                        * 0.01).astype(self.dtype),
            "enc_norm": w, "enc_norm_b": b,
            "final_norm": w2, "final_norm_b": b2,
        }

    # ---------------------------------------------------------------- layers
    def _init_enc_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        na, nab = _ln_params(cfg.d_model, self.dtype)
        nm, nmb = _ln_params(cfg.d_model, self.dtype)
        return {"attn": L.init_attention(k1, cfg, self.ctx.tp, self.dtype),
                "mlp": L.init_mlp(k2, cfg, self.ctx.tp, self.dtype),
                "norm_attn": na, "norm_attn_b": nab,
                "norm_mlp": nm, "norm_mlp_b": nmb}

    def _enc_apply(self, p, x, extras, ctx):
        cfg = self.cfg
        h = _ln(p, "norm_attn", x)
        x = x + L.attention_fwd(p["attn"], h, cfg, ctx, causal=False)
        h = _ln(p, "norm_mlp", x)
        x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
        return x, 0.0

    def _init_dec_layer(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = self._init_enc_layer(jax.random.fold_in(key, 7))
        nc, ncb = _ln_params(cfg.d_model, self.dtype)
        p["cross"] = init_cross_attention(k3, cfg, self.ctx.tp, self.dtype)
        p["norm_cross"], p["norm_cross_b"] = nc, ncb
        return p

    def _dec_apply(self, p, x, extras, ctx):
        cfg = self.cfg
        h = _ln(p, "norm_attn", x)
        x = x + L.attention_fwd(p["attn"], h, cfg, ctx, causal=True)
        h = _ln(p, "norm_cross", x)
        enc_kv = cross_kv(p["cross"], extras["enc_out"], cfg, ctx)
        x = x + cross_attention_fwd(p["cross"], h, enc_kv, cfg, ctx)
        h = _ln(p, "norm_mlp", x)
        x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
        return x, 0.0

    def _dec_prefill(self, p, x, extras, ctx):
        cfg = self.cfg
        h = _ln(p, "norm_attn", x)
        a, cache = L.attention_prefill(p["attn"], h, cfg, ctx)
        x = x + a
        h = _ln(p, "norm_cross", x)
        enc_kv = cross_kv(p["cross"], extras["enc_out"], cfg, ctx)
        x = x + cross_attention_fwd(p["cross"], h, enc_kv, cfg, ctx)
        h = _ln(p, "norm_mlp", x)
        x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
        return x, {"self": cache, "cross": enc_kv}

    def _dec_decode(self, p, x, cache, pos, extras, ctx):
        cfg = self.cfg
        h = _ln(p, "norm_attn", x)
        a, self_cache = L.attention_decode(p["attn"], h, cache["self"], pos, cfg, ctx)
        x = x + a
        h = _ln(p, "norm_cross", x)
        x = x + cross_attention_fwd(p["cross"], h, cache["cross"], cfg, ctx)
        h = _ln(p, "norm_mlp", x)
        x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
        return x, {"self": self_cache, "cross": cache["cross"]}

    def _dec_init_cache(self, batch, max_len):
        cfg = self.cfg
        cdtype = dtype_of(cfg.compute_dtype)
        _, kv_l, _ = L.gqa_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, self.ctx.tp)
        z = jnp.zeros((batch, cfg.encoder_frames, kv_l, cfg.head_dim), cdtype)
        return {
            "self": L.attention_init_cache(cfg, batch, max_len, self.ctx.tp, cdtype),
            "cross": {"k": z, "v": z},
        }

    def groups(self) -> list[BlockGroup]:
        cfg = self.cfg
        return [
            BlockGroup(name="encoder", length=cfg.num_encoder_layers,
                       init_layer=self._init_enc_layer, apply=self._enc_apply),
            BlockGroup(name="decoder", length=cfg.num_layers,
                       init_layer=self._init_dec_layer, apply=self._dec_apply,
                       init_cache=self._dec_init_cache,
                       prefill=self._dec_prefill, decode=self._dec_decode),
        ]

    # --------------------------------------------------------------- forward
    def embed(self, stem, batch) -> tuple[jax.Array, Any]:
        cfg = self.cfg
        cdtype = dtype_of(cfg.compute_dtype)
        frames = batch["frames"].astype(cdtype)  # [B,F,frontend_dim] (stub)
        x = L.matmul(frames, stem["frontend_proj"])
        x = x + stem["enc_pos"][None, : x.shape[1]].astype(cdtype)
        return x.astype(cdtype), {"tokens": batch["tokens"]}

    def between_groups(self, name, x, extras, stem, batch):
        if name == "decoder":
            # encoder finished: x is enc_out; switch the stream to tokens
            enc_out = _ln({"n": stem["enc_norm"], "n_b": stem["enc_norm_b"]}, "n", x)
            ids = batch["tokens"]
            cdtype = dtype_of(self.cfg.compute_dtype)
            tok = L.embed_lookup(stem["embed"], ids, self.cfg.vocab_size, self.ctx)
            pos = jnp.arange(ids.shape[1])
            tok = tok.astype(cdtype)
            return tok, {"enc_out": enc_out}
        return x, extras

    def head_loss(self, stem, x, batch) -> jax.Array:
        x = _ln({"n": stem["final_norm"], "n_b": stem["final_norm_b"]}, "n", x)
        logits = L.lm_logits_local(stem["embed"], x, self.ctx)
        per_tok = L.vocab_parallel_xent(logits, batch["labels"],
                                        self.cfg.vocab_size, self.ctx,
                                        mask=batch.get("mask"))
        return masked_mean_loss(per_tok, None, batch["global_tokens"])

    # --------------------------------------------------------------- serving
    def embed_decode(self, stem, token, pos, extras):
        cdtype = dtype_of(self.cfg.compute_dtype)
        x = L.embed_lookup(stem["embed"], token, self.cfg.vocab_size, self.ctx)
        return x.astype(cdtype)

    def head_logits(self, stem, x) -> jax.Array:
        x = _ln({"n": stem["final_norm"], "n_b": stem["final_norm_b"]}, "n", x)
        return L.lm_logits_local(stem["embed"], x, self.ctx)


def _whisper_tp_axes(self) -> dict:
    cfg = self.cfg
    tp = self.ctx.tp
    enc = {"attn": L.attention_tp_axes(cfg, tp), "mlp": L.mlp_tp_axes(cfg),
           "norm_attn": None, "norm_attn_b": None,
           "norm_mlp": None, "norm_mlp_b": None}
    dec = dict(enc)
    dec["cross"] = L.attention_tp_axes(cfg, tp)
    dec["norm_cross"] = None
    dec["norm_cross_b"] = None
    stem = {"embed": {"table": 0}, "frontend_proj": None, "enc_pos": None,
            "enc_norm": None, "enc_norm_b": None,
            "final_norm": None, "final_norm_b": None}
    return {"stem": stem, "groups": {"encoder": enc, "decoder": dec}}


WhisperBackbone.tp_axes = _whisper_tp_axes
