"""MoE language models: mixtral-8x7b and deepseek-v2-lite (MLA + MoE)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, dtype_of
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.api import BlockGroup
from repro.models.layers import AxisCtx
from repro.models.transformer import (
    TransformerLM,
    decoder_layer_fwd,
    decoder_layer_prefill,
    decoder_layer_decode,
    init_decoder_layer,
)


def init_moe_layer(key, cfg: MoEConfig, tp: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    if cfg.use_mla:
        attn = MLA.init_mla(k1, cfg, tp, dtype)
    else:
        attn = L.init_attention(k1, cfg, tp, dtype)
    return {
        "attn": attn,
        "moe": MOE.init_moe_mlp(k2, cfg, tp, dtype),
        "norm_attn": jnp.ones((cfg.d_model,), dtype),
        "norm_mlp": jnp.ones((cfg.d_model,), dtype),
    }


def moe_layer_fwd(p, x, cfg: MoEConfig, ctx: AxisCtx):
    h = L.rms_norm(x, p["norm_attn"])
    if cfg.use_mla:
        x = x + MLA.mla_fwd(p["attn"], h, cfg, ctx)
    else:
        x = x + L.attention_fwd(p["attn"], h, cfg, ctx)
    h = L.rms_norm(x, p["norm_mlp"])
    y, aux = MOE.moe_fwd(p["moe"], h, cfg, ctx)
    return x + y, aux


def moe_layer_prefill(p, x, cfg: MoEConfig, ctx: AxisCtx):
    h = L.rms_norm(x, p["norm_attn"])
    if cfg.use_mla:
        a, cache = MLA.mla_prefill(p["attn"], h, cfg, ctx)
    else:
        a, cache = L.attention_prefill(p["attn"], h, cfg, ctx)
    x = x + a
    h = L.rms_norm(x, p["norm_mlp"])
    y, _ = MOE.moe_fwd(p["moe"], h, cfg, ctx)
    return x + y, cache


def moe_layer_decode(p, x, cache, pos, cfg: MoEConfig, ctx: AxisCtx):
    h = L.rms_norm(x, p["norm_attn"])
    if cfg.use_mla:
        a, cache = MLA.mla_decode(p["attn"], h, cache, pos, cfg, ctx)
    else:
        a, cache = L.attention_decode(p["attn"], h, cache, pos, cfg, ctx)
    x = x + a
    h = L.rms_norm(x, p["norm_mlp"])
    y, _ = MOE.moe_fwd(p["moe"], h, cfg, ctx)
    return x + y, cache


class MoELM(TransformerLM):
    """Decoder-only MoE LM; optional MLA attention; optional leading dense
    layers (deepseek-v2 style)."""

    cfg: MoEConfig

    def _moe_layer_init(self, key):
        return init_moe_layer(key, self.cfg, self.ctx.tp, self.dtype)

    def _moe_init_cache(self, batch, max_len):
        cdtype = dtype_of(self.cfg.compute_dtype)
        if self.cfg.use_mla:
            return MLA.mla_init_cache(self.cfg, batch, max_len, cdtype,
                                      tp=self.ctx.tp)
        return L.attention_init_cache(self.cfg, batch, max_len, self.ctx.tp, cdtype)

    def groups(self) -> list[BlockGroup]:
        cfg = self.cfg
        out = []
        if cfg.first_dense_layers > 0:
            out.append(BlockGroup(
                name="dense_layers",
                length=cfg.first_dense_layers,
                init_layer=lambda k: init_decoder_layer(k, cfg, self.ctx.tp, self.dtype),
                apply=lambda p, x, e, ctx: (decoder_layer_fwd(p, x, cfg, ctx), 0.0),
                init_cache=lambda b, m: L.attention_init_cache(
                    cfg, b, m, self.ctx.tp, dtype_of(cfg.compute_dtype)),
                prefill=lambda p, x, e, ctx: decoder_layer_prefill(p, x, cfg, ctx),
                decode=lambda p, x, c, pos, e, ctx: decoder_layer_decode(
                    p, x, c, pos, cfg, ctx),
            ))
        out.append(BlockGroup(
            name="moe_layers",
            length=cfg.num_layers - cfg.first_dense_layers,
            init_layer=self._moe_layer_init,
            apply=lambda p, x, e, ctx: moe_layer_fwd(p, x, cfg, ctx),
            init_cache=self._moe_init_cache,
            prefill=lambda p, x, e, ctx: moe_layer_prefill(p, x, cfg, ctx),
            decode=lambda p, x, c, pos, e, ctx: moe_layer_decode(
                p, x, c, pos, cfg, ctx),
        ))
        return out


def moe_layer_tp_axes(cfg: MoEConfig, tp: int) -> dict:
    attn = MLA.mla_tp_axes() if cfg.use_mla else L.attention_tp_axes(cfg, tp)
    return {"attn": attn, "moe": MOE.moe_tp_axes(cfg),
            "norm_attn": None, "norm_mlp": None}


def _moelm_tp_axes(self) -> dict:
    from repro.models.transformer import _stem_tp_axes, decoder_layer_tp_axes
    cfg = self.cfg
    groups = {}
    if cfg.first_dense_layers > 0:
        groups["dense_layers"] = decoder_layer_tp_axes(cfg, self.ctx.tp)
    groups["moe_layers"] = moe_layer_tp_axes(cfg, self.ctx.tp)
    return {"stem": _stem_tp_axes(cfg), "groups": groups}


MoELM.tp_axes = _moelm_tp_axes
