"""xLSTM language model (arXiv:2405.04517): mLSTM blocks with interleaved
sLSTM blocks at ratio ``mlstm_per_unit : slstm_per_unit`` (xLSTM[7:1] for
the 1.3B config).

The layer stack is scanned over *units*; each unit's params hold a
stacked ``[mlstm_per_unit, ...]`` mLSTM subtree (inner scan) plus one
sLSTM subtree, so all units share one chunk layout.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig, dtype_of
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.api import BlockGroup
from repro.models.layers import AxisCtx, all_axes, vary_tree
from repro.models.transformer import TransformerLM


def _mlstm_block(p, x, cfg, ctx, carry=None):
    h = L.rms_norm(x, p["norm"])
    y, carry = S.mlstm_fwd(p["cell"], h, cfg, ctx, carry=carry)
    return x + y, carry


def _slstm_block(p, x, cfg, ctx, state=None):
    h = L.rms_norm(x, p["norm"])
    y, state = S.slstm_fwd(p["cell"], h, cfg, ctx, state=state)
    return x + (y - h), state  # slstm_fwd includes its own residual+ffn


class XLSTMLM(TransformerLM):
    cfg: XLSTMConfig

    # ------------------------------------------------------------------ unit
    def _init_unit(self, key):
        cfg = self.cfg
        km, ks = jax.random.split(key)
        mk = jax.random.split(km, cfg.mlstm_per_unit)

        def one_mlstm(k):
            return {"norm": jnp.ones((cfg.d_model,), self.dtype),
                    "cell": S.init_mlstm(k, cfg, self.ctx.tp, self.dtype)}

        unit = {"mlstm": jax.vmap(one_mlstm)(mk)}
        if cfg.slstm_per_unit:
            unit["slstm"] = {"norm": jnp.ones((cfg.d_model,), self.dtype),
                             "cell": S.init_slstm(ks, cfg, self.ctx.tp, self.dtype)}
        return unit

    def _unit_apply(self, p, x, extras, ctx):
        cfg = self.cfg

        va = all_axes(ctx)

        def body(carry_x, mparams):
            y, _ = _mlstm_block(mparams, carry_x, cfg, ctx)
            return vary_tree(y, va), None

        x, _ = jax.lax.scan(body, vary_tree(x, va), p["mlstm"])
        if cfg.slstm_per_unit:
            x, _ = _slstm_block(p["slstm"], x, cfg, ctx)
        return x, 0.0

    # --------------------------------------------------------------- serving
    def _unit_init_cache(self, batch, max_len):
        cfg = self.cfg
        m = S.mlstm_init_cache(cfg, batch, self.ctx.tp)
        m = jax.tree.map(lambda t: jnp.broadcast_to(
            t[None], (cfg.mlstm_per_unit,) + t.shape), m)
        cache = {"mlstm": m}
        if cfg.slstm_per_unit:
            cache["slstm"] = S.slstm_init_state(
                batch, cfg.n_heads, cfg.d_inner // cfg.n_heads)
        return cache

    def _unit_prefill(self, p, x, extras, ctx):
        cfg = self.cfg

        va = all_axes(ctx)

        def body(carry_x, inp):
            mparams, mcache0 = inp
            h = L.rms_norm(carry_x, mparams["norm"])
            y, carry = S.mlstm_fwd(mparams["cell"], h, cfg, ctx, carry=None)
            return vary_tree(carry_x + y, va), vary_tree(carry, va)

        x, mcaches = jax.lax.scan(
            body, vary_tree(x, va), (p["mlstm"], self._dummy_mcache_stack()))
        cache = {"mlstm": mcaches}
        if cfg.slstm_per_unit:
            h = L.rms_norm(x, p["slstm"]["norm"])
            y, st = S.slstm_fwd(p["slstm"]["cell"], h, cfg, ctx)
            x = x + (y - h)
            cache["slstm"] = st
        return x, cache

    def _dummy_mcache_stack(self):
        # scan xs placeholder so ys carries get stacked per inner layer
        cfg = self.cfg
        return jnp.zeros((cfg.mlstm_per_unit,), jnp.int32)

    def _unit_decode(self, p, x, cache, pos, extras, ctx):
        cfg = self.cfg

        va = all_axes(ctx)

        def body(carry_x, inp):
            mparams, mcache = inp
            h = L.rms_norm(carry_x, mparams["norm"])
            y, carry = S.mlstm_fwd(mparams["cell"], h, cfg, ctx, carry=mcache)
            return vary_tree(carry_x + y, va), vary_tree(carry, va)

        x, mcaches = jax.lax.scan(body, vary_tree(x, va), (p["mlstm"], cache["mlstm"]))
        new_cache = {"mlstm": mcaches}
        if cfg.slstm_per_unit:
            h = L.rms_norm(x, p["slstm"]["norm"])
            y, st = S.slstm_fwd(p["slstm"]["cell"], h, cfg, ctx,
                                state=cache["slstm"])
            x = x + (y - h)
            new_cache["slstm"] = st
        return x, new_cache

    def groups(self) -> list[BlockGroup]:
        return [BlockGroup(
            name="units",
            length=self.cfg.num_units,
            init_layer=self._init_unit,
            apply=self._unit_apply,
            init_cache=self._unit_init_cache,
            prefill=self._unit_prefill,
            decode=self._unit_decode,
        )]


def _xlstm_tp_axes(self) -> dict:
    from repro.models.transformer import _stem_tp_axes
    cfg = self.cfg
    m_axes = {"norm": None, "cell": S.mlstm_tp_axes(cfg, self.ctx.tp)}
    unit = {"mlstm": m_axes}
    if cfg.slstm_per_unit:
        unit["slstm"] = {"norm": None, "cell": S.slstm_tp_axes()}
    return {"stem": _stem_tp_axes(cfg), "groups": {"units": unit}}


XLSTMLM.tp_axes = _xlstm_tp_axes
