"""Dense decoder-only transformer LM (llama/qwen/nemotron families).

Covers the assigned dense archs: qwen3-0.6b (qk_norm), deepseek-7b
(llama-arch), qwen2.5-3b (QKV bias), nemotron-4-340b (squared-ReLU,
un-gated MLP), mixtral's dense skeleton (the MoE subclass swaps the MLP),
and the phi-3-vision language backbone (VLM subclass prepends patch
embeds).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BaseConfig, dtype_of
from repro.models import layers as L
from repro.models.api import BlockGroup, Model, masked_mean_loss
from repro.models.layers import AxisCtx


def init_decoder_layer(key, cfg, tp: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "attn": L.init_attention(k1, cfg, tp, dtype),
        "mlp": L.init_mlp(k2, cfg, tp, dtype),
    }
    if cfg.norm == "rms":
        p["norm_attn"] = jnp.ones((cfg.d_model,), dtype)
        p["norm_mlp"] = jnp.ones((cfg.d_model,), dtype)
    else:
        p["norm_attn"] = jnp.ones((cfg.d_model,), dtype)
        p["norm_attn_b"] = jnp.zeros((cfg.d_model,), dtype)
        p["norm_mlp"] = jnp.ones((cfg.d_model,), dtype)
        p["norm_mlp_b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _norm(p, prefix, x, cfg):
    if cfg.norm == "rms":
        return L.rms_norm(x, p[prefix])
    return L.layer_norm(x, p[prefix], p[prefix + "_b"])


def decoder_layer_fwd(p, x, cfg, ctx: AxisCtx, *, positions=None):
    h = _norm(p, "norm_attn", x, cfg)
    x = x + L.attention_fwd(p["attn"], h, cfg, ctx, positions=positions)
    h = _norm(p, "norm_mlp", x, cfg)
    x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
    return x


def decoder_layer_prefill(p, x, cfg, ctx: AxisCtx):
    h = _norm(p, "norm_attn", x, cfg)
    a, cache = L.attention_prefill(p["attn"], h, cfg, ctx)
    x = x + a
    h = _norm(p, "norm_mlp", x, cfg)
    x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
    return x, cache


def decoder_layer_decode(p, x, cache, pos, cfg, ctx: AxisCtx):
    h = _norm(p, "norm_attn", x, cfg)
    a, cache = L.attention_decode(p["attn"], h, cache, pos, cfg, ctx)
    x = x + a
    h = _norm(p, "norm_mlp", x, cfg)
    x = x + L.mlp_fwd(p["mlp"], h, cfg, ctx)
    return x, cache


class TransformerLM(Model):
    """Dense decoder-only LM implementing the Model protocol."""

    def __init__(self, cfg: BaseConfig, ctx: AxisCtx):
        super().__init__(cfg, ctx)
        self.dtype = dtype_of(cfg.param_dtype)

    # ------------------------------------------------------------------ stem
    def init_stem(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        stem = {"embed": L.init_embedding(k1, cfg.vocab_size, cfg.d_model,
                                          self.ctx.tp, self.dtype),
                "final_norm": jnp.ones((cfg.d_model,), self.dtype)}
        if cfg.norm == "ln":
            stem["final_norm_b"] = jnp.zeros((cfg.d_model,), self.dtype)
        if not cfg.tie_embeddings:
            stem["unembed"] = L.init_embedding(k2, cfg.vocab_size, cfg.d_model,
                                               self.ctx.tp, self.dtype)
        return stem

    # ---------------------------------------------------------------- groups
    def _layer_init(self, key):
        return init_decoder_layer(key, self.cfg, self.ctx.tp, self.dtype)

    def _layer_apply(self, p, x, extras, ctx):
        # apply returns (x, aux-loss); dense layers have no aux loss
        return decoder_layer_fwd(p, x, self.cfg, ctx), 0.0

    def _layer_prefill(self, p, x, extras, ctx):
        return decoder_layer_prefill(p, x, self.cfg, ctx)

    def _layer_decode(self, p, x, cache, pos, extras, ctx):
        return decoder_layer_decode(p, x, cache, pos, self.cfg, ctx)

    def _layer_init_cache(self, batch, max_len):
        return L.attention_init_cache(self.cfg, batch, max_len, self.ctx.tp,
                                      dtype_of(self.cfg.compute_dtype))

    def groups(self) -> list[BlockGroup]:
        return [BlockGroup(
            name="layers",
            length=self.cfg.num_layers,
            init_layer=self._layer_init,
            apply=self._layer_apply,
            init_cache=self._layer_init_cache,
            prefill=self._layer_prefill,
            decode=self._layer_decode,
        )]

    # --------------------------------------------------------------- forward
    def embed(self, stem, batch) -> tuple[jax.Array, Any]:
        ids = batch["tokens"]
        x = L.embed_lookup(stem["embed"], ids, self.cfg.vocab_size, self.ctx)
        return x.astype(dtype_of(self.cfg.compute_dtype)), None

    def head_loss(self, stem, x, batch) -> jax.Array:
        cfg = self.cfg
        x = self._final_norm(stem, x)
        table = stem["embed"] if cfg.tie_embeddings else stem["unembed"]
        blk = getattr(self.ctx, "xent_block", 0)
        if blk and x.shape[1] > blk:
            tot = L.blockwise_xent_sum(table, x, batch["labels"],
                                       cfg.vocab_size, self.ctx, blk,
                                       mask=batch.get("mask"))
            return tot / batch["global_tokens"]
        logits = L.lm_logits_local(table, x, self.ctx)
        per_tok = L.vocab_parallel_xent(logits, batch["labels"], cfg.vocab_size,
                                        self.ctx, mask=batch.get("mask"))
        return masked_mean_loss(per_tok, None, batch["global_tokens"])

    def _final_norm(self, stem, x):
        if self.cfg.norm == "rms":
            return L.rms_norm(x, stem["final_norm"])
        return L.layer_norm(x, stem["final_norm"], stem["final_norm_b"])

    # --------------------------------------------------------------- serving
    def embed_decode(self, stem, token, pos, extras) -> jax.Array:
        x = L.embed_lookup(stem["embed"], token, self.cfg.vocab_size, self.ctx)
        return x.astype(dtype_of(self.cfg.compute_dtype))

    def head_logits(self, stem, x) -> jax.Array:
        x = self._final_norm(stem, x)
        table = stem["embed"] if self.cfg.tie_embeddings else stem["unembed"]
        return L.lm_logits_local(table, x, self.ctx)


def decoder_layer_tp_axes(cfg, tp: int) -> dict:
    axes = {"attn": L.attention_tp_axes(cfg, tp), "mlp": L.mlp_tp_axes(cfg),
            "norm_attn": None, "norm_mlp": None}
    if cfg.norm != "rms":
        axes["norm_attn_b"] = None
        axes["norm_mlp_b"] = None
    return axes


def _stem_tp_axes(cfg) -> dict:
    axes = {"embed": {"table": 0}, "final_norm": None}
    if cfg.norm == "ln":
        axes["final_norm_b"] = None
    if not cfg.tie_embeddings:
        axes["unembed"] = {"table": 0}
    return axes


class _TransformerTPAxes:
    pass


def transformer_tp_axes(self) -> dict:
    return {"stem": _stem_tp_axes(self.cfg),
            "groups": {"layers": decoder_layer_tp_axes(self.cfg, self.ctx.tp)}}


TransformerLM.tp_axes = transformer_tp_axes
