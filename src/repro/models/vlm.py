"""Phi-3-vision backbone: phi-3-mini language decoder consuming stub
patch embeddings (the ViT/CLIP encoder is a stub per the assignment —
``input_specs`` supplies ``[B, num_patches, vision_dim]`` precomputed
patch embeddings; the stem projects them into d_model and prepends them
to the token stream).  Loss is computed on text positions only.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import VLMConfig, dtype_of
from repro.models import layers as L
from repro.models.api import masked_mean_loss
from repro.models.transformer import TransformerLM


class VLMBackbone(TransformerLM):
    cfg: VLMConfig

    def init_stem(self, key) -> dict:
        k1, k2 = jax.random.split(key)
        stem = super().init_stem(k1)
        cfg = self.cfg
        stem["projector"] = {
            "w1": L.dense_init(k2, (cfg.vision_dim, cfg.d_model), dtype=self.dtype),
            "w2": L.dense_init(jax.random.fold_in(k2, 1),
                               (cfg.d_model, cfg.d_model), dtype=self.dtype),
        }
        return stem

    def embed(self, stem, batch) -> tuple[jax.Array, Any]:
        cfg = self.cfg
        cdtype = dtype_of(cfg.compute_dtype)
        patches = batch["patch_embeds"].astype(cdtype)  # [B,P,vision_dim] stub
        vis = L.matmul(patches, stem["projector"]["w1"])
        vis = L.matmul(jax.nn.gelu(vis), stem["projector"]["w2"])
        tok = L.embed_lookup(stem["embed"], batch["tokens"], cfg.vocab_size,
                             self.ctx).astype(cdtype)
        x = jnp.concatenate([vis.astype(cdtype), tok], axis=1)
        return x, None

    def head_loss(self, stem, x, batch) -> jax.Array:
        cfg = self.cfg
        p = batch["patch_embeds"].shape[1]
        x = x[:, p:]  # loss on text positions only
        x = self._final_norm(stem, x)
        table = stem["embed"] if cfg.tie_embeddings else stem["unembed"]
        logits = L.lm_logits_local(table, x, self.ctx)
        per_tok = L.vocab_parallel_xent(logits, batch["labels"], cfg.vocab_size,
                                        self.ctx, mask=batch.get("mask"))
        return masked_mean_loss(per_tok, None, batch["global_tokens"])


def _vlm_tp_axes(self) -> dict:
    from repro.models.transformer import _stem_tp_axes, decoder_layer_tp_axes
    stem = _stem_tp_axes(self.cfg)
    stem["projector"] = {"w1": None, "w2": None}
    return {"stem": stem,
            "groups": {"layers": decoder_layer_tp_axes(self.cfg, self.ctx.tp)}}


VLMBackbone.tp_axes = _vlm_tp_axes
