"""Mixture-of-Experts layers (mixtral-8x7b, deepseek-v2-lite).

Routing is capacity-based token dropping (GShard-style) but implemented
with index gather/scatter instead of the O(T*E*C) one-hot einsum:
per-expert slot indices are computed from a cumulative-count, tokens are
gathered into an ``[E, C, d]`` dispatch buffer, expert FFNs run batched
over E, and outputs are gathered back per (token, k) and combined with
router probabilities.  Gradients flow through the gathers (transpose =
scatter-add), so no custom VJP is required.

Parallel layouts (cfg.moe_impl):

  "tp"  expert FFN width sharded over the model axis (every rank holds a
        1/tp slice of every expert).  Token->expert assignment is
        replicated across model ranks (activations are TP-replicated), so
        no all-to-all is needed and per-rank compute is exactly balanced.
  "ep"  experts sharded over the model axis (E/tp experts per rank, full
        width).  Each rank computes only its local experts' slots and the
        combine psums partial outputs over the model axis.  Requires
        E % tp == 0.  This is the expert-parallel layout whose collective
        profile (bigger psum payloads vs "tp") the §Perf loop examines.

Shared experts (deepseek) are a plain dense MLP on the side.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models.layers import AxisCtx


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_moe_mlp(key, cfg: MoEConfig, tp: int, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    if cfg.moe_impl == "ep":
        if e % tp != 0:
            raise ValueError(f"moe_impl=ep needs n_experts % tp == 0 ({e} % {tp})")
        e_l, f_l = e // tp, f
    else:
        if f % tp != 0:
            raise ValueError(f"d_ff_expert={f} not divisible by tp={tp}")
        e_l, f_l = e, f // tp
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), dtype=jnp.float32),  # router in fp32
        "w_gate": L.dense_init(ks[1], (e_l, d, f_l), in_axis=1, dtype=dtype),
        "w_up": L.dense_init(ks[2], (e_l, d, f_l), in_axis=1, dtype=dtype),
        "w_down": L.dense_init(ks[3], (e_l, f_l, d), in_axis=1, dtype=dtype),
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        shared_cfg = cfg.replace(d_ff=fs)
        p["shared"] = L.init_mlp(ks[4], shared_cfg, tp, dtype)
    return p


def moe_tp_axes(cfg: MoEConfig) -> dict:
    if cfg.moe_impl == "ep":
        axes = {"router": None, "w_gate": 0, "w_up": 0, "w_down": 0}
    else:
        axes = {"router": None, "w_gate": 2, "w_up": 2, "w_down": 1}
    if cfg.n_shared_experts > 0:
        axes["shared"] = L.mlp_tp_axes(cfg)
    return axes


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def route_topk(x, router_w, cfg: MoEConfig):
    """-> (probs [T,K], expert_idx [T,K], aux_loss scalar). x: [T, d]."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(probs_full, cfg.top_k)
    if getattr(cfg, "router_norm_topk", True):
        probs = probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch/GShard style)
    e = cfg.n_experts
    me = jnp.mean(probs_full, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        (jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)), axis=0
    )  # fraction of tokens whose top-1 is e
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef
    return probs, idx, aux


def dispatch_indices(expert_idx, n_experts: int, capacity: int):
    """Compute per-assignment slot positions and the [E*C] token map.

    expert_idx: [T, K].  Returns (pos_in_expert [T,K], keep [T,K] bool,
    slot_to_token [E*C] int32 with T as the "no token" sentinel).
    """
    t, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)  # [T*K], priority: token-major
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # [T*K, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # count of earlier same-expert
    pos = jnp.sum(pos * onehot, axis=-1)  # [T*K]
    keep = pos < capacity
    slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)  # [T*K]
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    # dropped assignments scatter to an out-of-bounds slot -> mode="drop"
    slot_or_oob = jnp.where(keep, slot, n_experts * capacity)
    slot_to_token = jnp.full((n_experts * capacity,), t, dtype=jnp.int32)
    slot_to_token = slot_to_token.at[slot_or_oob].set(token_of, mode="drop")
    return pos.reshape(t, k), keep.reshape(t, k), slot_to_token


def _expert_ffn(w_gate, w_up, w_down, xe, activation):
    """xe: [E_l, C', d] -> [E_l, C', d] batched expert FFN."""
    act = L.ACTIVATIONS[activation]
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up, preferred_element_type=jnp.float32)
    h = (act(g) * u).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32)


def moe_fwd(p, x, cfg: MoEConfig, ctx: AxisCtx):
    """x: [B, S, d] -> [B, S, d]; returns (y, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    probs, idx, aux = route_topk(xt, p["router"], cfg)
    # router params are replicated over the model axis, so every rank
    # computes the same aux loss; psum-mean makes it invariant and keeps
    # the synced router gradient exactly d(aux)/d(router) (not tp x it).
    aux = ctx.psum_model(aux) / ctx.tp
    capacity = max(int(t * cfg.top_k * cfg.capacity_factor / cfg.n_experts), 4)
    pos, keep, slot_to_token = dispatch_indices(idx, cfg.n_experts, capacity)

    # dispatch: [E*C] token gather (sentinel row t -> zeros)
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xd = jnp.take(x_pad, slot_to_token, axis=0).reshape(cfg.n_experts, capacity, d)

    combine_first = getattr(ctx, "moe_combine_first", False)

    def combine(out_full):
        """gather each (token, k)'s slot output, weight by router prob."""
        flat_slot = (idx * capacity + jnp.minimum(pos, capacity - 1)).reshape(-1)
        ok = keep.reshape(-1)
        picked = jnp.take(out_full.reshape(cfg.n_experts * capacity, d),
                          flat_slot, axis=0)
        picked = jnp.where(ok[:, None], picked, 0.0).reshape(t, cfg.top_k, d)
        return jnp.einsum("tkd,tk->td", picked, probs.astype(jnp.float32))

    if cfg.moe_impl == "ep" and ctx.tp > 1:
        e_l = cfg.n_experts // ctx.tp
        rank = ctx.model_rank()
        xd_l = jax.lax.dynamic_slice_in_dim(xd, rank * e_l, e_l, axis=0)
        out_l = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], xd_l,
                            cfg.activation)  # [E_l, C, d] fp32
        out = jnp.zeros((cfg.n_experts, capacity, d), jnp.float32)
        out = jax.lax.dynamic_update_slice_in_dim(out, out_l, rank * e_l, axis=0)
    else:
        out = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], xd, cfg.activation)

    if combine_first:
        # §Perf: combine to [T, d] BEFORE the model-axis psum — payload
        # shrinks by top_k*capacity_factor vs the [E, C, d] buffer, and
        # partial expert outputs sum linearly through the combine.
        y = ctx.psum_model(combine(out))
    else:
        out = ctx.psum_model(out)
        y = combine(out)

    if "shared" in p:
        shared_cfg = cfg.replace(d_ff=cfg.d_ff_expert * cfg.n_shared_experts)
        y = y + L.mlp_fwd(p["shared"], xt, shared_cfg, ctx).astype(jnp.float32)

    return y.reshape(b, s, d).astype(x.dtype), aux
