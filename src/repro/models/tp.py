"""Tensor-parallel metadata and gradient synchronization.

Each model exposes ``tp_axes()``: a pytree mirroring its param tree whose
leaves are the TP-sharded axis index, or ``None`` for params replicated
across the model axis.

Two uses:

1. **Gradient correctness.**  A replicated param feeds TP-sharded
   branches on every model rank; each rank's autodiff only sees its own
   branch, so the true gradient is the *psum over the model axis* of the
   per-rank gradients.  :func:`sync_replicated_grads` wraps replicated
   leaves in an identity whose VJP is that psum — sharded leaves (whose
   per-rank grads are already complete, and must NOT be mixed) are left
   alone.  Because replicated params receive identical synced grads and
   identical optimizer state on every rank, their copies stay bitwise in
   sync across training.

2. **TP resharding.**  ``split_for_tp`` splits a tp=1 ("global") param
   tree into a rank's local shard — used by tests (tp parity) and by the
   checkpoint converter.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


def _grad_psum(axis_name: str):
    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        # psum makes the cotangent invariant over the model axis; pvary
        # restores the varying type expected for the store-shard input
        # (the value is invariant in fact — all ranks hold the same sum).
        # pvary is typing-only and absent on jax without the vma system.
        g = jax.lax.psum(g, axis_name)
        if hasattr(jax.lax, "pvary"):
            g = jax.lax.pvary(g, axis_name)
        return (g,)

    f.defvjp(fwd, bwd)
    return f


def sync_replicated_grads(params: Any, axes: Any, axis_name: str | None, tp: int) -> Any:
    """Wrap replicated leaves so their grads psum over the model axis."""
    if axis_name is None:
        return params
    f = _grad_psum(axis_name)

    def apply(p, ax):
        return f(p) if ax is None else p

    return jax.tree.map(apply, params, axes,
                        is_leaf=lambda x: x is None)


def split_for_tp(tree: Any, axes: Any, tp: int, rank: int) -> Any:
    """Slice a tp=1 param tree into the TP-local shard for ``rank``."""

    def split(p, ax):
        if ax is None:
            return p
        n = p.shape[ax] // tp
        return jax.lax.slice_in_dim(p, rank * n, (rank + 1) * n, axis=ax)

    return jax.tree.map(split, tree, axes, is_leaf=lambda x: x is None)


def infer_tp_axes(global_specs: Any, local_specs: Any, tp: int) -> Any:
    """Derive the axes tree by comparing tp=1 and tp=N leaf shapes."""

    def infer(g, l):
        if g.shape == l.shape:
            return None
        for i, (a, b) in enumerate(zip(g.shape, l.shape)):
            if a == b * tp:
                return i
        raise ValueError(f"cannot infer tp axis: {g.shape} vs {l.shape}")

    return jax.tree.map(infer, global_specs, local_specs)
