"""Roofline analysis from compiled dry-run artifacts.

Hardware constants (TPU v5e-class, per chip):

  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI per link        ~50 GB/s

Three terms per (arch, shape, mesh), all in seconds **per device** (the
compiled SPMD module is the per-device program, so ``cost_analysis()``
flops/bytes are per-device):

  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes_accessed / 819e9
  collective = sum over collective ops of ring link-bytes / 50e9

Collective bytes are NOT in cost_analysis; we parse the optimized HLO
(``compiled.as_text()``): for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the op's
per-device buffer size and apply the ring cost factor
((p-1)/p for AG/RS, 2(p-1)/p for AR, 1 for A2A/permute) with p = the
op's replica-group size.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link
HOST_LINK_BW = 32e9  # bytes/s — PCIe Gen4 x16-class host<->device link
NVME_BW = 6e9  # bytes/s — NVMe-class host<->slow-tier link (ZeRO-Infinity)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[2,4,8]{...}' or a (tuple, of, shapes)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [n_groups,group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        inner = m.group(1).strip("{}")
        if not inner:
            return 1
        return inner.count(",") + 1
    return 1


@dataclasses.dataclass
class CollectiveStats:
    # per op kind: (count, buffer_bytes, link_bytes)
    by_kind: dict
    link_bytes_total: float

    def summary(self) -> str:
        parts = [f"{k}:n={v[0]},buf={v[1]:.3g},link={v[2]:.3g}"
                 for k, v in sorted(self.by_kind.items())]
        return " ".join(parts) if parts else "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind: dict = defaultdict(lambda: [0, 0.0, 0.0])
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        # opname appears right after the result shape: `%x = bf16[..] all-gather(...)`
        head, _, rest = s.partition("=")
        rest = rest.strip()
        kind = None
        for c in _COLLECTIVES:
            # match `all-gather(`, `all-gather-start(`, `all-gather-done(`
            if re.match(rf"\(?[\w\[\],{{}}:\s]*{c}(-start)?\(", rest):
                kind = c
                break
        if kind is None:
            continue
        if f"{kind}-done" in rest:
            continue  # counted at -start
        shape_part = rest.split(kind)[0]
        buf = _shape_bytes(shape_part)
        if buf == 0:
            continue
        p = _group_size(s)
        frac = (p - 1) / p if p > 1 else 0.0
        if kind == "all-gather":
            link = frac * buf  # result is the gathered (per-device) buffer
        elif kind == "reduce-scatter":
            link = frac * buf * p  # result is the scattered shard
        elif kind == "all-reduce":
            link = 2.0 * frac * buf
        elif kind == "all-to-all":
            link = frac * buf
        else:  # collective-permute
            link = float(buf)
        rec = by_kind[kind]
        rec[0] += 1
        rec[1] += buf
        rec[2] += link
    total = sum(v[2] for v in by_kind.values())
    return CollectiveStats(by_kind=dict(by_kind), link_bytes_total=total)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_link_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs (per device)
    collectives: CollectiveStats
    memory_stats: dict

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, *, model_flops_per_device: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some versions return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = parse_collectives(txt)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll.link_bytes_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
    }
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_link_bytes=coll.link_bytes_total,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops_per_device,
        useful_ratio=(model_flops_per_device / flops) if flops else 0.0,
        collectives=coll, memory_stats=mem,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE)
# ---------------------------------------------------------------------------


def count_params(rt) -> tuple[float, float]:
    """(N_total, N_active) global params from the runtime's layouts.

    Layout payloads are TP-local; sharded leaves scale by tp.  We count
    from tp_axes to know which leaves are replicated.  MoE expert FFN
    params scale by top_k/n_experts (+ shared experts) for N_active.
    """
    import jax
    import numpy as np

    cfg = rt.cfg
    tp = rt.ctx.tp
    specs = rt.model.param_specs()
    axes = rt.tp_axes

    def tree_count(spec_tree, axes_tree, scale_expert=False):
        total = 0.0
        active = 0.0
        leaves_s = jax.tree_util.tree_flatten_with_path(spec_tree)[0]
        leaves_a = jax.tree.leaves(
            jax.tree.map(lambda x: x, axes_tree,
                         is_leaf=lambda x: x is None or isinstance(x, int)),
            is_leaf=lambda x: x is None or isinstance(x, int))
        for (path, leaf), ax in zip(leaves_s, leaves_a):
            n = float(np.prod(leaf.shape))
            if ax is not None:
                n *= tp
            total += n
            name = jax.tree_util.keystr(path)
            if scale_expert and ("w_gate" in name or "w_up" in name
                                 or "w_down" in name) and "shared" not in name:
                active += n * cfg.top_k / cfg.n_experts
            else:
                active += n
        return total, active

    tot, act = tree_count(specs["stem"], axes["stem"])
    for g in rt.model.groups():
        ga = axes["groups"][g.name]
        one = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                           specs["groups"][g.name])
        is_moe = cfg.arch_type == "moe" and g.name == "moe_layers"
        t1, a1 = tree_count(one, ga, scale_expert=is_moe)
        tot += t1 * g.length
        act += a1 * g.length
    return tot, act


def model_flops(rt, shape, n_total: float, n_active: float) -> float:
    """Global MODEL_FLOPS for one step of this input shape."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
