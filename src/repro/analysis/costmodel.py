"""Analytical roofline cost model per (arch config x input shape x mesh).

WHY ANALYTICAL: XLA's ``compiled.cost_analysis()`` counts a while-loop
body ONCE, not trip_count times — under scan-over-layers (and the seq
scans inside SSM blocks / flash attention) the reported FLOPs/bytes
understate by ~L x.  The dry-run therefore records BOTH the raw HLO
numbers (with this caveat) and the analytical terms below; the collective
model is validated against the per-layer HLO parse (collectives appear
once per scan body = once per layer).

Conventions (everything PER DEVICE PER STEP):
  * matmul [m,k]@[k,n]: flops 2mkn; HBM traffic (2(mk + kn + mn)) bytes at
    bf16 — one read of each operand + one write (XLA fusion can beat
    this; it is a principled first-order bound).
  * train = fwd * (2 bwd) + fwd recompute under full remat => 4x fwd
    flops; "dots"/none remat => 3x.
  * batch/sequence per device: tokens_local = B*S / (pods*dp); the model
    axis divides head/ffn dims (TP), so TP-local matmul ledger entries
    already carry /tp.
  * collectives: ring cost, link-bytes per device:
      all-gather/reduce-scatter: (p-1)/p * buffer
      all-reduce: 2(p-1)/p * buffer
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs.base import BaseConfig, InputShape


@dataclasses.dataclass
class CostTerms:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # link bytes by mesh axis role
    zero_bytes: float = 0.0  # chunk all-gather + grad reduce-scatter (data)
    tp_bytes: float = 0.0  # activation psums (model)
    pod_bytes: float = 0.0  # inter-pod grad psum (pod)

    def add_matmul(self, m, k, n, *, itemsize=2.0, count=1.0):
        self.flops += 2.0 * m * k * n * count
        self.hbm_bytes += itemsize * (m * k + k * n + m * n) * count

    @property
    def collective_bytes(self) -> float:
        return self.zero_bytes + self.tp_bytes + self.pod_bytes

    def seconds(self) -> dict:
        return {
            "compute_s": self.flops / PEAK_FLOPS,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.collective_bytes / ICI_BW,
        }

    def dominant(self) -> str:
        s = self.seconds()
        return max(s, key=s.get).replace("_s", "")


def _ring(p: int) -> float:
    return (p - 1) / p if p > 1 else 0.0


def _attn_flops(ct: CostTerms, b, s, h, hd, *, causal=True, kv_len=None,
                train_mult=1.0):
    """Score + value matmuls of attention (per device; h is tp-local)."""
    kv = kv_len if kv_len is not None else s
    eff = 0.5 if (causal and kv_len is None) else 1.0
    flops = 2.0 * b * s * kv * h * hd * 2 * eff
    ct.flops += flops * train_mult
    # flash/scan streaming: read K/V once per q block + q + out
    ct.hbm_bytes += 2.0 * b * kv * h * hd * 2 * train_mult  # K,V bf16
    ct.hbm_bytes += 2.0 * b * s * h * hd * 2 * train_mult  # Q, out


def analyze_pair(cfg: BaseConfig, shape: InputShape, *, dp: int, tp: int,
                 pods: int = 1, remat: str = "full",
                 gather_per_layer: bool = True,
                 ep_combine_first: bool = False,
                 zero_gathers_train: int | None = None) -> CostTerms:
    """Main entry: analytical per-device roofline terms for one pair."""
    ct = CostTerms()
    b_loc = max(shape.global_batch // (dp * pods), 1)
    kind = shape.kind
    s = shape.seq_len if kind != "decode" else 1
    kv_len = shape.seq_len if kind == "decode" else None
    t_loc = b_loc * s  # tokens per device
    d = cfg.d_model
    mult = (4.0 if remat == "full" else 3.0) if kind == "train" else 1.0

    # ---------------- per-layer ledger ------------------------------------
    def dense_attn_layer(c: BaseConfig):
        h_l = max(c.n_heads // tp, 1)
        kv_heads = c.n_kv_heads
        kv_l = max(kv_heads // tp, 1) if kv_heads % tp == 0 else kv_heads
        hd = c.head_dim
        ct.add_matmul(t_loc, d, h_l * hd, count=mult)  # wq
        ct.add_matmul(t_loc, d, kv_l * hd, count=2 * mult)  # wk, wv
        ct.add_matmul(t_loc, h_l * hd, d, count=mult)  # wo
        window = getattr(c, "sliding_window", None)
        akv = min(kv_len or s, window) if window else (kv_len or s)
        _attn_flops(ct, b_loc, s, h_l, hd, causal=kind != "prefill" or True,
                    kv_len=akv if kind == "decode" else None, train_mult=mult)

    def mlp(c, width=None):
        f_l = max((width or c.d_ff) // tp, 1)
        n = 3 if getattr(c, "gated_mlp", True) else 2
        ct.add_matmul(t_loc, d, f_l, count=(n - 1) * mult)
        ct.add_matmul(t_loc, f_l, d, count=mult)

    def mla_layer(c):
        h_l = max(c.n_heads // tp, 1)
        nr = c.qk_nope_dim + c.qk_rope_dim
        r = c.kv_lora_rank
        ct.add_matmul(t_loc, d, h_l * nr, count=mult)  # wq
        ct.add_matmul(t_loc, d, r + c.qk_rope_dim, count=mult)  # w_dkv+w_krope
        if kind == "decode":
            # absorbed: q->latent, scores/out in latent space over cache/tp
            c_loc = (kv_len or s) // tp
            ct.add_matmul(b_loc, h_l * c.qk_nope_dim, r, count=1)
            ct.flops += 2.0 * b_loc * c.n_heads * c_loc * (r + c.qk_rope_dim) * 2
            ct.hbm_bytes += b_loc * c_loc * (r + c.qk_rope_dim) * 2  # cache read
            ct.add_matmul(b_loc, r, h_l * c.v_head_dim, count=1)
        else:
            ct.add_matmul(t_loc, r, h_l * c.qk_nope_dim, count=mult)  # w_uk
            ct.add_matmul(t_loc, r, h_l * c.v_head_dim, count=mult)  # w_uv
            _attn_flops(ct, b_loc, s, h_l, nr, train_mult=mult)
        ct.add_matmul(t_loc, h_l * c.v_head_dim, d, count=mult)  # wo

    def moe_layer(c):
        e = c.n_experts
        ct.add_matmul(t_loc, d, e, itemsize=4, count=mult)  # router fp32
        cap = max(int(t_loc * c.top_k * c.capacity_factor / e), 4)
        if c.moe_impl == "ep" and e % tp == 0:
            e_l, f_l = e // tp, c.d_ff_expert
        else:
            e_l, f_l = e, max(c.d_ff_expert // tp, 1)
        ct.add_matmul(e_l * cap, d, f_l, count=2 * mult)  # gate+up
        ct.add_matmul(e_l * cap, f_l, d, count=mult)  # down
        if c.n_shared_experts:
            mlp(c, width=c.d_ff_expert * c.n_shared_experts)
        # expert-output psum over model ([E,C,d] fp32, or [T,d] when the
        # combine happens before the psum — the §Perf optimization)
        buf = (t_loc * d * 4.0 if ep_combine_first else e * cap * d * 4.0)
        ct.tp_bytes += 2.0 * _ring(tp) * buf * (mult if kind == "train" else 1)

    def mamba_layer(c):
        di_l = max(c.d_inner // tp, 1)
        nh_l = max(c.mamba_heads // tp, 1)
        ds = c.ssm_state
        ct.add_matmul(t_loc, d, 2 * di_l + 2 * ds + nh_l, count=mult)
        ct.add_matmul(t_loc, di_l, d, count=mult)  # out proj
        # SSD: intra-chunk quadratic (q=chunk_len) + state updates
        q = c.chunk_len
        eff_s = s if kind != "decode" else 1
        ct.flops += (2.0 * b_loc * eff_s * q * nh_l * (c.mamba_headdim + ds)
                     + 4.0 * b_loc * eff_s * nh_l * c.mamba_headdim * ds) * mult
        if kind == "decode":
            ct.hbm_bytes += b_loc * nh_l * c.mamba_headdim * ds * 4  # state

    def mlstm_layer(c):
        di = c.d_inner
        nh = c.n_heads
        dh = di // nh
        dv = dh // tp if dh % tp == 0 and tp > 1 else dh
        ct.add_matmul(t_loc, d, di, count=mult)  # up
        ct.add_matmul(t_loc, di, 2 * nh * dh + nh * dv + 2 * nh, count=mult)
        ct.add_matmul(t_loc, nh * dv, d, count=mult)  # down
        q = c.chunk_len
        eff_s = s if kind != "decode" else 1
        ct.flops += (2.0 * b_loc * eff_s * q * nh * (dh + dv)
                     + 4.0 * b_loc * eff_s * nh * dh * dv) * mult
        if kind == "decode":
            ct.hbm_bytes += b_loc * nh * dh * dv * 4

    def slstm_layer(c):
        di = c.d_inner
        nh = c.n_heads
        dh = di // nh
        ct.add_matmul(t_loc, d, 4 * di, count=mult)
        ct.flops += 2.0 * b_loc * s * nh * dh * 4 * dh * mult  # recurrent R
        ct.add_matmul(t_loc, di, d, count=mult)
        ff = int(d * 4 / 3) // 8 * 8
        ct.add_matmul(t_loc, d, ff, count=mult)
        ct.add_matmul(t_loc, ff, d, count=mult)

    # ---------------- assemble per arch type -------------------------------
    at = cfg.arch_type
    layers_psums = 0  # activation psums over model per layer (fwd)
    if at in ("dense", "vlm"):
        for _ in range(cfg.num_layers):
            dense_attn_layer(cfg)
            mlp(cfg)
        layers_psums = 2 * cfg.num_layers
    elif at == "moe":
        from repro.configs.base import MoEConfig
        for _ in range(cfg.first_dense_layers):
            dense_attn_layer(cfg)
            mlp(cfg)
        for _ in range(cfg.num_layers - cfg.first_dense_layers):
            if cfg.use_mla:
                mla_layer(cfg)
            else:
                dense_attn_layer(cfg)
            moe_layer(cfg)
        layers_psums = 2 * cfg.num_layers
    elif at == "ssm":  # xlstm
        n_m = cfg.num_units * cfg.mlstm_per_unit
        n_s = cfg.num_units * cfg.slstm_per_unit
        for _ in range(n_m):
            mlstm_layer(cfg)
        for _ in range(n_s):
            slstm_layer(cfg)
        layers_psums = n_m
    elif at == "hybrid":  # zamba2
        for _ in range(cfg.num_layers):
            mamba_layer(cfg)
        # shared attention block at 2d width, once per unit
        sc = cfg.replace(d_model=2 * d, sliding_window=None)
        d2 = 2 * d
        for _ in range(cfg.num_units):
            h_l = max(sc.n_heads // tp, 1)
            ct.add_matmul(t_loc, d2, 4 * h_l * sc.head_dim, count=mult)
            _attn_flops(ct, b_loc, s, h_l, sc.head_dim,
                        kv_len=kv_len, train_mult=mult)
            f_l = max(sc.d_ff // tp, 1)
            ct.add_matmul(t_loc, d2, f_l, count=2 * mult)
            ct.add_matmul(t_loc, f_l, d2, count=mult)
            ct.add_matmul(t_loc, d2, d, count=mult)  # w_proj
        layers_psums = cfg.num_layers + 2 * cfg.num_units
    elif at == "audio":  # whisper: encoder full seq + decoder
        enc_t = b_loc * min(cfg.encoder_frames, shape.seq_len)
        h, hd = cfg.n_heads, cfg.head_dim  # attention replicated (20 % 16)
        for _ in range(cfg.num_encoder_layers):
            if kind != "decode":
                ct.add_matmul(enc_t, d, 4 * h * hd, count=mult)
                _attn_flops(ct, b_loc, min(cfg.encoder_frames, shape.seq_len),
                            h, hd, causal=False, train_mult=mult)
                ct.add_matmul(enc_t, d, cfg.d_ff // tp, count=mult)
                ct.add_matmul(enc_t, cfg.d_ff // tp, d, count=mult)
        for _ in range(cfg.num_layers):
            ct.add_matmul(t_loc, d, 4 * h * hd, count=mult)
            _attn_flops(ct, b_loc, s, h, hd, kv_len=kv_len, train_mult=mult)
            # cross attention over encoder frames
            _attn_flops(ct, b_loc, s, h, hd, causal=False,
                        kv_len=cfg.encoder_frames, train_mult=mult)
            ct.add_matmul(t_loc, d, cfg.d_ff // tp, count=mult)
            ct.add_matmul(t_loc, cfg.d_ff // tp, d, count=mult)
        layers_psums = cfg.num_layers + cfg.num_encoder_layers

    # ---------------- stem: embedding + head + xent ------------------------
    v_l = -(-cfg.vocab_size // tp)
    ct.hbm_bytes += t_loc * d * 2 * 2  # embed gather read+write
    if kind == "train":
        ct.add_matmul(t_loc, d, v_l, itemsize=2, count=3.0)  # head fwd+bwd
        ct.hbm_bytes += t_loc * v_l * 4 * 2  # fp32 logits + softmax pass
    else:
        ct.add_matmul(b_loc, d, v_l, count=1.0)

    # ---------------- collectives ------------------------------------------
    # ZeRO chunk traffic over `data`: params gathered per layer (or per
    # step), re-gathered in BWD under full remat, grads reduce-scattered.
    n_params_local = _param_bytes_local(cfg, tp)  # bf16 bytes per model-rank
    if kind == "train":
        gathers = zero_gathers_train if zero_gathers_train is not None else (
            2 if remat == "full" else 1)
        ct.zero_bytes += (gathers + 1) * _ring(dp) * n_params_local
        if pods > 1:  # inter-pod grad psum (bf16 grads of the local shard)
            ct.pod_bytes += 2 * _ring(pods) * n_params_local / max(dp, 1)
    else:
        ct.zero_bytes += _ring(dp) * n_params_local
    # TP activation psums ([B_loc, s, d] bf16): fwd (+bwd, +re-fwd in train)
    psum_phases = (3.0 if remat == "full" else 2.0) if kind == "train" else 1.0
    ct.tp_bytes += (layers_psums * psum_phases
                    * 2.0 * _ring(tp) * t_loc * d * 2)
    # vocab-parallel xent psums (scalars per token, fp32, ~3 of them)
    ct.tp_bytes += 3 * 2.0 * _ring(tp) * t_loc * 4
    return ct


# ---------------------------------------------------------------------------
# Per-operator compute durations for the transfer timeline
# (core/timeline.py): the eager engines advance a simulated clock
# moment-by-moment; each operator's duration is its roofline time —
# max(flops/PEAK, hbm/BW) — carved out of the analytical step ledger.
# ---------------------------------------------------------------------------


def _roofline_seconds(ct: CostTerms) -> float:
    return max(ct.flops / PEAK_FLOPS, ct.hbm_bytes / HBM_BW)


@dataclasses.dataclass(frozen=True)
class TrainOperatorCosts:
    """Durations of the training engine's moment kinds (seconds)."""

    fwd_layer_s: float
    bwd_layer_s: float  # recompute + grad under full remat: 3x fwd
    adam_chunk_s: float  # one chunk's 4-stream quad update

    def of_moment(self, op_name: str, phase: str) -> float:
        """Duration of one tracer moment.  ``.end`` moments mark the
        operator's finish and carry no compute of their own."""
        if op_name.endswith(".end"):
            return 0.0
        if phase == "FWD":
            return self.fwd_layer_s
        if phase == "BWD":
            return self.bwd_layer_s
        if phase == "ADAM":
            return self.adam_chunk_s
        return 0.0


def train_operator_costs(
    cfg: BaseConfig,
    *,
    global_batch: int,
    seq_len: int,
    num_layer_ops: int,
    chunk_bytes: int,
    dp: int = 1,
) -> TrainOperatorCosts:
    """Per-operator durations of one training iteration.

    The analytical train ledger is 4x forward under full remat
    (fwd + recompute + 2x bwd), so one layer's forward is a quarter of
    the step divided over the layer count, and a backward_layer moment
    (recompute inside vjp + both grads) is the remaining 3x.  The ADAM
    chunk update is memory-bound: read+write of the grad/p32/m/v quad at
    HBM bandwidth."""
    shape = InputShape("timeline", seq_len, max(global_batch, 1), "train")
    ct = analyze_pair(cfg, shape, dp=dp, tp=1, remat="full")
    fwd_layer = _roofline_seconds(ct) / 4.0 / max(num_layer_ops, 1)
    return TrainOperatorCosts(
        fwd_layer_s=fwd_layer,
        bwd_layer_s=3.0 * fwd_layer,
        adam_chunk_s=2.0 * 4.0 * chunk_bytes / HBM_BW,
    )


@dataclasses.dataclass(frozen=True)
class ServeOperatorCosts:
    """Durations of the serving engine's per-layer ops (seconds)."""

    prefill_layer_s: float  # one layer over one prompt
    decode_layer_s: float  # one layer, one token, one sequence


def serve_operator_costs(
    cfg: BaseConfig, *, prompt_tokens: int, horizon: int, num_layers: int
) -> ServeOperatorCosts:
    """Per-layer prefill/decode durations for one sequence (batch 1)."""
    n = max(num_layers, 1)
    pre = analyze_pair(
        cfg, InputShape("timeline", max(prompt_tokens, 1), 1, "prefill"),
        dp=1, tp=1)
    dec = analyze_pair(
        cfg, InputShape("timeline", max(horizon, 1), 1, "decode"), dp=1, tp=1)
    return ServeOperatorCosts(
        prefill_layer_s=_roofline_seconds(pre) / n,
        decode_layer_s=_roofline_seconds(dec) / n,
    )


def _param_bytes_local(cfg: BaseConfig, tp: int) -> float:
    """bf16 parameter bytes per model-rank (what ZeRO gathers move)."""
    d = cfg.d_model
    v_l = -(-cfg.vocab_size // tp)
    at = cfg.arch_type
    h_l = max(cfg.n_heads // tp, 1)
    kv_l = (max(cfg.n_kv_heads // tp, 1) if cfg.n_kv_heads % tp == 0
            else cfg.n_kv_heads)
    hd = cfg.head_dim
    total = v_l * d  # embedding
    if not cfg.tie_embeddings:
        total += v_l * d

    def dense_layer(c, dm=None):
        dm = dm or d
        n = (dm * (h_l * hd + 2 * kv_l * hd) + h_l * hd * dm)
        f_l = max(c.d_ff // tp, 1)
        n += dm * f_l * (3 if c.gated_mlp else 2)
        return n

    if at in ("dense", "vlm"):
        total += cfg.num_layers * dense_layer(cfg)
        if at == "vlm":
            total += cfg.vision_dim * d + d * d
    elif at == "moe":
        nr = getattr(cfg, "qk_nope_dim", 0) + getattr(cfg, "qk_rope_dim", 0)
        r = getattr(cfg, "kv_lora_rank", 0)
        if cfg.use_mla:
            attn = (d * (h_l * nr) + d * (r + cfg.qk_rope_dim)
                    + r * h_l * (cfg.qk_nope_dim + cfg.v_head_dim)
                    + h_l * cfg.v_head_dim * d)
        else:
            attn = d * (h_l * hd + 2 * kv_l * hd) + h_l * hd * d
        e = cfg.n_experts
        if cfg.moe_impl == "ep" and e % tp == 0:
            ex = (e // tp) * 3 * d * cfg.d_ff_expert
        else:
            ex = e * 3 * d * max(cfg.d_ff_expert // tp, 1)
        if cfg.n_shared_experts:
            ex += 3 * d * max(cfg.d_ff_expert * cfg.n_shared_experts // tp, 1)
        moe_layers = cfg.num_layers - cfg.first_dense_layers
        total += moe_layers * (attn + ex + d * e)
        total += cfg.first_dense_layers * dense_layer(cfg)
    elif at == "ssm":
        di = cfg.d_inner
        nh = cfg.n_heads
        dh = di // nh
        dv = dh // tp if dh % tp == 0 and tp > 1 else dh
        m = (d * di + di * (2 * nh * dh + nh * dv + 2 * nh) + nh * dv * d
             + d * nh * dv)
        sl = d * 4 * di + nh * dh * 4 * dh + di * d + 2 * d * (int(d * 4 / 3) // 8 * 8)
        total += cfg.num_units * (cfg.mlstm_per_unit * m
                                  + cfg.slstm_per_unit * sl)
    elif at == "hybrid":
        di_l = max(cfg.d_inner // tp, 1)
        nh_l = max(cfg.mamba_heads // tp, 1)
        m = (d * (2 * di_l + 2 * cfg.ssm_state + nh_l) + di_l * d)
        total += cfg.num_layers * m
        d2 = 2 * d
        sc_f = max(cfg.d_ff // tp, 1)
        total += (d2 * 4 * h_l * hd + d2 * sc_f * 3 + cfg.num_units * d2 * d)
    elif at == "audio":
        lay = d * 4 * cfg.n_heads * hd + d * (cfg.d_ff // tp) * 2
        total += cfg.num_encoder_layers * lay
        total += cfg.num_layers * (lay + d * 4 * cfg.n_heads * hd)
        total += cfg.frontend_dim * d + cfg.encoder_frames * d
    return float(total) * 2.0  # bf16
