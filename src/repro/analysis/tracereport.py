"""Offline report over a Chrome ``trace_event`` JSON exported by the hub.

``repro.core.telemetry.Telemetry.dump_chrome_trace`` writes the object
format: ``{"traceEvents": [...], "otherData": {...}}``.  This module
re-loads such a file, *validates* it (well-formed event array, monotone
timestamps per track, balanced span begin/end, and — crucially — that
the byte totals derivable from the event stream still equal the counter
totals the hub snapshotted into ``otherData`` at export time), then
prints three summaries:

  * top-K chunks by transferred bytes (who dominates DMA traffic),
  * stall attribution (seconds of critical-path wait per lane and per
    stream that caused the wait),
  * eviction churn (victim -> requester counts, plus per-policy and
    per-urgency tallies).

Opening the trace in Perfetto
-----------------------------
The exported JSON is a standard Chrome trace:

  1. Run a traced workload, e.g.::

         PYTHONPATH=src python benchmarks/run.py --smoke --trace-dir /tmp/traces

  2. Open https://ui.perfetto.dev in a browser.
  3. Click "Open trace file" (or drag-and-drop) and pick
     ``/tmp/traces/timeline.json``.
  4. Tracks: one per DMA lane (``dma:h2d``, ``dma:d2h``, ``dma:h2s``,
     ``dma:s2h``, ``dma:coll``), a ``wall`` track interleaving compute
     slices with ``stall:<lane>`` slices (the simulated critical path),
     per-tenant span tracks (``<tenant>/step``, ``<tenant>/moments``,
     ``<tenant>/round``, ``<tenant>/ops``), and instant-event tracks for
     evictions, prefetch lifecycle, state transitions and OOMs.
     Distributed runs prefix tracks with ``rank<N>/``.
  5. Timestamps are the ``TransferTimeline`` simulated clock in
     microseconds when a timeline was attached (``otherData.clock ==
     "timeline"``); otherwise event sequence numbers (``"seq"``) — still
     useful for ordering, meaningless as durations.

Command line::

    PYTHONPATH=src python -m repro.analysis.tracereport /tmp/traces/timeline.json --top 10
"""

from __future__ import annotations

import argparse
import collections
import json
import math
from typing import Any


def load(path: str) -> dict[str, Any]:
    """Load a Chrome trace JSON file (object format)."""
    with open(path) as fh:
        trace = json.load(fh)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError(f"{path}: not a Chrome trace object "
                         "(missing 'traceEvents')")
    return trace


def _tracks(trace: dict[str, Any]) -> dict[tuple[int, int], list[dict]]:
    """Group timestamped events by (pid, tid) track, preserving order."""
    tracks: dict[tuple[int, int], list[dict]] = collections.defaultdict(list)
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M":
            continue
        tracks[(ev.get("pid", 0), ev.get("tid", 0))].append(ev)
    return tracks


def validate(trace: dict[str, Any]) -> None:
    """Check structure, per-track monotonicity, span balance, conservation.

    Raises ``AssertionError`` with a descriptive message on any failure.
    """
    events = trace["traceEvents"]
    assert isinstance(events, list), "traceEvents must be a list"
    for ev in events:
        assert isinstance(ev, dict) and "ph" in ev and "name" in ev, (
            f"malformed trace event: {ev!r}")
        if ev["ph"] != "M":
            assert isinstance(ev.get("ts"), (int, float)), (
                f"event missing numeric ts: {ev!r}")

    for (pid, tid), evs in _tracks(trace).items():
        prev = -math.inf
        stack: list[str] = []
        for ev in evs:
            assert ev["ts"] >= prev, (
                f"track (pid={pid}, tid={tid}): timestamps regress at "
                f"{ev['name']!r} ({ev['ts']} < {prev})")
            prev = ev["ts"]
            if ev["ph"] == "B":
                stack.append(ev["name"])
            elif ev["ph"] == "E":
                assert stack, (f"track (pid={pid}, tid={tid}): span end "
                               f"{ev['name']!r} without begin")
                top = stack.pop()
                assert top == ev["name"], (
                    f"track (pid={pid}, tid={tid}): span end "
                    f"{ev['name']!r} does not match open {top!r}")
        assert not stack, (f"track (pid={pid}, tid={tid}): unclosed "
                           f"spans {stack}")

    counters = trace.get("otherData", {}).get("counters")
    if counters:
        got_bytes: dict[str, int] = collections.defaultdict(int)
        got_counts: dict[str, int] = collections.defaultdict(int)
        for ev in events:
            if ev.get("cat") == "move":
                lane = ev["args"]["lane"]
                got_bytes[lane] += ev["args"]["bytes"]
                got_counts[lane] += 1
            elif ev.get("cat") == "collective":
                got_bytes["coll"] += ev["args"]["bytes"]
        for lane, want in counters.get("lane_bytes", {}).items():
            assert got_bytes[lane] == want, (
                f"conservation violated in trace: {lane} events="
                f"{got_bytes[lane]} counters={want}")
        for lane, want in counters.get("lane_counts", {}).items():
            assert got_counts[lane] == want, (
                f"conservation violated in trace: {lane} count events="
                f"{got_counts[lane]} counters={want}")


def report(trace: dict[str, Any], top_k: int = 10) -> str:
    """Render the three summaries as a printable string."""
    events = trace["traceEvents"]

    chunk_bytes: collections.Counter = collections.Counter()
    chunk_moves: collections.Counter = collections.Counter()
    stall_by_lane: dict[str, float] = collections.defaultdict(float)
    stall_by_stream: dict[str, float] = collections.defaultdict(float)
    churn: collections.Counter = collections.Counter()
    evict_policy: collections.Counter = collections.Counter()
    evict_urgency: collections.Counter = collections.Counter()
    lane_bytes: collections.Counter = collections.Counter()

    for ev in events:
        cat, args = ev.get("cat"), ev.get("args", {})
        if cat == "move":
            key = (args.get("stream"), args.get("chunk"))
            chunk_bytes[key] += args.get("bytes", 0)
            chunk_moves[key] += 1
            lane_bytes[args.get("lane")] += args.get("bytes", 0)
        elif cat == "stall":
            lane = args.get("lane", ev["name"].split(":", 1)[-1])
            dur_s = args.get("seconds", ev.get("dur", 0) / 1e6)
            stall_by_lane[lane] += dur_s
            stall_by_stream[args.get("stream", "?")] += dur_s
        elif cat == "evict":
            victim = args.get("tenant", ev["name"])
            churn[(victim, args.get("requester"))] += 1
            evict_policy[args.get("policy")] += 1
            evict_urgency[args.get("urgency")] += 1

    lines: list[str] = []
    lines.append(f"== top {top_k} chunks by transferred bytes ==")
    if chunk_bytes:
        for (stream, chunk), nbytes in chunk_bytes.most_common(top_k):
            lines.append(f"  {stream}[chunk {chunk}]: "
                         f"{nbytes / 2**20:.2f} MiB over "
                         f"{chunk_moves[(stream, chunk)]} moves")
    else:
        lines.append("  (no chunk moves recorded)")
    if lane_bytes:
        per_lane = ", ".join(f"{lane}={b / 2**20:.2f} MiB"
                             for lane, b in sorted(lane_bytes.items()))
        lines.append(f"  lane totals: {per_lane}")

    lines.append("== stall attribution ==")
    if stall_by_lane:
        for lane, sec in sorted(stall_by_lane.items(),
                                key=lambda kv: -kv[1]):
            lines.append(f"  lane {lane}: {sec * 1e3:.3f} ms")
        for stream, sec in sorted(stall_by_stream.items(),
                                  key=lambda kv: -kv[1]):
            lines.append(f"  waited-on stream {stream}: {sec * 1e3:.3f} ms")
    else:
        lines.append("  (no stalls recorded)")

    lines.append("== eviction churn ==")
    if churn:
        for (victim, requester), n in churn.most_common(top_k):
            tag = ("self" if victim == requester
                   else f"for {requester}")
            lines.append(f"  {victim} evicted {n}x ({tag})")
        lines.append("  by policy: " + ", ".join(
            f"{p}={n}" for p, n in evict_policy.most_common()))
        lines.append("  by urgency: " + ", ".join(
            f"{u}={n}" for u, n in evict_urgency.most_common()))
    else:
        lines.append("  (no evictions recorded)")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="validate + summarise a telemetry Chrome trace")
    ap.add_argument("trace", help="path to a trace JSON written by "
                    "Telemetry.dump_chrome_trace")
    ap.add_argument("--top", type=int, default=10,
                    help="how many chunks / churn pairs to list")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip structural + conservation validation")
    ns = ap.parse_args(argv)

    trace = load(ns.trace)
    if not ns.no_validate:
        validate(trace)
        print(f"{ns.trace}: valid "
              f"({len(trace['traceEvents'])} events, "
              f"clock={trace.get('otherData', {}).get('clock', '?')})")
    print(report(trace, top_k=ns.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
