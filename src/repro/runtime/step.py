"""Distributed chunked runtime: the compiled counterpart of PatrickStar.

Array conventions (GLOBAL shapes; leading axes shard over the mesh):

  param store (stem)    [tp, G, p, S]        P(model, None, data, None)
  param store (group)   [tp, L, G, p, S]     P(model, None, None, data, None)
  optimizer-state store same layout, fp32 (3 of them: p32 / m / v),
                        optionally split along G into a device-resident
                        part and a pinned_host-resident part (Section 8.2)
  batch tensors         [B, ...]             P((pod, data), ...)
  decode caches         [tp, L, B, ...]      P(model, None, (pod,data), ...)

Inside shard_map every block is local; the leading tp/ZeRO axes collapse
to 1 and are squeezed.  Per-layer chunk fetch = ``all_gather`` over
``data`` inside the layer scan (transpose: reduce-scatter of grads);
HOLD_AFTER_FWD semantics = ``jax.checkpoint`` refusing to save gathered
params, so BWD re-gathers (Section 6.2).  ADAM runs on the local shard
only (Section 7: "the ADAM stage is executed locally").
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, dtype_of
from repro.core import zero
from repro.core.zero import ChunkLayout
from repro.models import tp as tpmod
from repro.models.api import Model
from repro.models.layers import AxisCtx, all_axes, greedy_token, vary_tree


@dataclasses.dataclass(frozen=True)
class RuntimeOptions:
    remat: str = "full"  # "full" | "dots" | "none"
    gather_policy: str = "layer"  # "layer" | "step"
    chunk_size: int | None = None  # None -> per-layout search
    # fraction of OS chunk groups host-resident (1.0 = ZeRO-Offload-style
    # all-on-host; 0.0 = all-on-device; paper's device-aware placement
    # picks this from margin space)
    os_host_fraction: float = 0.0
    # optimizer
    lr: float = 1e-3
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.0
    use_adam_kernel: bool = False  # Pallas fused chunked-Adam
    attn_impl: str = "auto"
    attn_block: int = 512
    # ---- beyond-paper §Perf switches -------------------------------------
    inner_remat: bool = False  # checkpoint inner seq scans (memory term)
    moe_combine_first: bool = False  # combine before psum (collective term)
    # gradient accumulation: split the global batch into N microbatches
    # scanned sequentially (activation memory / N at ~no flops cost)
    accum_steps: int = 1
    xent_block: int = 0  # blockwise LM-head cross-entropy (0 = off)


class ChunkedRuntime:
    """Binds (model, mesh, options) into lowered/lowerable step functions."""

    def __init__(self, model_cls, cfg, mesh, options: RuntimeOptions | None = None):
        from repro.launch.mesh import mesh_axes

        self.cfg = cfg
        self.mesh = mesh
        self.opt = options or RuntimeOptions()
        ax = mesh_axes(mesh)
        self.ctx = AxisCtx(
            model_axis=ax["model_axis"], tp=ax["tp"],
            data_axis=ax["data_axis"], dp=ax["dp"],
            pod_axis=ax["pod_axis"], pods=ax["pods"],
            attn_impl=self.opt.attn_impl, attn_block=self.opt.attn_block,
            inner_remat=self.opt.inner_remat,
            moe_combine_first=self.opt.moe_combine_first,
            xent_block=self.opt.xent_block,
        )
        self.model: Model = model_cls(cfg, self.ctx)
        self.tp_axes = self.model.tp_axes()
        self._build_layouts()

    # ------------------------------------------------------------------ layout
    def _build_layouts(self):
        specs = self.model.param_specs()
        pdtype = dtype_of(self.cfg.param_dtype)
        dp = self.ctx.dp
        self.layouts: dict[str, ChunkLayout] = {}
        self.layouts["stem"] = zero.make_layout(
            specs["stem"], nproc=dp, dtype=pdtype, chunk_size=self.opt.chunk_size)
        self.group_lengths: dict[str, int] = {}
        for g in self.model.groups():
            one_layer = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                specs["groups"][g.name])
            self.layouts[g.name] = zero.make_layout(
                one_layer, nproc=dp, dtype=pdtype, chunk_size=self.opt.chunk_size)
            self.group_lengths[g.name] = g.length

    # ---------------------------------------------------------------- shapes
    def store_specs(self) -> dict:
        """Global ShapeDtypeStructs of the bf16 param chunk stores."""
        tp = self.ctx.tp
        out = {}
        for name, lay in self.layouts.items():
            g, p, s = lay.store_shape
            if name == "stem":
                out[name] = jax.ShapeDtypeStruct((tp, g, p, s), lay.dtype)
            else:
                out[name] = jax.ShapeDtypeStruct(
                    (tp, self.group_lengths[name], g, p, s), lay.dtype)
        return out

    def store_pspecs(self) -> dict:
        out = {}
        for name in self.layouts:
            if name == "stem":
                out[name] = P("model", None, "data", None)
            else:
                out[name] = P("model", None, None, "data", None)
        return out

    def os_split(self, name: str) -> tuple[int, int]:
        """(device_groups, host_groups) along G for OS stores (Section 8.2)."""
        g = self.layouts[name].num_groups
        host = int(round(g * self.opt.os_host_fraction))
        host = min(max(host, 0), g)
        return g - host, host

    def os_specs(self) -> dict:
        """OS stores: {"name": {"p32"|"m"|"v": {"dev": SDS, "host": SDS}}}."""
        out = {}
        for name, spec in self.store_specs().items():
            gax = 1 if name == "stem" else 2
            dev_g, host_g = self.os_split(name)
            def _with_g(n_g):
                shape = list(spec.shape)
                shape[gax] = n_g
                return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
            out[name] = {k: {"dev": _with_g(dev_g), "host": _with_g(host_g)}
                         for k in ("p32", "m", "v")}
        return out

    def os_pspecs(self) -> dict:
        out = {}
        for name, pspec in self.store_pspecs().items():
            out[name] = {k: {"dev": pspec, "host": pspec} for k in ("p32", "m", "v")}
        return out

    # ------------------------------------------------------- gather plumbing
    def _gather_tree(self, name: str, local_store, *, dtype):
        """local_store: [G,1,S] (layer or stem slice) -> param pytree with
        replicated-grad sync applied."""
        lay = self.layouts[name]
        if self.ctx.data_axis:
            flat = zero.gather_store(local_store, self.ctx.data_axis)
        else:
            flat = local_store.reshape(-1)
        params = zero.unflatten_from_flat(lay, flat, dtype=dtype)
        axes = (self.tp_axes["stem"] if name == "stem"
                else self.tp_axes["groups"][name])
        return tpmod.sync_replicated_grads(params, axes, self.ctx.model_axis,
                                           self.ctx.tp)

    def _remat(self, fn):
        if self.opt.remat == "none":
            return fn
        if self.opt.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(fn)

    # ----------------------------------------------------------- local steps
    def _loss_local(self, pstores, batch):
        """Runs inside shard_map. pstores: local stores with leading 1s."""
        model, ctx, cdtype = self.model, self.ctx, dtype_of(self.cfg.compute_dtype)
        stem = self._gather_tree("stem", pstores["stem"][0], dtype=cdtype)
        x, extras = model.embed(stem, batch)
        aux = jnp.float32(0.0)
        for g in model.groups():
            x, extras = model.between_groups(g.name, x, extras, stem, batch)
            store = pstores[g.name][0]  # [L,G,1,S]
            if self.opt.gather_policy == "layer":
                va = all_axes(ctx)
                def body(carry, layer_store, _g=g, _va=va):
                    cx, caux = carry
                    params = self._gather_tree(_g.name, layer_store, dtype=cdtype)
                    y, a = _g.apply(params, cx, extras, ctx)
                    return vary_tree((y, caux + jnp.float32(a)), _va), None
                (x, aux), _ = jax.lax.scan(self._remat(body),
                                           vary_tree((x, aux), va), store)
            else:  # "step": one gather for the whole group, then scan
                lay = self.layouts[g.name]
                if ctx.data_axis:
                    flat = zero.gather_store(store, ctx.data_axis)  # [L, G*p*S]
                else:
                    flat = store.reshape(store.shape[0], -1)
                axes = self.tp_axes["groups"][g.name]

                def unflatten_layer(fl, _lay=lay, _axes=axes):
                    params = zero.unflatten_from_flat(_lay, fl, dtype=cdtype)
                    return tpmod.sync_replicated_grads(
                        params, _axes, ctx.model_axis, ctx.tp)

                va = all_axes(ctx)
                def body2(carry, fl, _g=g, _uf=unflatten_layer, _va=va):
                    cx, caux = carry
                    y, a = _g.apply(_uf(fl), cx, extras, ctx)
                    return vary_tree((y, caux + jnp.float32(a)), _va), None
                (x, aux), _ = jax.lax.scan(self._remat(body2),
                                           vary_tree((x, aux), va), flat)
        loss = self.model.head_loss(stem, x, batch)
        # the total is replicated over the model axis (every TP rank
        # computes the full loss); on legacy jax its cotangent must carry
        # 1/tp or all gradients come out tp-times too large
        from repro.models.layers import replicated_loss_compat
        return replicated_loss_compat(loss + aux, self.ctx.tp), (loss, aux)

    def train_step_fn(self) -> Callable:
        """Returns f(pstores, osstores, batch, step) -> (pstores', os', metrics),
        to be wrapped in shard_map by the caller (see ``shard_train_step``)."""
        ctx = self.ctx

        def step(pstores, osstores, batch, step_idx):
            if self.opt.accum_steps > 1:
                loss, aux, grads = self._accum_grads(pstores, batch)
            else:
                (tot, (loss, aux)), grads = jax.value_and_grad(
                    self._loss_local, has_aux=True)(pstores, batch)
            if ctx.pod_axis:
                grads = jax.lax.psum(grads, ctx.pod_axis)
            def metric(x):
                # sum over DP axes (per-shard losses carry 1/global_tokens)
                # and mean over the model axis, whose copies are identical —
                # also types the value invariant for the P() out_spec.
                axes = all_axes(ctx)
                if not axes:
                    return x
                from repro.models.layers import vary_to
                return jax.lax.psum(vary_to(x, axes), axes) / ctx.tp

            metrics = {"loss": metric(loss), "aux_loss": metric(aux)}
            new_p, new_os = self._adam_update(pstores, osstores, grads, step_idx)
            return new_p, new_os, metrics

        return step

    def _accum_grads(self, pstores, batch):
        """Gradient accumulation over microbatches (scan over batch
        slices): activation live range shrinks by accum_steps; the loss
        already carries 1/global_tokens, so microbatch grads SUM."""
        n = self.opt.accum_steps
        va = all_axes(self.ctx)
        b_loc = batch["tokens"].shape[0]
        if b_loc % n != 0 or b_loc < n:
            raise ValueError(
                f"accum_steps={n} must divide the per-device batch {b_loc}")

        def slice_mb(i):
            def sl(x):
                if not hasattr(x, "ndim") or x.ndim == 0:
                    return x
                mb = x.shape[0] // n
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
            return {k: sl(v) for k, v in batch.items()}

        def body(carry, i):
            loss_a, aux_a, g_a = carry
            (tot, (loss, aux)), g = jax.value_and_grad(
                self._loss_local, has_aux=True)(pstores, slice_mb(i))
            g_a = jax.tree.map(jnp.add, g_a, g)
            return vary_tree((loss_a + loss, aux_a + aux, g_a), va), None

        zeros = jax.tree.map(jnp.zeros_like, pstores)
        init = vary_tree((jnp.float32(0), jnp.float32(0), zeros), va)
        (loss, aux, grads), _ = jax.lax.scan(body, init, jnp.arange(n))
        return loss, aux / n, grads

    # -------------------------------------------------------------- optimizer
    def _adam_update(self, pstores, osstores, grads, step_idx):
        """Chunked ADAM on the local shard; grad-fp16 chunks are converted
        to fp32 on the fly (Section 6.2); host-resident OS groups round-trip
        through pinned_host (device-aware placement, Section 8.2)."""
        opt = self.opt
        b1, b2 = opt.betas
        t = step_idx.astype(jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)

        def update_part(p32, m, v, g32):
            if opt.use_adam_kernel:
                from repro.kernels import ops as kops
                return kops.chunked_adam(
                    p32, m, v, g32, lr=opt.lr, beta1=b1, beta2=b2,
                    eps=opt.eps, weight_decay=opt.weight_decay,
                    bias_corr1=bc1, bias_corr2=bc2)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * (g32 * g32)
            mhat = m / bc1
            vhat = v / bc2
            upd = mhat / (jnp.sqrt(vhat) + opt.eps)
            if opt.weight_decay:
                upd = upd + opt.weight_decay * p32
            p32 = p32 - opt.lr * upd
            return p32, m, v

        new_p, new_os = {}, {}
        for name, p in pstores.items():
            gax = 1 if name == "stem" else 2
            os_n = osstores[name]
            g32 = grads[name].astype(jnp.float32)
            dev_g = os_n["p32"]["dev"].shape[gax]
            g_dev = jax.lax.slice_in_dim(g32, 0, dev_g, axis=gax)
            g_host = jax.lax.slice_in_dim(g32, dev_g, g32.shape[gax], axis=gax)
            # device-resident OS groups
            p32d, md, vd = update_part(os_n["p32"]["dev"], os_n["m"]["dev"],
                                       os_n["v"]["dev"], g_dev)
            # host-resident OS groups: fetch -> update -> evict (the compiled
            # analogue of chunk h2d/d2h moves around ADAM)
            if g_host.shape[gax] > 0:
                from repro.runtime.driver import host_memory_kind_supported
                if host_memory_kind_supported():
                    fetch = lambda x: jax.device_put(
                        x, jax.sharding.TransferToMemoryKind("device"))
                    spill = lambda x: jax.device_put(
                        x, jax.sharding.TransferToMemoryKind("pinned_host"))
                else:  # CPU backend: offload is a placement no-op
                    fetch = spill = lambda x: x
                p32h, mh, vh = update_part(fetch(os_n["p32"]["host"]),
                                           fetch(os_n["m"]["host"]),
                                           fetch(os_n["v"]["host"]), g_host)
                p32h_s, mh_s, vh_s = spill(p32h), spill(mh), spill(vh)
            else:
                p32h, mh_s, vh_s = os_n["p32"]["host"], os_n["m"]["host"], os_n["v"]["host"]
                p32h_s = p32h
            new_os[name] = {"p32": {"dev": p32d, "host": p32h_s},
                            "m": {"dev": md, "host": mh_s},
                            "v": {"dev": vd, "host": vh_s}}
            # updated param fp32 -> param fp16 chunks (next iteration's params)
            pd = p32d.astype(p.dtype)
            ph = p32h.astype(p.dtype)
            new_p[name] = jax.lax.concatenate([pd, ph], dimension=gax)
        return new_p, new_os

    # --------------------------------------------------------------- serving
    def prefill_step_fn(self) -> Callable:
        ctx, cdtype = self.ctx, dtype_of(self.cfg.compute_dtype)
        model = self.model

        def step(pstores, batch):
            stem = self._gather_tree("stem", pstores["stem"][0], dtype=cdtype)
            x, extras = model.embed(stem, batch)
            caches = {}
            for g in model.groups():
                x, extras = model.between_groups(g.name, x, extras, stem, batch)
                store = pstores[g.name][0]
                fn = g.prefill if g.prefill is not None else None

                va = all_axes(ctx)
                def body(cx, layer_store, _g=g, _fn=fn, _va=va):
                    params = self._gather_tree(_g.name, layer_store, dtype=cdtype)
                    if _fn is None:
                        y, _ = _g.apply(params, cx, extras, ctx)
                        return vary_tree(y, _va), 0
                    y, cache = _fn(params, cx, extras, ctx)
                    return vary_tree(y, _va), vary_tree(cache, _va)
                x, ys = jax.lax.scan(body, vary_tree(x, va), store)
                if fn is not None:
                    # add the leading tp dim so caches match the global
                    # [tp, L, B, ...] convention
                    caches[g.name] = jax.tree.map(lambda t: t[None], ys)
            logits = model.head_logits(stem, x[:, -1:, :])
            return logits, caches

        return step

    def round_prefill_step_fn(self) -> Callable:
        """Batched prefill over one admission cohort: ``vmap`` of a
        per-sequence prefill pass over stacked prompt rows.

        ``tokens``: [K, S_prompt] int32.  Returns ``(first_tokens [K],
        caches)`` where every cache leaf is [tp, L, K, ...per-seq...] —
        lane-stacked single-sequence caches, NOT a batched cache.  The
        lane layout is what makes the round step arch-agnostic: archs
        whose caches don't lead with the batch dim (zamba's stacked
        per-unit mamba states) vmap exactly like dense attention, and a
        lane's math is bit-identical to a batch-1 eager prefill (MoE
        capacity, which depends on token count, sees one sequence)."""
        ctx, cdtype = self.ctx, dtype_of(self.cfg.compute_dtype)
        model = self.model

        def step(pstores, tokens):
            stem = self._gather_tree("stem", pstores["stem"][0], dtype=cdtype)

            def lane(row):
                batch = {"tokens": row[None, :]}
                x, extras = model.embed(stem, batch)
                caches = {}
                for g in model.groups():
                    x, extras = model.between_groups(
                        g.name, x, extras, stem, batch)
                    store = pstores[g.name][0]

                    def body(cx, layer_store, _g=g):
                        params = self._gather_tree(
                            _g.name, layer_store, dtype=cdtype)
                        y, cache = _g.prefill(params, cx, extras, ctx)
                        return y, cache
                    x, ys = jax.lax.scan(body, x, store)
                    caches[g.name] = ys
                logits = model.head_logits(stem, x[:, -1:, :])
                return greedy_token(logits, self.cfg.vocab_size, ctx), caches

            toks, caches = jax.vmap(lane, in_axes=0, out_axes=(0, 1))(tokens)
            # [K, 1] -> [K]; re-add the leading tp dim ([tp, L, K, ...])
            return toks[:, 0], jax.tree.map(lambda t: t[None], caches)

        return step

    def round_decode_step_fn(self) -> Callable:
        """One compiled continuous-batching decode step over padded
        active-sequence slots.

        ``tokens``: [S_slots, 1] int32, ``pos``: [S_slots] int32 (the
        position-vector decode signature: every slot advances from its
        own position in ONE call).  Cache leaves are [tp, L, S_slots,
        ...per-seq...].  Each slot is an independent ``vmap`` lane, so
        free/stale slots decode garbage that cannot leak into live lanes
        — the host simply ignores their tokens, and a re-bound slot's
        rows are fully overwritten by the next prefill scatter."""
        ctx, cdtype = self.ctx, dtype_of(self.cfg.compute_dtype)
        model = self.model

        def step(pstores, caches, tokens, pos):
            stem = self._gather_tree("stem", pstores["stem"][0], dtype=cdtype)

            def lane(lane_caches, token, p):
                x = model.embed_decode(stem, token[None], p, None)
                extras = model.decode_extras(stem, x)
                new_caches = {}
                for g in model.groups():
                    if g.decode is None:
                        continue
                    store = pstores[g.name][0]

                    def body(cx, inp, _g=g):
                        layer_store, layer_cache = inp
                        params = self._gather_tree(
                            _g.name, layer_store, dtype=cdtype)
                        y, c2 = _g.decode(params, cx, layer_cache, p,
                                          extras, ctx)
                        return y, c2
                    x, ys = jax.lax.scan(body, x, (store, lane_caches[g.name]))
                    new_caches[g.name] = ys
                logits = model.head_logits(stem, x)
                return greedy_token(logits, self.cfg.vocab_size, ctx), new_caches

            lane_in = jax.tree.map(lambda t: t[0], caches)  # strip tp dim
            toks, new_caches = jax.vmap(
                lane, in_axes=(1, 0, 0), out_axes=(0, 1))(lane_in, tokens, pos)
            return toks[:, 0], jax.tree.map(lambda t: t[None], new_caches)

        return step

    def decode_step_fn(self) -> Callable:
        ctx, cdtype = self.ctx, dtype_of(self.cfg.compute_dtype)
        model = self.model

        def step(pstores, caches, token, pos):
            stem = self._gather_tree("stem", pstores["stem"][0], dtype=cdtype)
            x = model.embed_decode(stem, token, pos, None)
            extras = model.decode_extras(stem, x)
            new_caches = {}
            for g in model.groups():
                if g.decode is None:
                    continue
                store = pstores[g.name][0]
                cache = jax.tree.map(lambda t: t[0], caches[g.name])  # strip tp dim

                # NOTE: scanning over (store, cache) double-buffers the
                # cache (xs in + ys out) in the XLA:CPU memory analysis;
                # on TPU, loop in/out buffer donation elides one copy —
                # see EXPERIMENTS.md §Dry-run "cache-adjusted fit".
                def body(cx, inp, _g=g):
                    layer_store, layer_cache = inp
                    params = self._gather_tree(_g.name, layer_store, dtype=cdtype)
                    y, c2 = _g.decode(params, cx, layer_cache, pos, extras, ctx)
                    return y, c2
                x, ys = jax.lax.scan(body, x, (store, cache))
                new_caches[g.name] = jax.tree.map(lambda t: t[None], ys)
            logits = model.head_logits(stem, x)
            next_tok = greedy_token(logits, self.cfg.vocab_size, ctx)
            return next_tok, new_caches

        return step
