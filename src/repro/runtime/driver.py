"""shard_map/jit wrappers, state init, and input specs for the runtime.

This is the layer the launcher and the dry-run call: it turns the local
step functions from ``runtime.step`` into jitted global-array functions
with explicit NamedShardings (including ``pinned_host`` memory kinds for
host-resident optimizer-state chunk groups).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, dtype_of
from repro.core import zero
from repro.models.layers import shard_map_compat as _shard_map
from repro.runtime.step import ChunkedRuntime


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def batch_axes(rt: ChunkedRuntime, global_batch: int):
    """Mesh axes the batch dim shards over (must divide evenly)."""
    axes = []
    n = 1
    if rt.ctx.pods > 1 and global_batch % (rt.ctx.pods * rt.ctx.dp) == 0:
        axes.append("pod")
        n *= rt.ctx.pods
    if rt.ctx.dp > 1 and global_batch % (n * rt.ctx.dp) == 0:
        axes.append("data")
    if not axes:
        return None  # replicate (e.g. batch=1 long-context decode)
    return tuple(axes)


@functools.lru_cache(maxsize=1)
def host_memory_kind_supported() -> bool:
    """Whether this backend can place jit outputs in pinned_host memory.

    True on TPU; False on the CPU backend (XLA:CPU lacks the
    annotate_device_placement custom call), where host-offloaded OS chunk
    groups fall back to device placement — the placement *policy* and its
    group split still lower and are what the roofline reads.
    """
    try:
        s = jax.sharding.SingleDeviceSharding(
            jax.devices()[0], memory_kind="pinned_host")
        jax.jit(lambda: jnp.zeros((8,), jnp.float32), out_shardings=s)()
        return True
    except Exception:
        return False


def _ns(rt, spec, *, host=False):
    kw = {"memory_kind": "pinned_host"} if host and host_memory_kind_supported() else {}
    return NamedSharding(rt.mesh, spec, **kw)


def os_shardings(rt: ChunkedRuntime):
    out = {}
    for name, pspec in rt.store_pspecs().items():
        out[name] = {k: {"dev": _ns(rt, pspec),
                         "host": _ns(rt, pspec, host=True)}
                     for k in ("p32", "m", "v")}
    return out


def param_shardings(rt: ChunkedRuntime):
    return {name: _ns(rt, pspec) for name, pspec in rt.store_pspecs().items()}


# ---------------------------------------------------------------------------
# input specs per (arch, input shape)  — ShapeDtypeStructs, no allocation
# ---------------------------------------------------------------------------


def train_batch_specs(rt: ChunkedRuntime, shape: InputShape):
    cfg = rt.cfg
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes(rt, b)
    tok = lambda shp: jax.ShapeDtypeStruct(shp, jnp.int32)
    if cfg.arch_type == "audio":
        frames = min(cfg.encoder_frames, s)
        specs = {
            "frames": jax.ShapeDtypeStruct(
                (b, frames, cfg.frontend_dim), jnp.float32),
            "tokens": tok((b, s)), "labels": tok((b, s)),
        }
        pspecs = {"frames": P(ba, None, None),
                  "tokens": P(ba, None), "labels": P(ba, None)}
        n_tokens = b * s
    elif cfg.arch_type == "vlm":
        p_ = cfg.num_patches
        st = s - p_
        specs = {
            "patch_embeds": jax.ShapeDtypeStruct((b, p_, cfg.vision_dim), jnp.float32),
            "tokens": tok((b, st)), "labels": tok((b, st)),
        }
        pspecs = {"patch_embeds": P(ba, None, None),
                  "tokens": P(ba, None), "labels": P(ba, None)}
        n_tokens = b * st
    else:
        specs = {"tokens": tok((b, s)), "labels": tok((b, s))}
        pspecs = {"tokens": P(ba, None), "labels": P(ba, None)}
        n_tokens = b * s
    specs["global_tokens"] = jax.ShapeDtypeStruct((), jnp.float32)
    pspecs["global_tokens"] = P()
    return specs, pspecs, float(n_tokens)


def cache_specs(rt: ChunkedRuntime, shape: InputShape):
    """Global decode-cache ShapeDtypeStructs + PartitionSpecs.

    Layout: [tp, L, B, ...] — tp shards over model, B over (pod, data).
    """
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes(rt, b)
    tp = rt.ctx.tp
    specs, pspecs = {}, {}
    for g in rt.model.groups():
        if g.init_cache is None or g.decode is None:
            continue
        one = jax.eval_shape(lambda: g.init_cache(b, s))
        L = g.length

        def to_global(sds):
            return jax.ShapeDtypeStruct((tp, L) + sds.shape, sds.dtype)

        def to_pspec(sds):
            # locate the batch dim (hybrid/xlstm caches carry extra
            # leading stacked dims before it); shard it over (pod, data)
            dims = [None] * len(sds.shape)
            if ba is not None:
                for i, d in enumerate(sds.shape):
                    if d == b:
                        dims[i] = ba
                        break
            return P("model", None, *dims)

        specs[g.name] = jax.tree.map(to_global, one)
        pspecs[g.name] = jax.tree.map(to_pspec, one)
    return specs, pspecs


def decode_input_specs(rt: ChunkedRuntime, shape: InputShape):
    b = shape.global_batch
    ba = batch_axes(rt, b)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    caches, cache_ps = cache_specs(rt, shape)
    return {
        "token": (token, P(ba, None)),
        "pos": (jax.ShapeDtypeStruct((), jnp.int32), P()),
        "caches": (caches, cache_ps),
    }


# ---------------------------------------------------------------------------
# jitted global-step builders
# ---------------------------------------------------------------------------


def _smap(rt, fn, in_specs, out_specs, *, check_vma=True):
    # check_vma=True is required for correct psum/pvary gradient
    # transposes in training; serve paths (no autodiff) run with it off,
    # since batch-replicated decode (global_batch=1) produces values that
    # are invariant in fact but typed varying.
    return _shard_map(fn, mesh=rt.mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=check_vma)


def build_train_step(rt: ChunkedRuntime, shape: InputShape):
    """-> (jitted step, arg ShapeDtypeStructs, arg shardings)."""
    step = rt.train_step_fn()
    bspecs, bpspecs, _ = train_batch_specs(rt, shape)
    p_ps = rt.store_pspecs()
    os_ps = rt.os_pspecs()
    metrics_ps = {"loss": P(), "aux_loss": P()}
    f = _smap(rt, step, (p_ps, os_ps, bpspecs, P()),
              (p_ps, os_ps, metrics_ps))
    in_shardings = (param_shardings(rt), os_shardings(rt),
                    jax.tree.map(lambda ps: _ns(rt, ps), bpspecs,
                                 is_leaf=lambda x: isinstance(x, P)),
                    _ns(rt, P()))
    out_shardings = (param_shardings(rt), os_shardings(rt),
                     jax.tree.map(lambda ps: _ns(rt, ps), metrics_ps,
                                  is_leaf=lambda x: isinstance(x, P)))
    jf = jax.jit(f, in_shardings=in_shardings, out_shardings=out_shardings,
                 donate_argnums=(0, 1))
    args = (rt.store_specs(), rt.os_specs(), bspecs,
            jax.ShapeDtypeStruct((), jnp.int32))
    return jf, args, in_shardings


def build_prefill_step(rt: ChunkedRuntime, shape: InputShape):
    step = rt.prefill_step_fn()
    cfg = rt.cfg
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes(rt, b)
    if cfg.arch_type == "audio":
        frames = min(cfg.encoder_frames, 1500)
        bspecs = {"frames": jax.ShapeDtypeStruct((b, frames, cfg.frontend_dim),
                                                 jnp.float32),
                  "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        bpspecs = {"frames": P(ba, None, None), "tokens": P(ba, None)}
    elif cfg.arch_type == "vlm":
        bspecs = {"patch_embeds": jax.ShapeDtypeStruct(
                      (b, cfg.num_patches, cfg.vision_dim), jnp.float32),
                  "tokens": jax.ShapeDtypeStruct((b, s - cfg.num_patches), jnp.int32)}
        bpspecs = {"patch_embeds": P(ba, None, None), "tokens": P(ba, None)}
    else:
        bspecs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        bpspecs = {"tokens": P(ba, None)}
    _, cache_ps = cache_specs(rt, shape)
    p_ps = rt.store_pspecs()
    logits_ps = P(ba, None, "model")
    f = _smap(rt, step, (p_ps, bpspecs), (logits_ps, cache_ps),
              check_vma=False)
    jf = jax.jit(f, in_shardings=(param_shardings(rt),
                                  jax.tree.map(lambda ps: _ns(rt, ps), bpspecs,
                                               is_leaf=lambda x: isinstance(x, P))))
    return jf, (rt.store_specs(), bspecs)


def build_decode_step(rt: ChunkedRuntime, shape: InputShape):
    step = rt.decode_step_fn()
    di = decode_input_specs(rt, shape)
    b = shape.global_batch
    ba = batch_axes(rt, b)
    p_ps = rt.store_pspecs()
    cache_ps = di["caches"][1]
    f = _smap(rt, step,
              (p_ps, cache_ps, di["token"][1], P()),
              (P(ba), cache_ps), check_vma=False)
    in_sh = (param_shardings(rt),
             jax.tree.map(lambda ps: _ns(rt, ps), cache_ps,
                          is_leaf=lambda x: isinstance(x, P)),
             _ns(rt, di["token"][1]), _ns(rt, P()))
    jf = jax.jit(f, in_shardings=in_sh, donate_argnums=(1,))
    args = (rt.store_specs(), di["caches"][0], di["token"][0], di["pos"][0])
    return jf, args


def round_cache_specs(rt: ChunkedRuntime, slots: int, horizon: int):
    """Slot-cache ShapeDtypeStructs + PartitionSpecs for the compiled
    serving round.

    Layout: [tp, L, S_slots, ...per-seq cache...] — every leaf is the
    lane-stacked single-sequence cache (batch dim 1 *inside* the per-seq
    shape, wherever the arch puts it), so the same layout serves archs
    with non-batch-leading cache leaves.  The slot axis is replicated:
    serving runs host-driven, one process.
    """
    tp = rt.ctx.tp
    specs, pspecs = {}, {}
    for g in rt.model.groups():
        if g.init_cache is None or g.decode is None:
            continue
        one = jax.eval_shape(lambda _g=g: _g.init_cache(1, horizon))
        L = g.length
        specs[g.name] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((tp, L, slots) + s.shape, s.dtype),
            one)
        pspecs[g.name] = jax.tree.map(
            lambda s: P("model", None, None, *([None] * len(s.shape))), one)
    return specs, pspecs


def build_round_decode_step(rt: ChunkedRuntime, slots: int, horizon: int):
    """-> (jitted round decode step, slot-cache ShapeDtypeStructs).

    ``step(pstores, caches, tokens [S,1], pos [S]) -> (tokens [S],
    caches)`` — ONE compiled call advances every padded slot from its own
    position.  Compilation keys only on the padded slot count (and
    horizon): membership changes within a padded shape never recompile.
    """
    step = rt.round_decode_step_fn()
    specs, cache_ps = round_cache_specs(rt, slots, horizon)
    p_ps = rt.store_pspecs()
    f = _smap(rt, step, (p_ps, cache_ps, P(None, None), P(None)),
              (P(None), cache_ps), check_vma=False)
    jf = jax.jit(f, donate_argnums=(1,))
    return jf, specs


def build_round_prefill_step(rt: ChunkedRuntime, cohort: int, prompt_len: int):
    """-> jitted cohort prefill: ``step(pstores, tokens [K, S_prompt]) ->
    (first_tokens [K], caches)`` with lane-stacked cache leaves
    [tp, L, K, ...].  Compilation keys on (padded cohort, prompt length)."""
    step = rt.round_prefill_step_fn()
    # prefill emits the same cache *structure* as init_cache with
    # prompt-length-dependent leaf shapes; the P specs only need ranks,
    # which match the init template leaf for leaf
    _, cache_ps = round_cache_specs(rt, cohort, prompt_len)
    p_ps = rt.store_pspecs()
    f = _smap(rt, step, (p_ps, P(None, None)), (P(None), cache_ps),
              check_vma=False)
    return jax.jit(f)


def slot_page_range(slot: int, total_layers: int,
                    pages_per_slot: int) -> range:
    """Chunk-id range padded batch slot ``slot`` pins its kv pages into:
    ``pages_per_slot`` ids per flattened layer, slots laid out
    contiguously.  With one page per slot (unpaged horizon) this is the
    historical ``[slot*total_layers, (slot+1)*total_layers)`` binding."""
    w = total_layers * pages_per_slot
    return range(slot * w, (slot + 1) * w)


def slot_page_chunk_id(slot: int, total_layers: int, pages_per_slot: int,
                       flat_layer: int, page: int) -> int:
    """Chunk id of one (slot, layer, page) kv tensor inside
    :func:`slot_page_range` — layer-major, page-minor, so a layer's pages
    are contiguous."""
    return (slot * total_layers * pages_per_slot
            + flat_layer * pages_per_slot + page)


# ---------------------------------------------------------------------------
# state init (for real runs — examples / integration tests)
# ---------------------------------------------------------------------------


def init_state(rt: ChunkedRuntime, key):
    """Materialize param + optimizer-state chunk stores on the mesh."""
    ctx = rt.ctx

    def local_init(key):
        # Sharded leaves draw per-model-rank randomness (their shards are
        # disjoint pieces of one logical tensor); REPLICATED leaves must
        # be bitwise identical across model ranks (router, MLA latent
        # projections, replicated kv, ...) — init both ways, select by
        # tp_axes.
        params_rank = rt.model.init_params(
            jax.random.fold_in(key, ctx.model_rank()))
        params_shared = rt.model.init_params(key)

        def select(axes, ranked, shared):
            return jax.tree.map(
                lambda ax, a, b: b if ax is None else a,
                axes, ranked, shared, is_leaf=lambda x: x is None)

        params = {"stem": select(rt.tp_axes["stem"], params_rank["stem"],
                                 params_shared["stem"]),
                  "groups": {g.name: select(rt.tp_axes["groups"][g.name],
                                            params_rank["groups"][g.name],
                                            params_shared["groups"][g.name])
                             for g in rt.model.groups()}}
        drank = (jax.lax.axis_index(ctx.data_axis)
                 if ctx.data_axis and ctx.dp > 1 else 0)
        pstores = {}
        stem_store = zero.flatten_to_store(rt.layouts["stem"], params["stem"])
        pstores["stem"] = jax.lax.dynamic_slice_in_dim(
            stem_store, drank, 1, axis=1)[None]
        for g in rt.model.groups():
            lay = rt.layouts[g.name]
            stacked = params["groups"][g.name]
            store = jax.vmap(lambda t, _l=lay: zero.flatten_to_store(_l, t))(stacked)
            pstores[g.name] = jax.lax.dynamic_slice_in_dim(
                store, drank, 1, axis=2)[None]
        osstores = {}
        for name, p in pstores.items():
            gax = 1 if name == "stem" else 2
            dev_g, host_g = rt.os_split(name)
            p32 = p.astype(jnp.float32)
            zeros = jnp.zeros_like(p32)
            # local stores keep the global rank ([1(tp), ..., G, 1, S]),
            # so the G axis index matches the global one
            sl = lambda x, a, b: jax.lax.slice_in_dim(x, a, b, axis=gax)
            osstores[name] = {
                "p32": {"dev": sl(p32, 0, dev_g), "host": sl(p32, dev_g, dev_g + host_g)},
                "m": {"dev": sl(zeros, 0, dev_g), "host": sl(zeros, dev_g, dev_g + host_g)},
                "v": {"dev": sl(zeros, 0, dev_g), "host": sl(zeros, dev_g, dev_g + host_g)},
            }
        return pstores, osstores

    p_ps = rt.store_pspecs()
    os_ps = rt.os_pspecs()
    f = _smap(rt, local_init, (P(),), (p_ps, os_ps))
    jf = jax.jit(f, out_shardings=(param_shardings(rt), os_shardings(rt)))
    return jf(key)


def init_caches(rt: ChunkedRuntime, shape: InputShape):
    """Materialize zero-filled decode caches (for real decode runs)."""
    specs, pspecs = cache_specs(rt, shape)
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes(rt, b) or ()
    shard = 1
    for a in ba:
        shard *= rt.mesh.shape[a]
    b_local = b // shard

    def make():
        out = {}
        for g in rt.model.groups():
            if g.name not in specs:
                continue
            one = g.init_cache(b_local, s)
            L = g.length
            out[g.name] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (L,) + t.shape)[None], one)
        return out

    jf = jax.jit(_smap_nullary(rt, make, pspecs))
    return jf()


def _smap_nullary(rt, fn, out_specs):
    def wrapper(dummy):
        return fn()
    return functools.partial(
        _shard_map(wrapper, mesh=rt.mesh, in_specs=(P(),),
                   out_specs=out_specs, check_vma=True),
        jnp.zeros((), jnp.int32))


def grow_caches(rt: ChunkedRuntime, caches, prefill_len: int, horizon: int,
                decode_shape: InputShape):
    """Pad prefill-emitted caches to a decode horizon.

    Distributed caches use STRIDED slot ownership (slot s -> rank
    s % seq_shards at local index s // seq_shards), so growing the horizon
    is a pure local pad along the per-rank slot axis — no cross-rank
    reshuffle.  State-style caches (SSM/mLSTM, no slot axis) pass through
    untouched: their shapes are horizon-independent.
    """
    target, _ = cache_specs(rt, decode_shape)

    def pad(cur, tgt):
        if cur.shape == tgt.shape:
            return cur
        pads = []
        for a, b in zip(cur.shape, tgt.shape):
            if b < a:
                raise ValueError(f"cannot shrink cache {cur.shape}->{tgt.shape}")
            pads.append((0, b - a))
        return jnp.pad(cur, pads)

    return jax.tree.map(pad, caches, target,
                        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(
                            x, jax.ShapeDtypeStruct))
