"""Compiled serving plane: the continuous-batching round lowered to the
``runtime/`` shard_map path.

:class:`CompiledServingEngine` keeps the ENTIRE host-side brain of the
eager :class:`~repro.core.serving.ServingEngine` — admission, per-round
reference-sequence planning, OPT eviction moments,
:class:`~repro.core.memory.SchedulePrefetcher` staging and
:class:`~repro.core.timeline.TransferTimeline` accounting — and replaces
only the *compute*: one jit-compiled **round decode step** over padded
active-sequence slots plus one compiled **cohort prefill** per admission
cohort, instead of per-layer eager dispatch.  This is the paper's thesis
applied to serving: chunk orchestration decisions live on the host
between rounds; the device runs dense, uninterrupted compute.

Slot model
----------
Active sequences bind to **padded batch slots**.  Slot caches are
persistent jax arrays with leaves ``[tp, L, S_slots, ...per-seq...]``
(lane-stacked single-sequence caches, see
:func:`~repro.runtime.driver.round_cache_specs`); the padded slot count
grows in powers of two and never shrinks, so the round decode step
recompiles only when the concurrency high-water mark crosses a power of
two — membership changes within a padded shape NEVER recompile.  Slot
``s`` also pins its kv pages to the fixed chunk-id range
:func:`~repro.runtime.driver.slot_page_range` — ``pages_per_slot`` ids
per flattened layer; with the unpaged whole-horizon stream this is the
historical ``[s*total_layers, (s+1)*total_layers)`` binding — and the
range is *reserved* in the :class:`~repro.core.chunk.DynamicChunkMap`
at bind time, so a paged sequence's late-appended pages land on their
precomputed ids and default allocation can never collide with a live
slot's range.  Re-binding a slot to a new sequence reuses the same
chunks.

Round ordering
--------------
Each round runs the compiled decode step over ALL padded slots *before*
writing the round's prefill rows.  Free, stale, and newly-bound slots
decode garbage — harmlessly: every slot is an independent ``vmap`` lane
(nothing leaks across lanes, MoE capacity included), the host ignores
their tokens, and a newly bound slot's rows are fully overwritten by the
prefill scatter before that slot's first real decode.  No in-graph
active mask is needed, so the compiled graph is membership-independent.

Plan boundary
-------------
The pool is the repo's memory *model*: payload traffic, OPT eviction,
prefetch and timeline stalls are replayed against the exact op order the
plan registered (``_replay_round_ops`` mirrors the eager engine's
access/release choreography), while the authoritative cache bytes live
in the slot arrays — exactly how the eager trainer anchors
``ChunkedRuntime``.  Token parity with the eager engine is exact; the
eager engine remains the semantics oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serving import ServeRequest, ServingEngine
from repro.core.state import TensorState
from repro.models.layers import AxisCtx

_MIN_SLOTS = 2  # smallest padded shape (avoids a recompile at 1 -> 2)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


class CompiledServingEngine(ServingEngine):
    """Continuous batching with compiled round steps over padded slots."""

    def __init__(self, model_cls, cfg, *, seed: int = 0,
                 init_params=None, **kw):
        if not kw.get("manage_kv", True):
            raise ValueError(
                "CompiledServingEngine serves the managed kv stream; use "
                "the eager ServingEngine for the unmanaged baseline")
        if init_params is None:
            # same ctx + key as the base engine: both planes must start
            # from bitwise-identical parameters
            init_params = model_cls(cfg, AxisCtx()).init_params(
                jax.random.key(seed))
        super().__init__(model_cls, cfg, seed=seed, init_params=init_params,
                         **kw)

        from repro.core import zero
        from repro.launch.mesh import make_smoke_mesh
        from repro.runtime.step import ChunkedRuntime, RuntimeOptions

        self._rt = ChunkedRuntime(model_cls, cfg, make_smoke_mesh(1, 1),
                                  RuntimeOptions())
        pstores = {}
        for name, lay in self._rt.layouts.items():
            if name == "stem":
                pstores[name] = zero.flatten_to_store(
                    lay, init_params["stem"])[None]
            else:
                stacked = init_params["groups"][name]
                pstores[name] = jax.vmap(
                    lambda t, _l=lay: zero.flatten_to_store(_l, t))(
                        stacked)[None]
        self._pstores = pstores

        # slot <-> request binding (slot index is also the chunk-id base)
        self._slots: list[int | None] = []
        self._slot_of: dict[int, int] = {}
        self._padded = 0
        self._slot_caches = None  # {gname: tree [tp, L, S_slots, ...]}
        # compiled-step caches: recompilation keys only on padded shapes
        self._decode_steps: dict[int, object] = {}
        self._prefill_steps: dict[tuple[int, int], object] = {}

    # ------------------------------------------------------------- compiles
    @property
    def decode_compile_count(self) -> int:
        """How many distinct padded slot shapes the round decode step has
        compiled for (the recompilation-policy observable)."""
        return len(self._decode_steps)

    @property
    def prefill_compile_count(self) -> int:
        return len(self._prefill_steps)

    @property
    def padded_slots(self) -> int:
        return self._padded

    # ---------------------------------------------------------------- slots
    def _bind_slot(self, rid: int) -> int:
        for s, r in enumerate(self._slots):
            if r is None:
                self._slots[s] = rid
                self._slot_of[rid] = s
                return s
        self._slots.append(rid)
        self._slot_of[rid] = len(self._slots) - 1
        return len(self._slots) - 1

    def _map_request_kv(self, req: ServeRequest) -> None:
        """Bind the request to the lowest free slot and reserve the
        slot's page-id range — every page the sequence will ever map
        (prompt pages now, decode-appended pages later) lands at its
        precomputed id, so admission churn re-walks the same chunk ids
        and nothing about the pool layout (or any compiled shape)
        depends on WHICH sequences are live."""
        from repro.runtime import driver

        slot = self._bind_slot(req.rid)
        self.kv_mgr.cmap.reserve_ids(driver.slot_page_range(
            slot, self._total_layers, self._pages_per_seq))
        super()._map_request_kv(req)

    def _map_page(self, rid: int, gname: str, layer: int, page: int) -> None:
        from repro.runtime import driver

        cid = driver.slot_page_chunk_id(
            self._slot_of[rid], self._total_layers, self._pages_per_seq,
            self._flat_layer[(gname, layer)], page)
        self.kv_mgr.add_tensor(
            self._kv_name(rid, gname, layer, page),
            (self._kv_chunk_elems,), chunk_id=cid)

    def _retire_finished(self) -> int:
        done = [r.rid for r in self._active
                if len(r.generated) >= r.max_new_tokens]
        n = super()._retire_finished()
        for rid in done:
            slot = self._slot_of.pop(rid)
            self._slots[slot] = None  # stale rows overwritten on re-bind
        return n

    def _prefill_batchable(self) -> bool:
        # compiled prefill vmaps independent per-sequence lanes: cohorts
        # need no batch-leading cache leaves and never batch MoE routing
        return True

    def _ensure_slot_capacity(self) -> None:
        need = len(self._slots)
        s = max(_MIN_SLOTS, _next_pow2(need))
        if self._slot_caches is not None and s <= self._padded:
            return
        from repro.runtime import driver

        if self._slot_caches is None:
            specs, _ = driver.round_cache_specs(
                self._rt, s, self.max_seq_len)
            self._slot_caches = jax.tree.map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype), specs)
        else:
            grow = lambda t: jnp.pad(
                t, [(0, 0), (0, 0), (0, s - self._padded)]
                + [(0, 0)] * (t.ndim - 3))
            self._slot_caches = jax.tree.map(grow, self._slot_caches)
        self._padded = s

    # ------------------------------------------------------ compiled phases
    def _compiled_decode(self, decode_reqs) -> None:
        from repro.runtime import driver

        fn = self._decode_steps.get(self._padded)
        if fn is None:
            fn, _ = driver.build_round_decode_step(
                self._rt, self._padded, self.max_seq_len)
            self._decode_steps[self._padded] = fn
        tokens = np.zeros((self._padded, 1), np.int32)
        pos = np.zeros((self._padded,), np.int32)
        for r in decode_reqs:
            s = self._slot_of[r.rid]
            tokens[s, 0] = r.generated[-1]
            pos[s] = r.pos
        toks, self._slot_caches = fn(
            self._pstores, self._slot_caches,
            jnp.asarray(tokens), jnp.asarray(pos))
        toks = np.asarray(toks)
        for r in decode_reqs:
            r.generated.append(int(toks[self._slot_of[r.rid]]))
            r.pos += 1
            self.total_decode_tokens += 1

    def _compiled_prefill(self, cohort) -> None:
        from repro.runtime import driver

        k = len(cohort)
        sp = int(cohort[0].prompt.size)
        kpad = _next_pow2(k)
        fn = self._prefill_steps.get((kpad, sp))
        if fn is None:
            fn = driver.build_round_prefill_step(self._rt, kpad, sp)
            self._prefill_steps[(kpad, sp)] = fn
        rows = np.stack([r.prompt for r in cohort]
                        + [cohort[0].prompt] * (kpad - k))
        toks, caches = fn(self._pstores, jnp.asarray(rows))
        toks = np.asarray(toks)

        # pad each lane's prefill cache to the decode-horizon template
        # and scatter the real rows into their slots (padding lanes are
        # dropped — they only exist to keep the compiled shape pow2)
        idx = jnp.asarray([self._slot_of[r.rid] for r in cohort])
        for gname, tree in caches.items():
            tmpl_shapes = self._cache_tmpl[gname][1]
            dst, dtd = jax.tree_util.tree_flatten(self._slot_caches[gname])
            src = jax.tree_util.tree_leaves(tree)
            out = []
            for d, sl, t in zip(dst, src, tmpl_shapes):
                pads = [(0, 0)] * 3 + [(0, b - a)
                                       for a, b in zip(sl.shape[3:], t)]
                row = jnp.pad(sl, pads)[:, :, :k].astype(d.dtype)
                out.append(d.at[:, :, idx].set(row))
            self._slot_caches[gname] = jax.tree_util.tree_unflatten(dtd, out)
        for j, r in enumerate(cohort):
            r.pos = int(r.prompt.size)
            r.generated.append(int(toks[j]))
            self.total_prefill_tokens += int(r.prompt.size)

    # --------------------------------------------------------- pool replay
    def _replay_round_ops(self, cohorts, decode_reqs) -> None:
        """Walk the planned op order against the pool — the same
        access/release choreography the eager engine performs around its
        compute, so chunk placement, h2d/d2h traffic, OPT eviction,
        prefetch staging and timeline stalls evolve under the identical
        reference sequence.  Payload *contents* are not written: the
        authoritative cache bytes live in the slot arrays; the pool is
        the placement/traffic model (as it is for the compiled trainer)."""
        for cohort in cohorts:
            for g in self._decode_groups:
                for i in range(g.length):
                    self._begin_op(("param", g.name, i))
                    names = self._group_tensor_names[g.name][i]
                    for n in names:
                        self.params_mgr.access_tensor(n, "device")
                    self._release_layer(names)
                    for req in cohort:
                        for p in range(self._req_pages[req.rid]):
                            name = self._kv_name(req.rid, g.name, i, p)
                            self._begin_op(("kv", req.rid, g.name, i, p))
                            self.kv_mgr.access_tensor(name, "device")
                            self.kv_mgr.release_tensor(
                                name, TensorState.HOLD)
        if decode_reqs:
            for g in self._decode_groups:
                for i in range(g.length):
                    self._begin_op(("param", g.name, i))
                    names = self._group_tensor_names[g.name][i]
                    for n in names:
                        self.params_mgr.access_tensor(n, "device")
                    # params stay COMPUTE-pinned while the kv chunks
                    # cycle under them, exactly like the eager sweep
                    for req in decode_reqs:
                        for p in range(self._req_pages[req.rid]):
                            name = self._kv_name(req.rid, g.name, i, p)
                            self._begin_op(("kv", req.rid, g.name, i, p))
                            self.kv_mgr.access_tensor(name, "device")
                            self.kv_mgr.release_tensor(
                                name, TensorState.HOLD)
                    self._release_layer(names)

    # ----------------------------------------------------------- the round
    def _execute_round(self, cohorts, batches) -> None:
        """Compiled round: decode ALL padded slots from their pre-prefill
        caches (one jitted call), then prefill this round's admission
        cohorts and scatter their rows, then replay the plan against the
        pool.  Compute order differs from the plan's (prefill-first) op
        order on purpose — the plan order only drives the memory model,
        and decoding before the prefill scatter is what makes free-slot
        garbage harmless."""
        self._ensure_slot_capacity()
        decode_reqs = [r for b in batches for r in b]
        tel = self.pool.telemetry
        if tel is not None:
            # compiled rounds split into two phases: the jitted compute
            # (decode + prefill) and the pool replay that walks the
            # planned op order — the replay is where every move/eviction
            # event of the round is emitted.
            tel.begin_span(self.tenant.qualify("compiled"), "compute",
                           ts=self.pool._now(), tenant=self.tenant.name,
                           rank=self.pool.telemetry_rank)
        if decode_reqs:
            self._compiled_decode(decode_reqs)
        for cohort in cohorts:
            self._compiled_prefill(cohort)
        tel = self.pool.telemetry
        if tel is not None:
            tel.switch_span(self.tenant.qualify("compiled"), "replay",
                            ts=self.pool._now(), tenant=self.tenant.name,
                            rank=self.pool.telemetry_rank)
        self._replay_round_ops(cohorts, decode_reqs)
        tel = self.pool.telemetry
        if tel is not None:
            tel.close_span(self.tenant.qualify("compiled"),
                           ts=self.pool._now(),
                           rank=self.pool.telemetry_rank)
