"""Chunk-granular checkpointing.

The chunk store IS the checkpoint format: each (stream, store) pair is
one .npy per host plus a JSON manifest recording the chunk layouts, so a
restore can remap chunks onto a different ZeRO degree (re-chunking via
``zero.unflatten -> flatten`` with the target layout).  Optimizer state
(p32/m/v) rides along, preserving exact training state.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zero


def _manifest(rt) -> dict:
    return {
        "cfg": dataclasses.asdict(rt.cfg),
        "layouts": {
            name: {
                "chunk_size": lay.chunk_size,
                "nproc": lay.nproc,
                "num_groups": lay.num_groups,
                "names": list(lay.names),
                "shapes": [list(s) for s in lay.shapes],
            }
            for name, lay in rt.layouts.items()
        },
        "mesh": {k: int(v) for k, v in rt.mesh.shape.items()},
        "step": None,
    }


def _np_save(path: pathlib.Path, arr) -> str:
    """numpy lacks bfloat16: persist as a uint16 view + dtype tag."""
    raw = np.asarray(jax.device_get(arr))
    if raw.dtype == jnp.bfloat16:
        np.save(path, raw.view(np.uint16))
        return "bfloat16"
    np.save(path, raw)
    return str(raw.dtype)


def _np_load(path: pathlib.Path, dtype_tag: str):
    raw = np.load(path)
    if dtype_tag == "bfloat16":
        return raw.view(jnp.bfloat16)
    return raw


def save(rt, pstores, osstores, path: str, *, step: int = 0) -> None:
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    man = _manifest(rt)
    man["step"] = step
    dtypes = {}
    for name, arr in pstores.items():
        dtypes[f"param__{name}"] = _np_save(p / f"param__{name}.npy", arr)
    for name, streams in osstores.items():
        for sname, parts in streams.items():
            for part, arr in parts.items():
                fn = f"os__{name}__{sname}__{part}"
                dtypes[fn] = _np_save(p / f"{fn}.npy", arr)
    man["dtypes"] = dtypes
    (p / "manifest.json").write_text(json.dumps(man, indent=1, default=str))


def restore(rt, path: str):
    """Load stores saved by :func:`save`; layouts must match (same-mesh
    restore).  Returns (pstores, osstores, step)."""
    p = pathlib.Path(path)
    man = json.loads((p / "manifest.json").read_text())
    for name, lay in rt.layouts.items():
        m = man["layouts"][name]
        if m["chunk_size"] != lay.chunk_size or m["nproc"] != lay.nproc:
            raise ValueError(
                f"layout mismatch for {name}: checkpoint "
                f"(S={m['chunk_size']},p={m['nproc']}) vs runtime "
                f"(S={lay.chunk_size},p={lay.nproc}); use reshard()")
    from repro.runtime import driver

    psh = driver.param_shardings(rt)
    ossh = driver.os_shardings(rt)
    dt = man.get("dtypes", {})
    pstores = {
        name: jax.device_put(
            _np_load(p / f"param__{name}.npy", dt.get(f"param__{name}", "")),
            psh[name])
        for name in rt.layouts
    }
    osstores = {}
    for name in rt.layouts:
        osstores[name] = {}
        for sname in ("p32", "m", "v"):
            osstores[name][sname] = {
                part: jax.device_put(
                    _np_load(p / f"os__{name}__{sname}__{part}.npy",
                             dt.get(f"os__{name}__{sname}__{part}", "")),
                    ossh[name][sname][part])
                for part in ("dev", "host")
            }
    return pstores, osstores, man["step"]


def to_param_tree(rt, pstores) -> Any:
    """Unpack chunk stores into a logical (TP-stacked) parameter pytree —
    the export path toward framework-agnostic weights."""
    out = {"stem": [], "groups": {}}
    stem = np.asarray(jax.device_get(pstores["stem"]))
    for r in range(stem.shape[0]):
        out["stem"].append(zero.unflatten_from_flat(
            rt.layouts["stem"], jnp.asarray(stem[r]).reshape(-1)))
    for g in rt.model.groups():
        arr = np.asarray(jax.device_get(pstores[g.name]))
        per_rank = []
        for r in range(arr.shape[0]):
            flat = jnp.asarray(arr[r]).reshape(arr.shape[1], -1)
            per_rank.append(jax.vmap(
                lambda f, _l=rt.layouts[g.name]: zero.unflatten_from_flat(_l, f)
            )(flat))
        out["groups"][g.name] = per_rank
    return out
