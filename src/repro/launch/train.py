"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \\
      --dp 2 --tp 2 --steps 50 --batch 8 --seq 128

Runs the chunked ZeRO runtime end-to-end on the host devices (set
``--devices N`` to fake a mesh on CPU), with the synthetic data pipeline,
checkpointing, and metrics logging.  This is also the driver the
end-to-end example wraps.
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--gather-policy", default="layer", choices=["layer", "step"])
    ap.add_argument("--os-host-fraction", type=float, default=0.0)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_dev = args.devices or (args.pods * args.dp * args.tp)
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import get_config, model_class
    from repro.configs.base import InputShape
    from repro.data.pipeline import make_batch_fn
    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.param_dtype:
        cfg = cfg.replace(param_dtype=args.param_dtype,
                          compute_dtype=args.param_dtype)
    mesh = make_smoke_mesh(args.dp, args.tp, args.pods)
    options = RuntimeOptions(
        remat=args.remat, gather_policy=args.gather_policy,
        os_host_fraction=args.os_host_fraction, chunk_size=args.chunk_size,
        lr=args.lr)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, options)
    n_params = sum(
        int(jnp.prod(jnp.array(s.shape)))
        for s in jax.tree.leaves(rt.model.param_specs()))
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"tp-local params={n_params/1e6:.1f}M "
          f"layouts={[(k, v.store_shape, round(v.cmap.utilization, 3)) for k, v in rt.layouts.items()]}")

    shape = InputShape("cli", args.seq, args.batch, "train")
    step_fn, _, _ = driver.build_train_step(rt, shape)
    pstores, osstores = driver.init_state(rt, jax.random.key(args.seed))
    next_batch = make_batch_fn(cfg, args.batch, args.seq, seed=args.seed)

    import time
    for step in range(args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in next_batch().items()
                 if k != "mask"}
        pstores, osstores, metrics = step_fn(
            pstores, osstores, batch, jnp.int32(step))
        if step % args.log_every == 0:
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"aux {float(metrics['aux_loss']):.4f}  {dt*1e3:.0f} ms")
        if (args.checkpoint and args.checkpoint_every
                and (step + 1) % args.checkpoint_every == 0):
            ckpt.save(rt, pstores, osstores, args.checkpoint, step=step + 1)
    if args.checkpoint:
        ckpt.save(rt, pstores, osstores, args.checkpoint, step=args.steps)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
