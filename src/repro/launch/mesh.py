"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls these.
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    # axis_types / AxisType only exist on newer jax; older releases have
    # Auto semantics by default, so the plain call is equivalent there.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """One pod = 16x16 = 256 chips (data, model); two pods add a leading
    pure-DP 'pod' axis across the slow inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_smoke_mesh(dp: int = 1, tp: int = 1, pods: int = 1):
    """Small mesh for CPU tests (uses however many host devices exist)."""
    if pods > 1:
        return _mesh((pods, dp, tp), ("pod", "data", "model"))
    return _mesh((dp, tp), ("data", "model"))


def mesh_axes(mesh) -> dict:
    names = mesh.axis_names
    return {
        "pod_axis": "pod" if "pod" in names else None,
        "pods": mesh.shape.get("pod", 1) if "pod" in names else 1,
        "data_axis": "data",
        "dp": mesh.shape["data"],
        "model_axis": "model",
        "tp": mesh.shape["model"],
    }
