"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Writes one JSON record per run to results/dryrun/.
"""

# The dry-run needs 512 placeholder devices so jax.make_mesh can build the
# production mesh; jax locks the device count at first init, so this MUST
# precede every other import (including `from repro...`).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               options=None, verbose: bool = True) -> dict:
    from repro.analysis import roofline
    from repro.configs import get_config, model_class
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, options or RuntimeOptions())

    if shape_name not in cfg.supported_shapes():
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "full-attention arch: long_500k skipped per "
                          "DESIGN.md §Arch-applicability"}

    t0 = time.time()
    if shape.kind == "train":
        jf, args, _ = driver.build_train_step(rt, shape)
    elif shape.kind == "prefill":
        jf, args = driver.build_prefill_step(rt, shape)
    else:
        if not rt.model.supports_decode:
            return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                    "status": "skipped", "reason": "no decode step"}
        jf, args = driver.build_decode_step(rt, shape)
    lowered = jf.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x "
              f"{'2pod' if multi_pod else '1pod'}] memory_analysis:", ma)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print("  cost_analysis: flops=%.4g bytes=%.4g" % (
            float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0))))

    n_tot, n_act = roofline.count_params(rt)
    chips = mesh.size
    mf = roofline.model_flops(rt, shape, n_tot, n_act) / chips
    rl = roofline.analyze(compiled, model_flops_per_device=mf)
    per_dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "status": "ok",
        "chips": chips,
        "params_total": n_tot, "params_active": n_act,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device_bytes": per_dev_bytes,
        "arg_bytes": ma.argument_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "flops": rl.flops, "hbm_bytes": rl.hbm_bytes,
        "collective_link_bytes": rl.collective_link_bytes,
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "collective_s": rl.collective_s, "dominant": rl.dominant,
        "model_flops_per_device": mf, "useful_ratio": rl.useful_ratio,
        "collectives": {k: {"count": v[0], "buffer_bytes": v[1],
                            "link_bytes": v[2]}
                        for k, v in rl.collectives.by_kind.items()},
    }
    if verbose:
        print(f"  roofline: compute={rl.compute_s:.4g}s memory={rl.memory_s:.4g}s "
              f"collective={rl.collective_s:.4g}s dominant={rl.dominant} "
              f"useful={rl.useful_ratio:.3f}")
    return rec


def main() -> None:
    from repro.configs import ARCH_IDS
    from repro.configs.base import INPUT_SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--gather-policy", default="layer", choices=["layer", "step"])
    ap.add_argument("--os-host-fraction", type=float, default=0.0)
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    args = ap.parse_args()

    from repro.runtime.step import RuntimeOptions
    options = RuntimeOptions(gather_policy=args.gather_policy,
                             os_host_fraction=args.os_host_fraction,
                             remat=args.remat)

    archs = [a for a in ARCH_IDS if not a.startswith("gpt2-paper")] \
        if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2pod' if mp else '1pod'}"
                try:
                    rec = dryrun_one(arch, shape, multi_pod=mp, options=options)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                print(f"{tag}: {rec['status']}")
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
