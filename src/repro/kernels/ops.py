"""Jitted public wrappers around the Pallas kernels.

On TPU these call compiled Mosaic kernels; everywhere else they run in
interpret mode (same math, Python-per-block) or fall back to the jnp
oracle — selected once at import from the backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chunked_adam import BLOCK, chunked_adam_kernel
from repro.kernels.flash_attention import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def chunked_adam(p32, m, v, g, *, lr, beta1, beta2, eps, weight_decay,
                 bias_corr1, bias_corr2):
    """Fused ADAM over chunk stores of any shape.

    Pads the flattened store to the kernel block size, dispatches to the
    Pallas kernel (TPU) or the jnp oracle (CPU — interpret mode is
    correct but orders of magnitude slower than XLA for big stores, so
    the oracle is the right CPU fallback inside the train step).
    Returns (p32', m', v') matching the input shape; the bf16 conversion
    happens in the caller (the kernel also emits it fused on TPU).
    """
    if not _on_tpu():
        return ref.adam_ref(p32, m, v, g, lr=lr, beta1=beta1, beta2=beta2,
                            eps=eps, weight_decay=weight_decay,
                            bias_corr1=bias_corr1, bias_corr2=bias_corr2)
    shape = p32.shape
    n = p32.size
    pad = (-n) % BLOCK
    flat = lambda x: jnp.pad(x.reshape(-1), (0, pad))
    p32f, mf, vf, _ = chunked_adam_kernel(
        flat(p32), flat(m), flat(v), flat(g), lr=lr, beta1=beta1,
        beta2=beta2, eps=eps, weight_decay=weight_decay,
        bias_corr1=bias_corr1, bias_corr2=bias_corr2)
    unflat = lambda x: x[:n].reshape(shape)
    return unflat(p32f), unflat(mf), unflat(vf)


def flash_attention(q, k, v, *, causal: bool = True):
    """[B,S,H,D] attention; kernel on TPU, scan twin elsewhere."""
    if _on_tpu():
        return flash_attention_kernel(q, k, v, causal=causal)
    from repro.models.layers import scan_attention
    return scan_attention(q, k, v, causal=causal)
