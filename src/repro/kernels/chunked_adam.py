"""Fused chunked-ADAM Pallas TPU kernel.

The paper runs ADAM on CPU because it is memory-bound; on TPU the same
operator family (elementwise over the OS chunk streams) is HBM-bandwidth
bound, so the win is *fusion*: one pass reading (p32, m, v, g) and
writing (p32, m, v, p_bf16) — 16+4 bytes/elem in, 12+2 out — instead of
the ~8 separate elementwise HLO ops XLA would emit unfused.  Because
chunks are fixed-size contiguous buffers, the kernel is shape-oblivious:
it tiles the flattened chunk payload into (8, 1024) VMEM blocks (vreg
aligned: 8 sublanes x 128 lanes x 8).

Grid: one program per block of the flattened store.  The chunk store is
padded to the block size by construction (chunk_size % 1024 == 0 via
``zero.CHUNK_ALIGN``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024  # elements per program: (8, 1024) fp32 tile = 32 KiB VMEM


def _adam_kernel(hp_ref, p_ref, m_ref, v_ref, g_ref,
                 p_out, m_out, v_out, p16_out):
    lr, b1, b2, eps, wd, bc1, bc2 = [hp_ref[i] for i in range(7)]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    upd = mhat / (jnp.sqrt(vhat) + eps)
    p = p_ref[...]
    upd = upd + wd * p
    p = p - lr * upd
    p_out[...] = p
    m_out[...] = m
    v_out[...] = v
    p16_out[...] = p.astype(p16_out.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("lr", "beta1", "beta2", "eps", "weight_decay",
                     "param_dtype", "interpret"))
def chunked_adam_kernel(p32, m, v, g, *, lr, beta1, beta2, eps,
                        weight_decay, bias_corr1, bias_corr2,
                        param_dtype=jnp.bfloat16, interpret: bool = False):
    """p32/m/v: fp32 [N]; g: bf16/fp32 [N]; N % BLOCK == 0 (pad upstream).

    Returns (p32', m', v', p16') — the fused update plus the fp32->bf16
    param conversion (Section 6.2's "updated param fp32 is converted").
    """
    n = p32.shape[0]
    assert n % BLOCK == 0, f"store size {n} not a multiple of {BLOCK}"
    rows = n // 1024
    shape2d = (rows, 1024)
    hp = jnp.stack([jnp.float32(lr), jnp.float32(beta1), jnp.float32(beta2),
                    jnp.float32(eps), jnp.float32(weight_decay),
                    jnp.asarray(bias_corr1, jnp.float32),
                    jnp.asarray(bias_corr2, jnp.float32)])
    grid = (rows // 8,)
    bspec = pl.BlockSpec((8, 1024), lambda i: (i, 0))
    out_shapes = (
        jax.ShapeDtypeStruct(shape2d, jnp.float32),
        jax.ShapeDtypeStruct(shape2d, jnp.float32),
        jax.ShapeDtypeStruct(shape2d, jnp.float32),
        jax.ShapeDtypeStruct(shape2d, param_dtype),
    )
    p32o, mo, vo, p16o = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((7,), lambda i: (0,)),  # hyperparams (replicated)
                  bspec, bspec, bspec, bspec],
        out_specs=(bspec, bspec, bspec, bspec),
        out_shape=out_shapes,
        interpret=interpret,
    )(hp, p32.reshape(shape2d), m.reshape(shape2d), v.reshape(shape2d),
      g.reshape(shape2d))
    return p32o.reshape(n), mo.reshape(n), vo.reshape(n), p16o.reshape(n)
