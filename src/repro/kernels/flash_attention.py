"""Flash-attention Pallas TPU kernel (forward).

Online-softmax blocked attention: grid over (batch*heads, q blocks); the
kernel loops over KV blocks with ``jax.lax.fori_loop``, keeping the
running (acc, m, l) in VMEM scratch.  Block sizes default to (128, 512)
— q-block rows fill the MXU's 128 dim, kv blocks stream through VMEM at
512*head_dim*2B per tile.

This is the TPU-native adaptation of the paper's "move data in large
fixed-size blocks" insight applied to the attention hot spot: HBM->VMEM
traffic is exactly one pass over K/V per q block, with no [S, S] score
materialization.  The train/prefill paths use the jnp scan twin
(``models.layers.scan_attention``) for XLA portability; this kernel is
the TPU drop-in validated against the same oracle.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k,
                  seq_k, causal, scale):
    # q_ref: [1, block_q, d]; k_ref/v_ref: [1, seq_k, d]; o_ref: [1, block_q, d]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    d = q.shape[-1]
    nkv = seq_k // block_k

    def body(j, carry):
        acc, m, l = carry
        # index the collapsed batch*heads dim with a size-1 Slice, not a
        # raw int: this jax's load/store discharge rules only accept Slice
        # or array indexers (an int scalar has no .shape and trips an
        # AttributeError inside pallas/primitives.py).
        k = pl.load(k_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None)))[0]
        logits = q @ k.astype(jnp.float32).T  # [bq, bk]
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(kpos <= qpos, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc_new, m_new, l_new

    acc = jnp.zeros((block_q, d), jnp.float32)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    # causal: kv blocks beyond this q block's diagonal contribute nothing
    if causal:
        upper = jnp.minimum(
            jax.lax.div((qi + 1) * block_q + block_k - 1, block_k), nkv)
    else:
        upper = nkv
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc, m, l))
    o_ref[0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 512,
                           interpret: bool = False):
    """q/k/v: [B, S, H, D] (same H; GQA repeat upstream). Returns [B,S,H,D]."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = 1.0 / math.sqrt(d)
    # fold batch and heads into the grid's leading axis
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    grid = (b * h, sq // block_q)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, seq_k=sk, causal=causal,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, sk, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
