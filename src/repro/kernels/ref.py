"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def adam_ref(p32, m, v, g, *, lr, beta1, beta2, eps, weight_decay,
             bias_corr1, bias_corr2):
    """Fused chunked-ADAM oracle.  All fp32, any shape."""
    g32 = g.astype(jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g32
    v = beta2 * v + (1.0 - beta2) * g32 * g32
    mhat = m / bias_corr1
    vhat = v / bias_corr2
    upd = mhat / (jnp.sqrt(vhat) + eps)
    if weight_decay:
        upd = upd + weight_decay * p32
    p32 = p32 - lr * upd
    return p32, m, v


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """Naive attention oracle.  q: [B,Sq,H,D], k/v: [B,Sk,H,D] (same H)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None] + (sk - sq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
