"""Tensor and chunk state machine (PatrickStar Table 1 / Fig. 7).

Every model-data tensor managed by PatrickStar carries a state that
determines where the chunk containing it may legally live:

  FREE            no payload space is held for this tensor.
  COMPUTE         the tensor is about to be / being used by an operator and
                  must be resident on the *computing device*.
  HOLD            payload must be kept, but may live on either tier.
  HOLD_AFTER_FWD  HOLD produced by releasing a tensor after forward.
  HOLD_AFTER_BWD  HOLD produced by releasing a tensor after backward.
  RELEASED        multi-process only (Section 7): the tensor lives in a
                  chunk owned by a *remote* rank; the local replica's
                  payload has been dropped and the authoritative value is
                  the owner's.  A chunk-granular all-gather re-materializes
                  the whole communication group (RELEASED -> HOLD) before
                  any of its tensors may enter COMPUTE.

The HOLD/HOLD_AFTER_* three are collectively "HOLD-like".  Distinguishing
the after-FWD/after-BWD variants is what lets the distributed runtime
decide when a whole communication group has finished a phase
(Algorithm 2), even in the presence of activation checkpointing, which
re-runs forward computation *during* backward.  RELEASED differs from
FREE in exactly one way that matters: a FREE tensor's first access
zero-fills (Algorithm 1 line 31), while a RELEASED tensor's first access
must FETCH the owner's bytes — zero-filling a remote parameter would
corrupt the model.

The **activation stream** (the fifth managed stream) reuses this same
machine with a strictly simpler trajectory — each checkpointed layer
input is written once during FWD, read once during BWD at the mirrored
layer index, then dropped:

    FREE -> COMPUTE (FWD write) -> HOLD_AFTER_FWD
         -> COMPUTE (BWD read)  -> FREE (payload released)

No act tensor ever enters RELEASED (activations are rank-local: there is
no remote owner to fetch from) and none survives the step, so the act
stream needs no new states or transitions — only the FREE<->COMPUTE and
HOLD_AFTER_FWD->COMPUTE edges that already exist.
"""

from __future__ import annotations

import enum
from typing import Iterable


class TensorState(enum.Enum):
    FREE = "FREE"
    COMPUTE = "COMPUTE"
    HOLD = "HOLD"
    HOLD_AFTER_FWD = "HOLD_AFTER_FWD"
    HOLD_AFTER_BWD = "HOLD_AFTER_BWD"
    RELEASED = "RELEASED"

    @property
    def is_hold_like(self) -> bool:
        return self in _HOLD_LIKE

    @property
    def is_payload_free(self) -> bool:
        """States in which the tensor holds no local payload bytes."""
        return self is TensorState.FREE or self is TensorState.RELEASED

    def __repr__(self) -> str:  # compact in logs
        return self.value


_HOLD_LIKE = frozenset(
    {TensorState.HOLD, TensorState.HOLD_AFTER_FWD, TensorState.HOLD_AFTER_BWD}
)

# Legal transitions of a param-fp16 tensor, following Fig. 7 of the paper.
# (init) -> HOLD -> COMPUTE -> HOLD_AFTER_FWD -> HOLD (reset before BWD)
#        -> COMPUTE -> HOLD_AFTER_BWD -> (grad overwrites payload) ... -> HOLD
# FREE is entered when a chunk's payload is dropped, and left when the
# chunk re-materializes it.  RELEASED is the remote-chunk lifecycle
# (Section 7 / Algorithm 1-2): entered at init for non-owned chunks and
# again when a communication group finishes its post-FWD/post-BWD
# transition; left only through the all-gather that re-materializes the
# group (-> HOLD, or directly -> COMPUTE for the accessed tensor).
_LEGAL_TRANSITIONS: dict[TensorState, frozenset[TensorState]] = {
    TensorState.FREE: frozenset(
        {TensorState.HOLD, TensorState.COMPUTE, TensorState.RELEASED}
    ),
    TensorState.HOLD: frozenset(
        {TensorState.COMPUTE, TensorState.FREE, TensorState.HOLD, TensorState.RELEASED}
    ),
    TensorState.COMPUTE: frozenset(
        {
            TensorState.HOLD,
            TensorState.HOLD_AFTER_FWD,
            TensorState.HOLD_AFTER_BWD,
            TensorState.FREE,
        }
    ),
    TensorState.HOLD_AFTER_FWD: frozenset(
        {TensorState.COMPUTE, TensorState.HOLD, TensorState.FREE, TensorState.RELEASED}
    ),
    TensorState.HOLD_AFTER_BWD: frozenset(
        {TensorState.COMPUTE, TensorState.HOLD, TensorState.FREE, TensorState.RELEASED}
    ),
    TensorState.RELEASED: frozenset({TensorState.HOLD, TensorState.COMPUTE}),
}


class IllegalTransition(RuntimeError):
    """Raised when a tensor attempts a transition Fig. 7 does not permit."""


def check_transition(old: TensorState, new: TensorState) -> None:
    if new not in _LEGAL_TRANSITIONS[old]:
        raise IllegalTransition(f"illegal tensor state transition {old!r} -> {new!r}")


class ChunkState(enum.Enum):
    """Derived location constraint of a chunk (Sections 6.2, 7).

    FREE      all tensors FREE: the payload may be reused or released.
    COMPUTE   >=1 tensor COMPUTE: chunk must be on the computing device.
    HOLD      otherwise (>=1 HOLD-like, none COMPUTE): may live on any tier.
    RELEASED  no COMPUTE/HOLD-like tensor but >=1 RELEASED: the chunk is a
              remote rank's; no local payload, re-enters HOLD by all-gather.
    """

    FREE = "FREE"
    COMPUTE = "COMPUTE"
    HOLD = "HOLD"
    RELEASED = "RELEASED"


def derive_chunk_state(tensor_states: Iterable[TensorState]) -> ChunkState:
    saw_any = False
    saw_hold = False
    saw_released = False
    for s in tensor_states:
        saw_any = True
        if s is TensorState.COMPUTE:
            return ChunkState.COMPUTE
        if s.is_hold_like:
            saw_hold = True
        elif s is TensorState.RELEASED:
            saw_released = True
    if saw_hold:
        return ChunkState.HOLD
    if saw_released:
        return ChunkState.RELEASED
    return ChunkState.FREE


def all_in(states: Iterable[TensorState], target: TensorState) -> bool:
    return all(s is target for s in states)
