"""Transfer timeline: a two-queue DMA model with stall accounting.

The pool's staging machinery classifies every H2D byte *hidden* (issued
ahead of demand, overlappable with compute) or *critical-path* (a demand
miss) — a classification, not a performance model.  Whether "hidden"
bytes are actually hidden depends on whether they fit inside the
consuming operator's compute window at the available CPU<->GPU bandwidth
(the overlap analysis PatrickStar Section 7 / Fig. 9 and ZeRO-Infinity's
bandwidth-centric design reason about).  :class:`TransferTimeline` makes
that temporal: it models the accelerator's DMA engines as FIFO queues of
finite bandwidth and advances a simulated clock moment-by-moment against
per-operator compute durations derived from
:mod:`repro.analysis.costmodel`.

Engines (one FIFO queue each, issue order preserved):

  ``h2d``   host->device stages and demand fetches;
  ``d2h``   device->host evictions and host-placed ADAM pulls;
  ``h2s``   host->slow demotions onto the NVMe-class third tier;
  ``s2h``   slow->host promotions (the first leg of a two-hop fetch —
            the chained h2d leg starts only after it lands);
  ``coll``  the collective lane (group all-gathers, grad reduce-scatter,
            the stem all-reduce) of the distributed plane.

Clock rules — every advance of ``now`` is classified exactly once, so
the per-step decomposition ``step == compute + h2d_stall + d2h_stall +
gather_stall`` holds *by construction* and is asserted as a conservation
law in tests:

  * **compute**: entering moment ``m+1`` adds moment ``m``'s operator
    duration (transfers recorded while the cursor sat at ``m`` were
    issued at the operator's start, so they overlap its compute).
  * **critical transfer**: the consumer waits for the transfer's queue
    position AND its wire time — ``now`` jumps to the transfer's end,
    the jump is booked as stall on that engine (and per stream, per
    moment).  A backlog of earlier (hidden) transfers on the same engine
    therefore delays a critical one: DMA-engine contention.
  * **late hidden transfer**: a staged chunk (or prefetched gather) hit
    by its consumer before the wire finished stalls for the remainder —
    hidden bytes in excess of the overlap window *surface* instead of
    disappearing.
  * **end-of-step drain**: residual queue backlog (e.g. D2H evictions
    still in flight) is waited out engine-by-engine in completion order,
    each booked the marginal wait beyond the previous — concurrent
    drains are never double-counted.

Under infinite bandwidth (the default: ``bandwidth=None``) every
transfer takes zero seconds, every stall is exactly ``0.0`` and step
time equals summed compute — the degenerate case the property tests pin.

The timeline also answers the *planning* queries the bandwidth-aware
prefetchers ask (:class:`~repro.core.memory.SchedulePrefetcher` /
:class:`~repro.core.memory.GatherPrefetcher` with ``timeline=``):
``projected_ready_s`` (queue delay + wire time of a would-be transfer)
vs ``time_until`` (summed compute between now and the reference's
moment) decides how deep and how early to issue — instead of the fixed
``lookahead/max_inflight`` heuristic.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Hashable


def _is_infinite(bandwidth: float | None) -> bool:
    return bandwidth is None or math.isinf(bandwidth)


@dataclasses.dataclass
class DmaEngine:
    """One FIFO transfer queue of finite (or infinite) bandwidth."""

    name: str
    bandwidth: float | None = None  # bytes/second; None == infinite
    busy_until: float = 0.0

    def transfer_seconds(self, nbytes: int) -> float:
        if _is_infinite(self.bandwidth):
            return 0.0
        return nbytes / float(self.bandwidth)

    def enqueue(self, now: float, nbytes: int,
                start_after: float | None = None) -> float:
        """FIFO issue: starts when the queue drains (and, for the second
        leg of a chained two-hop transfer, not before ``start_after`` —
        the first leg's completion), returns the end."""
        start = max(now, self.busy_until)
        if start_after is not None:
            start = max(start, start_after)
        end = start + self.transfer_seconds(nbytes)
        self.busy_until = end
        return end


@dataclasses.dataclass
class StepTimeline:
    """One step's (or serving round's) wall-clock decomposition."""

    compute_s: float = 0.0
    h2d_stall_s: float = 0.0
    d2h_stall_s: float = 0.0
    h2s_stall_s: float = 0.0
    s2h_stall_s: float = 0.0
    gather_stall_s: float = 0.0
    # simulated wall seconds this step actually took (now - step start);
    # equals compute_s + stall_s up to float associativity
    wall_s: float = 0.0
    stall_by_stream: dict[str, float] = dataclasses.field(default_factory=dict)
    stall_by_moment: dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def stall_s(self) -> float:
        return (self.h2d_stall_s + self.d2h_stall_s + self.h2s_stall_s
                + self.s2h_stall_s + self.gather_stall_s)

    @property
    def step_s(self) -> float:
        """The decomposed step time: compute + per-engine stalls."""
        return self.compute_s + self.stall_s


# stall bucket per engine name
_STALL_FIELD = {"h2d": "h2d_stall_s", "d2h": "d2h_stall_s",
                "h2s": "h2s_stall_s", "s2h": "s2h_stall_s",
                "coll": "gather_stall_s"}

_DRAIN_STREAM = "(drain)"


@dataclasses.dataclass
class _Schedule:
    """One moment namespace: a cursor plus its compute-duration table.

    Multi-tenant pools give each non-default tenant its own namespace
    (keyed by tenant name; the default tenant keeps the unnamed ``None``
    namespace), because tenants' moment ids are independent clocks — the
    trainer's moment 7 and the server's moment 7 are unrelated operators.
    The DMA engines stay *shared* across namespaces: the lanes are the
    physical contention point, so one tenant's backlog delays another's
    critical fetch exactly as it would a sibling stream's."""

    cur: int | None = None
    durations: dict[int, float] = dataclasses.field(default_factory=dict)
    order: list[int] = dataclasses.field(default_factory=list)
    prefix: list[float] = dataclasses.field(default_factory=lambda: [0.0])

    def rebuild(self) -> None:
        self.order = sorted(self.durations)
        acc = 0.0
        self.prefix = [0.0]
        for m in self.order:
            acc += self.durations[m]
            self.prefix.append(acc)


class TransferTimeline:
    """Two DMA queues + a collective lane advanced against compute.

    Attach to a pool with :meth:`HeteroMemory.set_timeline`; the pool
    forwards every tier move and the moment cursor.  Per-operator
    compute durations are installed after the warm-up iteration
    (:meth:`install_durations`, moment -> seconds) or extended
    round-by-round on the serving plane (:meth:`extend_durations`).

    Every schedule method takes ``tenant=`` (a namespace name, ``None``
    for the historical unnamed namespace): co-resident tenants keep
    independent moment clocks over the *same* DMA engines, so the
    bandwidth-aware issue policy sees both tenants' projected windows
    through one ``projected_ready_s`` while ``time_until`` answers
    against the asking tenant's own schedule."""

    def __init__(
        self,
        *,
        h2d_bandwidth: float | None = None,
        d2h_bandwidth: float | None = None,
        h2s_bandwidth: float | None = None,
        s2h_bandwidth: float | None = None,
        collective_bandwidth: float | None = None,
    ) -> None:
        self.h2d = DmaEngine("h2d", h2d_bandwidth)
        self.d2h = DmaEngine("d2h", d2h_bandwidth)
        # slow-tier (NVMe-class) lanes; idle on two-tier pools
        self.h2s = DmaEngine("h2s", h2s_bandwidth)
        self.s2h = DmaEngine("s2h", s2h_bandwidth)
        self.coll = DmaEngine("coll", collective_bandwidth)
        self._engines = {"h2d": self.h2d, "d2h": self.d2h,
                         "h2s": self.h2s, "s2h": self.s2h, "coll": self.coll}
        self.now = 0.0
        self._step_start = 0.0
        # moment namespaces (None == the historical unnamed one); the
        # engines above are shared across all of them
        self._sched: dict[str | None, _Schedule] = {None: _Schedule()}
        # namespace of the last-advanced cursor: stalls recorded between
        # advances are attributed to that tenant's current moment
        self._active: str | None = None
        # in-flight overlappable transfers awaiting their consumer:
        # key -> (engine name, completion time, stream)
        self._pending: dict[Hashable, tuple[str, float, str]] = {}
        self._step = StepTimeline()
        # telemetry hub (None == disabled: one predicate per call site);
        # the pool's set_telemetry propagates here with its rank tag
        self.telemetry = None
        self.telemetry_rank: int | None = None
        # (start, end) of the most recent _record — the pool reads it
        # right after recording a move to timestamp the telemetry event
        self.last_window: tuple[float, float] = (0.0, 0.0)
        # whole-run per-lane stall seconds (never reset by take_step):
        # the conservation ground truth the event log is checked against
        self.total_stalls: dict[str, float] = {n: 0.0 for n in self._engines}

    @classmethod
    def calibrated(cls) -> "TransferTimeline":
        """Timeline with bandwidths derived from the roofline hardware
        constants instead of ad-hoc test scales: H2D/D2H ride the
        PCIe-class host link, the slow-tier lanes an NVMe-class link,
        collectives the ICI ring — so simulated stalls come out in
        absolute Fig. 16-style seconds across every link."""
        from repro.analysis.roofline import HOST_LINK_BW, ICI_BW, NVME_BW

        return cls(h2d_bandwidth=HOST_LINK_BW, d2h_bandwidth=HOST_LINK_BW,
                   h2s_bandwidth=NVME_BW, s2h_bandwidth=NVME_BW,
                   collective_bandwidth=ICI_BW)

    def set_telemetry(self, telemetry, *, rank: int | None = None) -> None:
        if self.telemetry is not None and self.telemetry is not telemetry:
            self.telemetry.detach_timeline(self)
        self.telemetry = telemetry
        self.telemetry_rank = rank
        if telemetry is not None:
            telemetry.attach_timeline(self)

    # ------------------------------------------------------------- durations
    def _ns(self, tenant: str | None) -> _Schedule:
        ns = self._sched.get(tenant)
        if ns is None:
            ns = self._sched[tenant] = _Schedule()
        return ns

    @property
    def has_durations(self) -> bool:
        return any(ns.durations for ns in self._sched.values())

    def has_durations_for(self, tenant: str | None = None) -> bool:
        """Whether *this tenant's* namespace has a compute schedule (the
        bandwidth-aware prefetcher gate: another tenant's durations say
        nothing about this tenant's overlap windows)."""
        ns = self._sched.get(tenant)
        return ns is not None and bool(ns.durations)

    def install_durations(self, durations: dict[int, float],
                          tenant: str | None = None) -> None:
        """Replace the moment -> compute-seconds schedule (training: one
        iteration's moments, reused every step)."""
        ns = self._ns(tenant)
        ns.durations = dict(durations)
        ns.rebuild()

    def extend_durations(self, durations: dict[int, float],
                         tenant: str | None = None) -> None:
        """Merge additional moments (serving: each round plans fresh,
        strictly increasing moments)."""
        ns = self._ns(tenant)
        ns.durations.update(durations)
        ns.rebuild()

    def duration_of(self, moment: int, tenant: str | None = None) -> float:
        ns = self._sched.get(tenant)
        return ns.durations.get(moment, 0.0) if ns is not None else 0.0

    # ----------------------------------------------------------------- clock
    def advance_to_moment(self, moment: int,
                          tenant: str | None = None) -> None:
        """Moment cursor moved: the previous operator's compute elapsed.
        Each tenant namespace keeps its own cursor; the simulated clock
        (and the shared engines behind it) advances for everyone."""
        ns = self._ns(tenant)
        if ns.cur is not None and moment != ns.cur:
            self._run_compute(ns, ns.cur, tenant)
        ns.cur = moment
        self._active = tenant

    def _run_compute(self, ns: _Schedule, moment: int,
                     tenant: str | None) -> None:
        dur = ns.durations.get(moment, 0.0)
        if dur > 0.0:
            tel = self.telemetry
            if tel is not None:
                tel.compute(moment=moment, seconds=dur, tenant=tenant,
                            ts=self.now, rank=self.telemetry_rank)
            self.now += dur
            self._step.compute_s += dur

    def _stall(self, engine: str, stream: str, seconds: float) -> None:
        if seconds <= 0.0:
            return
        cur = self._sched[self._active].cur if self._active in self._sched \
            else None
        tel = self.telemetry
        if tel is not None:
            tel.stall(engine, stream=stream, seconds=seconds, ts=self.now,
                      moment=cur, rank=self.telemetry_rank)
        self.total_stalls[engine] += seconds
        self.now += seconds
        setattr(self._step, _STALL_FIELD[engine],
                getattr(self._step, _STALL_FIELD[engine]) + seconds)
        by_s = self._step.stall_by_stream
        by_s[stream] = by_s.get(stream, 0.0) + seconds
        if cur is not None:
            by_m = self._step.stall_by_moment
            by_m[cur] = by_m.get(cur, 0.0) + seconds

    # -------------------------------------------------------------- transfers
    def record_h2d(self, nbytes: int, *, stream: str, critical: bool,
                   key: Hashable | None = None,
                   start_after: float | None = None) -> float:
        return self._record("h2d", nbytes, stream=stream, critical=critical,
                            key=key, start_after=start_after)

    def record_d2h(self, nbytes: int, *, stream: str, critical: bool,
                   key: Hashable | None = None,
                   start_after: float | None = None) -> float:
        return self._record("d2h", nbytes, stream=stream, critical=critical,
                            key=key, start_after=start_after)

    def record_h2s(self, nbytes: int, *, stream: str, critical: bool,
                   key: Hashable | None = None,
                   start_after: float | None = None) -> float:
        return self._record("h2s", nbytes, stream=stream, critical=critical,
                            key=key, start_after=start_after)

    def record_s2h(self, nbytes: int, *, stream: str, critical: bool,
                   key: Hashable | None = None,
                   start_after: float | None = None) -> float:
        return self._record("s2h", nbytes, stream=stream, critical=critical,
                            key=key, start_after=start_after)

    def record_collective(self, nbytes: int, *, critical: bool,
                          stream: str = "param",
                          key: Hashable | None = None) -> float:
        return self._record("coll", nbytes, stream=stream, critical=critical,
                            key=key)

    def _record(self, engine: str, nbytes: int, *, stream: str,
                critical: bool, key: Hashable | None,
                start_after: float | None = None) -> float:
        eng = self._engines[engine]
        start = max(self.now, eng.busy_until)
        if start_after is not None:
            start = max(start, start_after)
        end = eng.enqueue(self.now, nbytes, start_after)
        self.last_window = (start, end)
        if critical:
            # the consumer waits for queue position + wire time (FIFO:
            # hidden backlog ahead of it delays it — engine contention)
            self._stall(engine, stream, end - self.now)
        elif key is not None:
            self._pending[key] = (engine, end, stream)
        return end

    def wait_for(self, key: Hashable) -> float:
        """The consumer of an overlappable transfer arrived: stall for
        whatever wire time remains (0 if it already landed).  No-op for
        unknown keys."""
        rec = self._pending.pop(key, None)
        if rec is None:
            return 0.0
        engine, end, stream = rec
        late = end - self.now
        self._stall(engine, stream, late)
        return max(late, 0.0)

    def cancel(self, key: Hashable) -> None:
        """Drop a pending transfer's rendezvous (wasted stage: the chunk
        was evicted / released before its consumer arrived)."""
        self._pending.pop(key, None)

    # ------------------------------------------------------------- planning
    def projected_ready_s(self, engine: str, nbytes: int) -> float:
        """Seconds from now until a transfer issued now would land:
        current queue backlog + its own wire time."""
        eng = self._engines[engine]
        return max(0.0, eng.busy_until - self.now) + eng.transfer_seconds(nbytes)

    def time_until(self, moment: int, tenant: str | None = None) -> float:
        """Summed compute seconds between the tenant's current cursor and
        ``moment`` — the overlap window a transfer issued now can hide
        inside (includes the current operator's own duration: transfers
        issue at operator start)."""
        ns = self._sched.get(tenant)
        if ns is None or ns.cur is None or not ns.order:
            return 0.0
        i = bisect.bisect_left(ns.order, ns.cur)
        j = bisect.bisect_left(ns.order, moment)
        if j <= i:
            return 0.0
        return ns.prefix[j] - ns.prefix[i]

    # ----------------------------------------------------------------- steps
    def take_step(self) -> StepTimeline:
        """Close the step: flush every namespace's current operator's
        compute (under the coarse co-tenancy interleave at most one
        cursor is armed at a time), drain residual queue backlog
        (marginal attribution in completion order), return this step's
        decomposition and re-arm."""
        for tenant, ns in self._sched.items():
            if ns.cur is not None:
                self._run_compute(ns, ns.cur, tenant)
                ns.cur = None
        for eng in sorted(self._engines.values(), key=lambda e: e.busy_until):
            self._stall(eng.name, _DRAIN_STREAM, eng.busy_until - self.now)
        rep = self._step
        rep.wall_s = self.now - self._step_start
        tel = self.telemetry
        if tel is not None:
            # the mark closes a per-step event segment and carries the
            # step's lane totals, so event-derived per-step stalls can be
            # compared against the StepTimeline bit-for-bit
            tel.mark("take_step", ts=self.now, rank=self.telemetry_rank,
                     compute_s=rep.compute_s, h2d_stall_s=rep.h2d_stall_s,
                     d2h_stall_s=rep.d2h_stall_s,
                     h2s_stall_s=rep.h2s_stall_s,
                     s2h_stall_s=rep.s2h_stall_s,
                     gather_stall_s=rep.gather_stall_s, wall_s=rep.wall_s)
        self._step = StepTimeline()
        self._step_start = self.now
        return rep

    def prune_durations_before(self, moment: int,
                               tenant: str | None = None) -> None:
        """Drop duration entries for moments < ``moment`` (the serving
        plane's moments increase forever; training reuses one iteration's
        ids and never calls this)."""
        ns = self._ns(tenant)
        ns.durations = {m: d for m, d in ns.durations.items() if m >= moment}
        ns.rebuild()
