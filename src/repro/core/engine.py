"""PatrickStarEngine — the paper's runtime, eagerly executed.

This is the faithful system of Sections 6-8: chunked model data managed
over a bounded two-tier (device/host) memory space by one shared
:class:`~repro.core.memory.HeteroMemory` pool (param fp16, param fp32,
momentum and variance are per-stream
:class:`~repro.core.manager.ChunkManager` views of it, so all four
streams compete for ONE device budget and eviction is cross-stream),
with

  * the tensor state machine driving chunk movement (Table 1, Fig. 7),
  * grad-fp16 chunks REUSING param-fp16 chunk payloads (Fig. 6),
  * a warm-up iteration feeding the RuntimeMemoryTracer (Section 8.1),
  * OPT/Belady chunk eviction from per-stream traced moment schedules
    (8.3),
  * a schedule-driven prefetcher staging the next-k chunk references
    ahead of their operator after warm-up (simulated-async; H2D bytes are
    classified hidden vs critical-path in :class:`EngineMetrics`),
  * device-aware OS placement in GPU margin space + embedding kept on
    host (Section 8.2),
  * block-granular activation checkpointing (inputs saved, fwd recomputed
    inside jax.vjp during BWD — the re-COMPUTE transitions that make
    HOLD_AFTER_FWD/BWD states necessary),
  * an **activation chunk stream** (``manage_activations``, on by
    default): the checkpointed inputs themselves live as chunks in a
    fifth ChunkManager view of the same pool — written once in FWD, read
    once at the mirrored BWD layer, then freed — so OPT eviction can
    spill cold activations to host mid-step and the prefetcher stages
    them back ahead of ``backward_layer``.  This is what turns the fixed
    device budget into *batch-size* headroom (the paper's "larger batch
    sizes" claim), measured by benchmarks/max_batch.py under
    ``strict_device_budget``.

The class doubles as the **single-rank core of the distributed plane**
(Section 7): constructed with ``nproc > 1`` it owns only the chunk shard
of its ``rank`` (rank r owns chunk ``g*p + r`` of every communication
group), keeps non-owned chunks in the RELEASED remote lifecycle, and
delegates chunk-granular all-gather / reduce-scatter to a ``collective``
(the rank-parallel driver in :mod:`repro.core.distributed`).  ``step()``
itself is a thin composition of the phase methods (``begin_step`` /
``forward_layer`` / ``backward_layer`` / ``adam_chunks`` / ``end_step``)
that the driver interleaves across ranks in lock-step.

On this container the "device" tier is simulated: payloads are numpy
buffers tagged device/host with byte-capacity enforcement and full
transfer accounting, so eviction-policy quality and data-movement volume
are measured exactly as the paper measures them.  Compute runs through
jax on CPU.  The API mirrors the paper's Listing 1.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunk import (
    TensorSpec,
    build_act_chunk_map,
    build_chunk_map,
    search_chunk_size,
)
from repro.core.manager import ChunkManager
from repro.core.memory import (
    HeteroMemory,
    OutOfMemory,
    SchedulePrefetcher,
    Tenant,
    acquire_pool,
)
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.state import ChunkState, TensorState
from repro.core.telemetry import Telemetry
from repro.core.timeline import StepTimeline, TransferTimeline
from repro.core.tracer import RuntimeMemoryTracer
from repro.models.api import Model
from repro.models.layers import AxisCtx


def _leaves_with_names(tree, prefix: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(prefix + jax.tree_util.keystr(path), leaf) for path, leaf in flat]


@dataclasses.dataclass
class EngineMetrics:
    fwd_s: float = 0.0
    bwd_s: float = 0.0
    adam_s: float = 0.0
    loss: float = 0.0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    adam_h2d_bytes: int = 0
    adam_d2h_bytes: int = 0
    # overlap accounting (schedule-driven prefetch, post-warm-up):
    # every H2D byte this step is either hidden (staged ahead of its use,
    # overlappable with compute) or critical-path (a demand miss).
    hidden_h2d_bytes: int = 0
    critical_h2d_bytes: int = 0
    prefetch_hits: int = 0
    demand_misses: int = 0
    # high-water mark of the unified pool's device tier THIS step (the
    # pool keeps the cumulative lifetime mark separately)
    peak_device_bytes: int = 0
    # transfer-timeline decomposition of this step's simulated wall time
    # (step == compute + h2d_stall + d2h_stall + gather_stall); None when
    # the engine runs without a timeline.
    timeline: StepTimeline | None = None

    @property
    def total_s(self) -> float:
        return self.fwd_s + self.bwd_s + self.adam_s

    @property
    def moved_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes + self.adam_h2d_bytes + self.adam_d2h_bytes

    @property
    def prefetch_hit_rate(self) -> float:
        total = self.prefetch_hits + self.demand_misses
        return self.prefetch_hits / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class _ActRef:
    """A checkpointed layer input parked in the activation chunk stream
    (instead of held live on the device): the saved jax array is released
    and only the chunk name + original shape/dtype survive until the
    mirrored BWD read re-materializes it."""

    name: str
    shape: tuple[int, ...]
    dtype: Any


@dataclasses.dataclass
class _StepState:
    """Mutable per-step context threaded through the phase methods, so a
    rank-parallel driver can hold one per rank and interleave phases."""

    batch: dict
    met: EngineMetrics
    h2d0: int
    d2h0: int
    pf0: Any
    t0: float = 0.0
    stem: Any = None
    x: Any = None
    extras: Any = None
    # (group, layer, x | _ActRef) per checkpointed layer input
    saved: list = dataclasses.field(default_factory=list)
    gx: Any = None
    stem_grad: Any = None


class PatrickStarEngine:
    def __init__(
        self,
        model_cls,
        cfg,
        *,
        device_memory_bytes: int | None = None,
        host_memory_bytes: int | None = None,
        slow_memory_bytes: int | None = None,
        pool: HeteroMemory | None = None,
        tenant: Tenant | None = None,
        policy: str = "opt",
        chunk_size: int | None = None,
        warmup_chunk_fraction: float = 0.2,
        lr: float = 1e-3,
        betas: tuple[float, float] = (0.9, 0.95),
        eps: float = 1e-8,
        seed: int = 0,
        device_aware_placement: bool = True,
        embedding_on_host: bool = True,
        prefetch: bool = True,
        prefetch_lookahead: int = 6,
        timeline: TransferTimeline | None = None,
        telemetry: "Telemetry | None" = None,
        bandwidth_aware_prefetch: bool = True,
        manage_activations: bool = True,
        strict_device_budget: bool = False,
        nproc: int = 1,
        rank: int = 0,
        collective: "Any | None" = None,
        init_params: "Any | None" = None,
    ) -> None:
        if nproc > 1 and collective is None:
            raise ValueError(
                "nproc > 1 needs a collective (the rank-parallel driver in "
                "repro.core.distributed) to fetch remote chunks")
        self.cfg = cfg
        self.ctx = AxisCtx()  # single device, no mesh axes
        self.model: Model = model_cls(cfg, self.ctx)
        self.lr, self.betas, self.eps = lr, betas, eps
        self.device_aware_placement = device_aware_placement
        self.nproc = nproc
        self.rank = rank
        self.collective = collective

        # init_params: the rank-parallel driver initializes ONE tree and
        # shares it across rank cores (values are COPIED into chunk
        # payloads below, so sharing the source is safe) instead of
        # paying nproc full-model inits for bitwise-identical trees.
        params = init_params if init_params is not None \
            else self.model.init_params(jax.random.key(seed))
        # paper 8.2: embedding params are NOT chunk-managed.  Under ZeRO
        # they stay replicated; their grads all-reduce (outside the
        # chunked collective plane, counted separately by the driver).
        self._stem_np = jax.tree.map(np.asarray, params["stem"])
        self._stem_m: list[np.ndarray] | None = None  # ADAM moments (lazy)
        self._stem_v: list[np.ndarray] | None = None
        self.embedding_on_host = embedding_on_host

        # ---- chunk stream over all block-group tensors, model order -----
        named: list[tuple[str, np.ndarray]] = []
        self._group_tensor_names: dict[str, list[list[str]]] = {}
        for g in self.model.groups():
            stacked = params["groups"][g.name]
            per_layer: list[list[str]] = []
            for i in range(g.length):
                layer_tree = jax.tree.map(lambda t: np.asarray(t[i]), stacked)
                pairs = _leaves_with_names(layer_tree, f"{g.name}.{i}")
                per_layer.append([n for n, _ in pairs])
                named.extend(pairs)
            self._group_tensor_names[g.name] = per_layer
        self._layer_trees = {
            g.name: jax.tree_util.tree_structure(
                jax.tree.map(lambda t: t[0], params["groups"][g.name]))
            for g in self.model.groups()
        }

        specs = [TensorSpec(n, tuple(v.shape)) for n, v in named]
        if chunk_size is None:
            res = search_chunk_size(specs, nproc=nproc, align=256)
            chunk_size = res.chunk_size
        self.cmap = build_chunk_map(specs, chunk_size, nproc=nproc)

        # ---- ONE heterogeneous memory space shared by all four streams ----
        # (Sections 6.2, 8): param fp16 (grads reuse its payloads), param
        # fp32, momentum and variance are views of a single pool with a
        # single device budget, so eviction sees cross-stream pressure.
        # Under nproc > 1 every rank owns its own pool (its own GPU).
        # With pool= (+ tenant=), the engine instead joins a SHARED pool
        # as one tenant — co-resident with e.g. a ServingEngine — and the
        # budget args become its planning shares, not tier capacities.
        self._lease = acquire_pool(
            pool=pool, tenant=tenant,
            device_memory_bytes=device_memory_bytes,
            host_memory_bytes=host_memory_bytes,
            slow_memory_bytes=slow_memory_bytes,
            policy=policy, timeline=timeline)
        self.pool = self._lease.pool
        self.tenant = self._lease.tenant
        if telemetry is not None:
            self.pool.set_telemetry(telemetry)
        # the pool's policy governs (identical to the policy arg for an
        # owned pool; an external pool was built with its own)
        self.policy = self.pool.policy
        # transfer timeline (optional): every tier move / collective is
        # enqueued on finite-bandwidth DMA engines and the per-step report
        # decomposes step time into compute + per-engine stalls.
        self.timeline = self._lease.timeline
        device_share = self._lease.device_bytes
        if device_share is None:
            raise ValueError(
                "the trainer needs a device budget: pass "
                "device_memory_bytes= or give its tenant a "
                "device_budget_bytes soft budget")
        self.params_mgr = self._lease.stream("param", self.cmap)
        self.os_mgrs = {
            name: self._lease.stream(name, self.cmap)
            for name in ("p32", "m", "v")
        }
        # tracer over the simulated device (this tenant's share of it)
        self.tracer = RuntimeMemoryTracer(
            device_share, warmup_chunk_fraction=warmup_chunk_fraction)
        # the chunkable budget must never drop below one operator's working
        # set: the largest layer's param chunks during FWD/BWD (plus, on
        # the distributed plane, one communication group pinned while its
        # all-gather is in flight), and the four per-stream chunks pinned
        # together during one ADAM chunk update (all are COMPUTE-pinned or
        # refcount-pinned, hence unevictable).
        max_layer_chunks = max(
            len({self.cmap.placement(n).chunk_id for n in layer})
            for layers in self._group_tensor_names.values() for layer in layers)
        self._model_floor_bytes = max(max_layer_chunks + max(nproc, 1), 5) \
            * self.params_mgr.chunk_bytes
        self.pool.set_chunkable_memory_fn(self._chunkable_budget,
                                          tenant=self.tenant,
                                          basis_bytes=device_share)

        # ---- activation chunk stream (the fifth managed stream) ---------
        # Checkpointed layer inputs become chunks in the same pool: written
        # once in FWD, read once at the mirrored BWD layer, then freed.  No
        # fp32 master / ADAM refs, and rank-local under nproc > 1 (never
        # gathered or reduced).  Built lazily at the first forward_embed —
        # the act chunk layout is batch-shape-dependent.
        self.manage_activations = manage_activations
        # strict mode: refuse to clamp the chunkable budget up to the
        # working-set floor — when the traced non-model footprint leaves
        # less device memory than one operator's working set, raise
        # OutOfMemory instead (the honest "does this batch fit" signal the
        # max-batch benchmark binary-searches on).
        self.strict_device_budget = strict_device_budget
        self.act_mgr: ChunkManager | None = None
        self.act_cmap = None
        self._act_numel = 0
        self._batch_sig: tuple | None = None
        self._batch_tokens_shape: tuple[int, int] = (1, 1)
        # schedule-driven prefetcher (installed after the warm-up
        # iteration).  OPT only: staging consumes the same future-reference
        # schedule, and running it under lru/fifo would contaminate those
        # baselines with future knowledge.
        self.prefetcher = self._lease.prefetcher(
            lookahead=prefetch_lookahead,
            bandwidth_aware=bandwidth_aware_prefetch) if prefetch else None

        # initialize payloads: param fp16 stream + param fp32 copies, for
        # the chunks THIS rank owns (every chunk when nproc == 1); tensors
        # in non-owned chunks enter the RELEASED remote lifecycle.
        for name, val in named:
            if self.cmap.chunk_owner(self.cmap.placement(name).chunk_id) != rank:
                continue
            view = self.params_mgr.access_tensor(name, "host")
            view[...] = np.asarray(val, np.float32)
            self.params_mgr.release_tensor(name, TensorState.HOLD)
            p32 = self.os_mgrs["p32"].access_tensor(name, "host")
            p32[...] = np.asarray(val, np.float32)
            self.os_mgrs["p32"].release_tensor(name, TensorState.HOLD)
            for s in ("m", "v"):
                self.os_mgrs[s].access_tensor(name, "host")
                self.os_mgrs[s].release_tensor(name, TensorState.HOLD)
        if nproc > 1:
            for c in range(self.cmap.num_chunks):
                if self.cmap.chunk_owner(c) != rank and self.cmap.chunk_tensors(c):
                    self.params_mgr.mark_released(c)

        self.step_count = 0
        self.placement: PlacementPlan | None = None
        self._live_activation_bytes = 0
        self._moment_of_op: dict[str, int] = {}

    # ------------------------------------------------------------------ utils
    def _moment(self, op: str, phase: str) -> None:
        m = self.tracer.record_moment(op, phase, self._live_activation_bytes)
        self.tenant.set_moment(m)
        tel = self.pool.telemetry
        if tel is not None:
            tel.switch_span(self.tenant.qualify("moments"), f"{op}:{phase}",
                            ts=self.pool._now(), moment=m,
                            tenant=self.tenant.name,
                            rank=self.pool.telemetry_rank)
        # schedule-driven prefetch: stage the next-k chunk references
        # before the operator at this moment runs (their H2D overlaps it)
        if self.prefetcher is not None and not self.tracer.warmup:
            self.prefetcher.advance(m)
        # the driver's gather prefetcher walks the same moment cursor:
        # upcoming remote groups are all-gathered ahead of their operator.
        # Advanced once per lock-step moment from the LAST rank — it runs
        # each layer after all others, so when its cursor moves every rank
        # has finished the layer's state transitions and a group is either
        # fully released everywhere or fully resident (never mixed).
        if self.collective is not None and self.rank == self.nproc - 1 \
                and not self.tracer.warmup:
            self.collective.advance_prefetch(m)

    # ------------------------------------------------------ activation stream
    def _chunkable_budget(self) -> int:
        """Device bytes the pool may use for chunks right now: the traced
        chunkable memory, floored at one operator's working set (layer
        param chunks + in-flight comm group + ADAM quad + the act chunks
        the operator reads/writes).  In strict mode the floor is a
        feasibility CHECK, not a clamp: a post-warm-up moment whose
        non-model footprint leaves less than the floor raises
        OutOfMemory — that batch does not fit this device."""
        floor = self._model_floor_bytes + self._act_floor_bytes()
        dyn = self.tracer.chunkable_memory()
        if dyn < floor and self.strict_device_budget and not self.tracer.warmup:
            raise OutOfMemory(
                f"strict device budget: chunkable memory {dyn} at the "
                f"current moment is below the working-set floor {floor} "
                f"(device {self.tracer.device_total_bytes} bytes cannot "
                f"hold this batch's non-model footprint plus one "
                f"operator's chunks)")
        return max(dyn, floor)

    def _act_floor_bytes(self) -> int:
        """Act chunks co-resident with one operator: the input being
        written (FWD) or read (BWD) plus one staged neighbour."""
        return 2 * self.act_mgr.chunk_bytes if self.act_mgr is not None else 0

    def _ensure_act_stream(self, x) -> None:
        """(Re)build the act stream for this batch's activation shape.
        Called from forward_embed, where the embed output — the input of
        every checkpointed layer — is first known."""
        if not self.manage_activations:
            return
        numel = int(x.size)
        if self.act_mgr is not None and numel == self._act_numel:
            return
        if self.act_mgr is not None:
            # batch shape changed: the act chunk layout is stale (and the
            # traced schedules with it — they re-form on the next warm-up)
            self.pool.unregister_stream(self.tenant.qualify("act"))
        names = [f"act.{g.name}.{i}"
                 for g in self.model.groups() for i in range(g.length)]
        self.act_cmap = build_act_chunk_map(names, numel)
        self.act_mgr = self._lease.stream("act", self.act_cmap)
        self._act_numel = numel

    def _save_activation(self, gname: str, layer: int, x):
        """FWD half of the act lifecycle: park the checkpointed input in
        its act chunk (FREE -> COMPUTE -> HOLD_AFTER_FWD) and return the
        reference stored in ``st.saved``.  Falls back to holding the live
        array when the stream is off or the shape does not match the
        stream's layout (defensive: no current eager model changes x
        shape between layers)."""
        if self.act_mgr is None or int(x.size) != self._act_numel:
            return x
        cb = self.act_mgr.chunk_bytes
        budget = self.pool.device_budget()
        host_cap = self.pool.host_capacity
        slow_cap = self.pool.slow_capacity
        if (budget is not None and host_cap is not None
                and self.pool.device_bytes_used() + cb > budget
                and self.pool.host_bytes_used() + cb > host_cap
                and (slow_cap is None
                     or self.pool.slow_bytes_used() + cb > slow_cap)):
            # Fig. 10's dual-constrained corner: the device is over its
            # dynamic budget (margin-overflow spills) AND every lower
            # tier is full, so admitting would only ping-pong evictions
            # between the full tiers.  Refuse up-front — eviction
            # attempts are not free, they relocate chunks — and hold the
            # input live, honestly counted as non-model bytes.  A slow
            # tier with headroom lifts the refusal: host evictions can
            # demote further down instead of bouncing.
            return x
        name = f"act.{gname}.{layer}"
        try:
            view = self.act_mgr.access_tensor(name, "device")
        except OutOfMemory:
            # backstop for admission failures the cheap pre-check above
            # cannot see; same graceful degradation
            return x
        if self.tracer.warmup:
            self.tracer.record_chunk_use(
                self.act_cmap.placement(name).chunk_id, stream="act")
        view[...] = np.asarray(x, np.float32).reshape(-1)
        self.act_mgr.release_tensor(name, TensorState.HOLD_AFTER_FWD)
        return _ActRef(name, tuple(x.shape), x.dtype)

    def _fetch_activation(self, saved):
        """BWD half: re-materialize the checkpointed input from its act
        chunk (HOLD_AFTER_FWD -> COMPUTE -> FREE; read once, then the
        payload is dropped)."""
        if not isinstance(saved, _ActRef):
            return saved
        if self.tracer.warmup:
            self.tracer.record_chunk_use(
                self.act_cmap.placement(saved.name).chunk_id, stream="act")
        try:
            view = self.act_mgr.access_tensor(saved.name, "device")
        except OutOfMemory:
            # pathological dual-tight budgets can refuse the H2D move;
            # the data must still be read — consume it in place (the one
            # transfer this skips is exactly the move the pool refused)
            view = self.act_mgr.tensor_view(saved.name)
            self.act_mgr.force_tensor_state(saved.name, TensorState.COMPUTE)
        # fp32 chunk payload -> original dtype: exact for fp32 compute,
        # exact upcast round-trip for bf16
        x_in = jnp.asarray(
            np.array(view, copy=True).reshape(saved.shape)).astype(saved.dtype)
        self.act_mgr.release_tensor(saved.name, TensorState.FREE)
        return x_in

    def _fetch_layer_groups(self, gname: str, layer: int) -> None:
        """Demand half of Algorithm 1 line 12: any chunk of this layer
        still in the RELEASED remote lifecycle pulls in its whole
        communication group by all-gather before the operator runs."""
        if self.collective is None:
            return
        timed = self.pool.timeline is not None
        groups: set[int] = set()
        for n in self._group_tensor_names[gname][layer]:
            chunk_id = self.cmap.placement(n).chunk_id
            if timed:
                groups.add(self.cmap.comm_group(chunk_id))
            if self.params_mgr.chunk_state(chunk_id) is ChunkState.RELEASED:
                self.collective.fetch_group(self.cmap.comm_group(chunk_id))
        if timed:
            # this operator consumes the layer's groups: a prefetched
            # gather still on the collective wire stalls it for the
            # remainder
            for grp in sorted(groups):
                self.pool.timeline.wait_for(("gather", grp))

    def _access_layer(self, gname: str, layer: int, mgr: ChunkManager,
                      dev: str, record: bool = True):
        names = self._group_tensor_names[gname][layer]
        arrs = []
        for n in names:
            if record and self.tracer.warmup:
                self.tracer.record_chunk_use(
                    self.cmap.placement(n).chunk_id, stream=mgr.name)
            # COPY at the numpy->jax boundary: jnp.asarray on CPU may be
            # zero-copy, and grad-fp16 reuse later overwrites this chunk
            # payload in place (Fig. 6) — an alias would corrupt captured
            # parameter values mid-backward.
            arrs.append(jnp.array(mgr.access_tensor(n, dev), copy=True))
        tree = jax.tree_util.tree_unflatten(self._layer_trees[gname], arrs)
        return names, tree

    def _release_layer(self, names, mgr: ChunkManager, state: TensorState):
        for n in names:
            mgr.release_tensor(n, state)

    def _groups_completing(self, gname: str, layer: int,
                           state: TensorState) -> list[int]:
        """Communication groups this layer touches whose every tensor has
        now reached ``state`` (Algorithm 2's post-FWD/BWD group check)."""
        groups = sorted({
            self.cmap.tensor_comm_group(n)
            for n in self._group_tensor_names[gname][layer]})
        return [g for g in groups
                if self.params_mgr.comm_group_state_complete(g, state)]

    def _release_remote_of_group(self, group: int) -> None:
        """Algorithm 1 line 18: after the group's post-FWD transition the
        non-owned chunk replicas are dropped back to RELEASED.  The
        driver is notified so the gather prefetcher can retire the
        group's staged-gather slot once every rank has dropped (its
        in-flight cap bounds replicas actually held)."""
        for c in self.cmap.comm_group_chunk_ids(group):
            if self.cmap.chunk_owner(c) != self.rank and self.cmap.chunk_tensors(c):
                self.params_mgr.mark_released(c)
        if self.collective is not None:
            self.collective.retire_group(group)

    # ------------------------------------------------------------ step phases
    # step() composes these in order; the rank-parallel driver interleaves
    # them across ranks in lock-step (layer granularity), inserting the
    # collectives at communication-group boundaries.

    def begin_step(self, batch: dict) -> _StepState:
        # the warm-up profile predicts later iterations only while the
        # compute pattern repeats (Section 8.1); a batch-shape change
        # invalidates the traced non-model curve, the per-stream OPT
        # schedules AND the act chunk layout — re-arm the warm-up so this
        # step re-traces and end_step re-installs everything fresh
        sig = tuple(sorted(
            (k, tuple(getattr(v, "shape", ()))) for k, v in batch.items()))
        if self._batch_sig is not None and sig != self._batch_sig:
            self.tracer.warmup = True
            if self.timeline is not None:
                # the traced moment schedule (and with it the per-moment
                # durations) is stale; re-installed after the re-warm-up
                self.timeline.install_durations(
                    {}, tenant=self.tenant.timeline_ns)
        self._batch_sig = sig
        tok = batch.get("tokens")
        if tok is not None and getattr(tok, "ndim", 0) >= 2:
            self._batch_tokens_shape = (int(tok.shape[0]), int(tok.shape[1]))
        self.tracer.begin_iteration()
        tel = self.pool.telemetry
        if tel is not None:
            tel.begin_span(self.tenant.qualify("step"),
                           f"step{self.step_count}", ts=self.pool._now(),
                           tenant=self.tenant.name,
                           rank=self.pool.telemetry_rank)
        st0, pf0 = self.tenant.snapshot()
        return _StepState(
            batch=batch, met=EngineMetrics(),
            h2d0=st0.h2d_bytes, d2h0=st0.d2h_bytes, pf0=pf0)

    def forward_embed(self, st: _StepState) -> None:
        st.t0 = time.perf_counter()
        st.stem = jax.tree.map(jnp.asarray, self._stem_np)
        st.x, st.extras = self.model.embed(st.stem, st.batch)
        self._ensure_act_stream(st.x)
        self._live_activation_bytes += st.x.size * st.x.dtype.itemsize

    def forward_group_start(self, st: _StepState, gname: str) -> None:
        st.x, st.extras = self.model.between_groups(
            gname, st.x, st.extras, st.stem, st.batch)

    def forward_layer(self, st: _StepState, g, i: int) -> None:
        self._moment(f"{g.name}.{i}", "FWD")
        self._fetch_layer_groups(g.name, i)
        names, ptree = self._access_layer(g.name, i, self.params_mgr, "device")
        x_in = st.x
        saved = self._save_activation(g.name, i, x_in)
        st.saved.append((g.name, i, saved))
        st.x, _aux = g.apply(ptree, x_in, st.extras, self.ctx)
        self._live_activation_bytes += st.x.size * st.x.dtype.itemsize
        if isinstance(saved, _ActRef):
            # the checkpointed input now lives in the act chunk plane
            # (pool-managed, spillable) instead of pinned device memory —
            # this is the batch-size headroom the paper claims
            self._live_activation_bytes -= x_in.size * x_in.dtype.itemsize
        self._release_layer(names, self.params_mgr, TensorState.HOLD_AFTER_FWD)
        # distributed: a communication group whose every tensor is now
        # HOLD_AFTER_FWD is done with forward — remote replicas released
        # (purely local bookkeeping, no collective)
        if self.nproc > 1:
            for grp in self._groups_completing(
                    g.name, i, TensorState.HOLD_AFTER_FWD):
                self._release_remote_of_group(grp)
        self._moment(f"{g.name}.{i}.end", "FWD")

    def end_forward(self, st: _StepState) -> None:
        st.met.fwd_s = time.perf_counter() - st.t0

    def begin_backward(self, st: _StepState) -> None:
        st.t0 = time.perf_counter()
        # reset param states to HOLD before BWD (Section 6.2); RELEASED
        # remote replicas stay released until their group is re-gathered
        self.params_mgr.reset_states(TensorState.HOLD)
        loss, head_vjp = jax.vjp(
            lambda s, xx: self.model.head_loss(s, xx, st.batch), st.stem, st.x)
        st.met.loss = float(loss)
        st.stem_grad, st.gx = head_vjp(jnp.float32(1.0))

    def backward_layer(self, st: _StepState, idx: int) -> list[int]:
        """Run BWD for ``st.saved[idx]``; returns the communication groups
        that completed HOLD_AFTER_BWD on this rank (the driver
        reduce-scatters them once every rank has finished the layer)."""
        g, i, saved = st.saved[idx]
        grp = next(gg for gg in self.model.groups() if gg.name == g)
        self._moment(f"{g}.{i}", "BWD")
        self._fetch_layer_groups(g, i)
        x_in = self._fetch_activation(saved)
        names, ptree = self._access_layer(g, i, self.params_mgr, "device")
        # activation checkpointing: recompute fwd inside vjp
        _, vjp_fn = jax.vjp(
            lambda p, xx: grp.apply(p, xx, st.extras, self.ctx)[0], ptree, x_in)
        gp, st.gx = vjp_fn(st.gx)
        # grad fp16 reuses the param fp16 chunk payload (Fig. 6): after
        # BWD of this operator the param values are overwritten (on every
        # rank — each replica now carries that rank's grad contribution,
        # which is exactly what the reduce-scatter sums onto the owner).
        for n, gleaf in _leaves_with_names(gp, f"{g}.{i}"):
            view = self.params_mgr.tensor_view(n)
            view[...] = np.asarray(gleaf, np.float32)
        self._release_layer(names, self.params_mgr, TensorState.HOLD_AFTER_BWD)
        if not isinstance(saved, _ActRef):
            # chunk-managed inputs were uncounted at save time; only live
            # (fallback-held) arrays still contribute to the footprint
            self._live_activation_bytes -= max(
                x_in.size * x_in.dtype.itemsize, 0)
        done = self._groups_completing(g, i, TensorState.HOLD_AFTER_BWD) \
            if self.nproc > 1 else []
        self._moment(f"{g}.{i}.end", "BWD")
        return done

    def backward_embed(self, st: _StepState) -> None:
        """Close the gradient path through the embedding: the head vjp in
        :meth:`begin_backward` only covers final-norm + LM head, and the
        layer loop ends with ``gx = d loss / d x_embed`` — without this
        vjp the embedding table would never see that contribution (and the
        eager trajectory would drift from the compiled runtime's, whose
        autodiff spans the whole step).  Exact when ``between_groups`` is
        the identity (every current eager-engine model)."""
        _, embed_vjp = jax.vjp(
            lambda s: self.model.embed(s, st.batch)[0], st.stem)
        (emb_grad,) = embed_vjp(st.gx)
        st.stem_grad = jax.tree.map(jnp.add, st.stem_grad, emb_grad)

    def end_backward(self, st: _StepState) -> None:
        st.met.bwd_s = time.perf_counter() - st.t0
        st.met.h2d_bytes = self.tenant.stats.h2d_bytes - st.h2d0
        st.met.d2h_bytes = self.tenant.stats.d2h_bytes - st.d2h0

    def adam_chunks(self, st: _StepState) -> None:
        """Chunked ADAM over the chunks THIS rank owns (Section 7: "the
        ADAM stage is executed locally" — after the reduce-scatter the
        owner's grad chunk already holds the cross-rank sum)."""
        st.t0 = time.perf_counter()
        a_h2d0, a_d2h0 = (self.tenant.stats.h2d_bytes,
                          self.tenant.stats.d2h_bytes)
        b1, b2 = self.betas
        t = self.step_count + 1
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        dev_groups = self.placement.os_device_groups if self.placement else 0
        for g_idx in range(self.cmap.num_comm_groups):
            # device-aware operator placement: first `dev_groups` OS chunk
            # groups update on device (margin space), the rest on host
            comp_dev = "device" if g_idx < dev_groups else "host"
            for chunk_id in self.cmap.comm_group_chunk_ids(g_idx):
                if self.nproc > 1 and self.cmap.chunk_owner(chunk_id) != self.rank:
                    continue
                if not self.cmap.chunk_tensors(chunk_id):
                    continue
                self._adam_chunk(chunk_id, comp_dev, bc1, bc2)
        st.met.adam_h2d_bytes = self.tenant.stats.h2d_bytes - a_h2d0
        st.met.adam_d2h_bytes = self.tenant.stats.d2h_bytes - a_d2h0
        st.met.adam_s = time.perf_counter() - st.t0

    def _adam_chunk(self, chunk_id: int, comp_dev: str,
                    bc1: float, bc2: float) -> None:
        b1, b2 = self.betas
        self._moment(f"adam.{chunk_id}", "ADAM")
        if self.tracer.warmup:
            for s in ("param", "p32", "m", "v"):
                self.tracer.record_chunk_use(chunk_id, stream=s, dev=comp_dev)
        # grad chunk (reusing param chunk payload) converted fp32 on the
        # fly on the computing device; all four streams' chunks must
        # co-reside for the update, so pin them — the shared pool would
        # otherwise be free to evict the earlier ones while admitting the
        # later ones.
        quad = [self.params_mgr, self.os_mgrs["p32"],
                self.os_mgrs["m"], self.os_mgrs["v"]]
        pinned = []
        try:
            payloads = []
            for smgr in quad:
                payloads.append(smgr.prepare_payload(chunk_id, comp_dev))
                smgr.pin(chunk_id)
                pinned.append(smgr)
            grad_payload, p32, m, v = payloads
            g = grad_payload
            m[...] = b1 * m + (1 - b1) * g
            v[...] = b2 * v + (1 - b2) * g * g
            upd = (m / bc1) / (np.sqrt(v / bc2) + self.eps)
            p32[...] = p32 - self.lr * upd
            # updated param fp32 copied back into the param chunk
            grad_payload[...] = p32
        finally:
            for smgr in pinned:
                smgr.unpin(chunk_id)
        for tn in self.cmap.chunk_tensors(chunk_id):
            self.params_mgr.force_tensor_state(tn.name, TensorState.HOLD)

    def update_stem(self, stem_grad) -> None:
        """Stem (embedding + norms) update on its own device — real ADAM
        with per-leaf moments, the same hyperparameters and bias
        correction as the chunked streams (not the SGD shortcut: the two
        paths must optimize consistently)."""
        b1, b2 = self.betas
        t = self.step_count + 1
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        leaves, treedef = jax.tree_util.tree_flatten(self._stem_np)
        gleaves = jax.tree_util.tree_leaves(stem_grad)
        if self._stem_m is None:
            self._stem_m = [np.zeros_like(p, dtype=np.float32) for p in leaves]
            self._stem_v = [np.zeros_like(p, dtype=np.float32) for p in leaves]
        new = []
        for i, (p, gv) in enumerate(zip(leaves, gleaves)):
            g = np.asarray(gv, np.float32)
            self._stem_m[i] = b1 * self._stem_m[i] + (1 - b1) * g
            self._stem_v[i] = b2 * self._stem_v[i] + (1 - b2) * g * g
            upd = (self._stem_m[i] / bc1) / (
                np.sqrt(self._stem_v[i] / bc2) + self.eps)
            new.append(np.asarray(p - self.lr * upd, dtype=p.dtype))
        self._stem_np = jax.tree_util.tree_unflatten(treedef, new)

    def end_step(self, st: _StepState) -> EngineMetrics:
        met = st.met
        # ----------------------------------- overlap / prefetch accounting
        pf = self.tenant.prefetch
        met.hidden_h2d_bytes = pf.hidden_h2d_bytes - st.pf0.hidden_h2d_bytes
        met.critical_h2d_bytes = pf.critical_h2d_bytes - st.pf0.critical_h2d_bytes
        met.prefetch_hits = pf.hits - st.pf0.hits
        met.demand_misses = pf.demand_misses - st.pf0.demand_misses
        met.peak_device_bytes = self.tenant.take_step_peak_device_bytes()

        # ----------------------------------------------- end of iteration
        self._live_activation_bytes = 0
        if self.tracer.warmup:
            self.tracer.end_warmup()
            self._plan_placement()
            # per-stream OPT schedules over *device* references: a param
            # chunk's next device use may be in FWD/BWD (or ADAM when its
            # group updates in GPU margin space), an OS chunk's only at a
            # device-placed ADAM moment.  The warm-up ran all ADAM on the
            # host, so promote the host-side refs of groups the plan just
            # moved onto the device.
            promote: dict[str, set[int]] = {}
            if self.placement is not None and self.placement.os_device_groups:
                dev_chunks = self.placement.os_device_chunk_ids(self.cmap)
                promote = {s: dev_chunks for s in ("param", "p32", "m", "v")}
            by_stream = self.tracer.schedule_by_stream(promote_chunks=promote)
            self.params_mgr.register_moments(by_stream.get("param", {}))
            for name, m in self.os_mgrs.items():
                m.register_moments(by_stream.get(name, {}))
            if self.act_mgr is not None:
                # act chunks: exactly two refs each (FWD write, mirrored
                # BWD read) — the reuse distance OPT and the prefetcher
                # exploit to spill/restage activations mid-step
                self.act_mgr.register_moments(by_stream.get("act", {}))
            if self.prefetcher is not None:
                # tracer stream labels are tenant-local; the pool's
                # stream registry keys are tenant-qualified
                self.prefetcher.install(
                    [(m, self.tenant.qualify(s), c) for m, s, c in
                     self.tracer.reference_sequence(by_stream)])
        if self.timeline is not None:
            met.timeline = self.timeline.take_step()
            if not self.tracer.warmup and not self.timeline.has_durations_for(
                    self.tenant.timeline_ns):
                # first post-warm-up install (and re-install after a
                # batch-shape re-warm-up): the traced moments now exist
                self.timeline.install_durations(
                    self._moment_durations(),
                    tenant=self.tenant.timeline_ns)
        tel = self.pool.telemetry
        if tel is not None:
            # close AFTER take_step so the span end covers the drain
            # stalls booked inside it
            ts = self.pool._now()
            rank = self.pool.telemetry_rank
            tel.close_span(self.tenant.qualify("moments"), ts=ts, rank=rank)
            tel.close_span(self.tenant.qualify("step"), ts=ts, rank=rank)
            tel.snapshot(
                f"{self.tenant.name}:step{self.step_count}", ts=ts,
                rank=rank, loss=met.loss,
                h2d_bytes=self.tenant.stats.h2d_bytes - st.h2d0,
                d2h_bytes=self.tenant.stats.d2h_bytes - st.d2h0,
                hidden_h2d_bytes=met.hidden_h2d_bytes,
                critical_h2d_bytes=met.critical_h2d_bytes,
                prefetch_hits=met.prefetch_hits,
                demand_misses=met.demand_misses,
                peak_device_bytes=met.peak_device_bytes)
        self.step_count += 1
        return met

    def _moment_durations(self) -> dict[int, float]:
        """Per-moment compute durations for the transfer timeline,
        derived from the analytical cost model over this batch shape."""
        from repro.analysis.costmodel import train_operator_costs

        b, s = self._batch_tokens_shape
        costs = train_operator_costs(
            self.cfg, global_batch=b, seq_len=s,
            num_layer_ops=sum(g.length for g in self.model.groups()),
            chunk_bytes=self.params_mgr.chunk_bytes)
        return self.tracer.duration_schedule(costs.of_moment)

    # ------------------------------------------------------------------ step
    def step(self, batch: dict) -> EngineMetrics:
        """One fused FWD+BWD+ADAM iteration (single-rank composition of
        the phase methods above)."""
        st = self.begin_step(batch)
        self.forward_embed(st)
        for g in self.model.groups():
            self.forward_group_start(st, g.name)
            for i in range(g.length):
                self.forward_layer(st, g, i)
        self.end_forward(st)
        self.begin_backward(st)
        for idx in range(len(st.saved) - 1, -1, -1):
            self.backward_layer(st, idx)
        self.backward_embed(st)
        self.end_backward(st)
        self.adam_chunks(st)
        self.update_stem(st.stem_grad)
        return self.end_step(st)

    # -------------------------------------------------------------- placement
    def _plan_placement(self) -> None:
        if not self.device_aware_placement:
            self.placement = None
            return
        layer0 = self._group_tensor_names[self.model.groups()[0].name][0]
        working = sum(
            int(np.prod(self.cmap.placement(n).shape)) * 4 for n in layer0)
        margin = self.tracer.margin_space(working * 2)
        # per-rank model bytes: this rank owns 1 chunk of each group's
        # nproc, so both the OS "local group" unit (3 fp32 chunks) and the
        # local param-fp16 bytes scale by 1/nproc.
        self.placement = plan_placement(
            margin_bytes=margin,
            num_local_groups=self.cmap.num_comm_groups,
            chunk_size_elems=self.cmap.chunk_size,
            param_fp16_local_bytes=self.cmap.capacity * 4 // max(self.nproc, 1),
            device_total_bytes=self.tracer.device_total_bytes,
            peak_nonmodel_bytes=self.tracer.peak_nonmodel_bytes,
            vocab_size=self.cfg.vocab_size, hidden=self.cfg.d_model,
            batch_tokens=0,
            act_working_bytes=self._act_floor_bytes(),
            host_capacity_bytes=self._lease.host_bytes,
            slow_capacity_bytes=self._lease.slow_bytes,
        )


def initialize_engine(model_func: Callable[[], tuple], config: dict):
    """Paper Listing 1:  model, optimizer = initialize_engine(...)

    ``model_func`` returns (model_cls, cfg); ``config`` carries the
    memory/optimizer settings.  The returned engine exposes the familiar
    loop surface: ``loss = model(batch); model.backward(loss);
    optimizer.step()`` — internally one fused :meth:`PatrickStarEngine.step`.
    """
    model_cls, cfg = model_func()
    engine = PatrickStarEngine(model_cls, cfg, **config)

    class _ModelFacade:
        def __init__(self, eng):
            self._eng = eng
            self._pending = None

        def __call__(self, batch):
            self._pending = batch
            return self  # loss proxy; materialized in backward()

        def backward(self, _loss_proxy):
            self._metrics = self._eng.step(self._pending)
            self.loss = self._metrics.loss

    class _OptimizerFacade:
        def __init__(self, eng):
            self._eng = eng

        def zero_grad(self):
            pass  # grads live in reused chunks; nothing to zero

        def step(self):
            pass  # fused into engine.step (ADAM stage)

    return _ModelFacade(engine), _OptimizerFacade(engine)
