"""PatrickStarEngine — the paper's runtime, eagerly executed.

This is the faithful single-device system of Sections 6 and 8: chunked
model data managed over a bounded two-tier (device/host) memory space by
one shared :class:`~repro.core.memory.HeteroMemory` pool (param fp16,
param fp32, momentum and variance are per-stream
:class:`~repro.core.manager.ChunkManager` views of it, so all four
streams compete for ONE device budget and eviction is cross-stream),
with

  * the tensor state machine driving chunk movement (Table 1, Fig. 7),
  * grad-fp16 chunks REUSING param-fp16 chunk payloads (Fig. 6),
  * a warm-up iteration feeding the RuntimeMemoryTracer (Section 8.1),
  * OPT/Belady chunk eviction from per-stream traced moment schedules
    (8.3),
  * a schedule-driven prefetcher staging the next-k chunk references
    ahead of their operator after warm-up (simulated-async; H2D bytes are
    classified hidden vs critical-path in :class:`EngineMetrics`),
  * device-aware OS placement in GPU margin space + embedding kept on
    host (Section 8.2),
  * block-granular activation checkpointing (inputs saved, fwd recomputed
    inside jax.vjp during BWD — the re-COMPUTE transitions that make
    HOLD_AFTER_FWD/BWD states necessary).

On this container the "device" tier is simulated: payloads are numpy
buffers tagged device/host with byte-capacity enforcement and full
transfer accounting, so eviction-policy quality and data-movement volume
are measured exactly as the paper measures them.  Compute runs through
jax on CPU.  The API mirrors the paper's Listing 1.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import dtype_of
from repro.core.chunk import TensorSpec, build_chunk_map, search_chunk_size
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, SchedulePrefetcher
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.state import TensorState
from repro.core.tracer import RuntimeMemoryTracer
from repro.models.api import Model
from repro.models.layers import AxisCtx


def _leaves_with_names(tree, prefix: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(prefix + jax.tree_util.keystr(path), leaf) for path, leaf in flat]


@dataclasses.dataclass
class EngineMetrics:
    fwd_s: float = 0.0
    bwd_s: float = 0.0
    adam_s: float = 0.0
    loss: float = 0.0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    adam_h2d_bytes: int = 0
    adam_d2h_bytes: int = 0
    # overlap accounting (schedule-driven prefetch, post-warm-up):
    # every H2D byte this step is either hidden (staged ahead of its use,
    # overlappable with compute) or critical-path (a demand miss).
    hidden_h2d_bytes: int = 0
    critical_h2d_bytes: int = 0
    prefetch_hits: int = 0
    demand_misses: int = 0
    # high-water mark of the unified pool's device tier (cumulative)
    peak_device_bytes: int = 0

    @property
    def total_s(self) -> float:
        return self.fwd_s + self.bwd_s + self.adam_s

    @property
    def moved_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes + self.adam_h2d_bytes + self.adam_d2h_bytes

    @property
    def prefetch_hit_rate(self) -> float:
        total = self.prefetch_hits + self.demand_misses
        return self.prefetch_hits / total if total else 0.0


class PatrickStarEngine:
    def __init__(
        self,
        model_cls,
        cfg,
        *,
        device_memory_bytes: int,
        host_memory_bytes: int | None = None,
        policy: str = "opt",
        chunk_size: int | None = None,
        warmup_chunk_fraction: float = 0.2,
        lr: float = 1e-3,
        betas: tuple[float, float] = (0.9, 0.95),
        eps: float = 1e-8,
        seed: int = 0,
        device_aware_placement: bool = True,
        embedding_on_host: bool = True,
        prefetch: bool = True,
        prefetch_lookahead: int = 6,
    ) -> None:
        self.cfg = cfg
        self.ctx = AxisCtx()  # single device, no mesh axes
        self.model: Model = model_cls(cfg, self.ctx)
        self.lr, self.betas, self.eps = lr, betas, eps
        self.device_aware_placement = device_aware_placement
        self.policy = policy

        params = self.model.init_params(jax.random.key(seed))
        # paper 8.2: embedding params are NOT chunk-managed
        self._stem_np = jax.tree.map(np.asarray, params["stem"])
        self.embedding_on_host = embedding_on_host

        # ---- chunk stream over all block-group tensors, model order -----
        named: list[tuple[str, np.ndarray]] = []
        self._group_tensor_names: dict[str, list[list[str]]] = {}
        for g in self.model.groups():
            stacked = params["groups"][g.name]
            per_layer: list[list[str]] = []
            for i in range(g.length):
                layer_tree = jax.tree.map(lambda t: np.asarray(t[i]), stacked)
                pairs = _leaves_with_names(layer_tree, f"{g.name}.{i}")
                per_layer.append([n for n, _ in pairs])
                named.extend(pairs)
            self._group_tensor_names[g.name] = per_layer
        self._layer_trees = {
            g.name: jax.tree_util.tree_structure(
                jax.tree.map(lambda t: t[0], params["groups"][g.name]))
            for g in self.model.groups()
        }

        specs = [TensorSpec(n, tuple(v.shape)) for n, v in named]
        if chunk_size is None:
            res = search_chunk_size(specs, nproc=1, align=256)
            chunk_size = res.chunk_size
        self.cmap = build_chunk_map(specs, chunk_size, nproc=1)

        # ---- ONE heterogeneous memory space shared by all four streams ----
        # (Sections 6.2, 8): param fp16 (grads reuse its payloads), param
        # fp32, momentum and variance are views of a single pool with a
        # single device budget, so eviction sees cross-stream pressure.
        self.pool = HeteroMemory(
            device_capacity_bytes=device_memory_bytes,
            host_capacity_bytes=host_memory_bytes, policy=policy)
        self.params_mgr = ChunkManager(
            self.cmap, dtype=np.float32, name="param", pool=self.pool)
        self.os_mgrs = {
            name: ChunkManager(self.cmap, dtype=np.float32, name=name,
                               pool=self.pool)
            for name in ("p32", "m", "v")
        }
        # tracer over the simulated device
        self.tracer = RuntimeMemoryTracer(
            device_memory_bytes, warmup_chunk_fraction=warmup_chunk_fraction)
        # the chunkable budget must never drop below one operator's working
        # set: the largest layer's param chunks during FWD/BWD, and the four
        # per-stream chunks pinned together during one ADAM chunk update
        # (all are COMPUTE-pinned or refcount-pinned, hence unevictable).
        max_layer_chunks = max(
            len({self.cmap.placement(n).chunk_id for n in layer})
            for layers in self._group_tensor_names.values() for layer in layers)
        floor = max(max_layer_chunks + 1, 5) * self.params_mgr.chunk_bytes
        self.pool.set_chunkable_memory_fn(
            lambda: max(self.tracer.chunkable_memory(), floor))
        # schedule-driven prefetcher (installed after the warm-up
        # iteration).  OPT only: staging consumes the same future-reference
        # schedule, and running it under lru/fifo would contaminate those
        # baselines with future knowledge.
        self.prefetcher = SchedulePrefetcher(
            self.pool, lookahead=prefetch_lookahead) \
            if prefetch and policy == "opt" else None

        # initialize payloads: param fp16 stream + param fp32 copies (host)
        for name, val in named:
            view = self.params_mgr.access_tensor(name, "host")
            view[...] = np.asarray(val, np.float32)
            self.params_mgr.release_tensor(name, TensorState.HOLD)
            p32 = self.os_mgrs["p32"].access_tensor(name, "host")
            p32[...] = np.asarray(val, np.float32)
            self.os_mgrs["p32"].release_tensor(name, TensorState.HOLD)
            for s in ("m", "v"):
                self.os_mgrs[s].access_tensor(name, "host")
                self.os_mgrs[s].release_tensor(name, TensorState.HOLD)

        self.step_count = 0
        self.placement: PlacementPlan | None = None
        self._live_activation_bytes = 0
        self._moment_of_op: dict[str, int] = {}

    # ------------------------------------------------------------------ utils
    def _moment(self, op: str, phase: str) -> None:
        m = self.tracer.record_moment(op, phase, self._live_activation_bytes)
        self.pool.set_moment(m)
        # schedule-driven prefetch: stage the next-k chunk references
        # before the operator at this moment runs (their H2D overlaps it)
        if self.prefetcher is not None and not self.tracer.warmup:
            self.prefetcher.advance(m)

    def _access_layer(self, gname: str, layer: int, mgr: ChunkManager,
                      dev: str, record: bool = True):
        names = self._group_tensor_names[gname][layer]
        arrs = []
        for n in names:
            if record and self.tracer.warmup:
                self.tracer.record_chunk_use(
                    self.cmap.placement(n).chunk_id, stream=mgr.name)
            # COPY at the numpy->jax boundary: jnp.asarray on CPU may be
            # zero-copy, and grad-fp16 reuse later overwrites this chunk
            # payload in place (Fig. 6) — an alias would corrupt captured
            # parameter values mid-backward.
            arrs.append(jnp.array(mgr.access_tensor(n, dev), copy=True))
        tree = jax.tree_util.tree_unflatten(self._layer_trees[gname], arrs)
        return names, tree

    def _release_layer(self, names, mgr: ChunkManager, state: TensorState):
        for n in names:
            mgr.release_tensor(n, state)

    # ------------------------------------------------------------------ step
    def step(self, batch: dict) -> EngineMetrics:
        met = EngineMetrics()
        mgr = self.params_mgr
        h2d0, d2h0 = self.pool.stats.h2d_bytes, self.pool.stats.d2h_bytes
        pf0 = dataclasses.replace(self.pool.prefetch)
        self.tracer.begin_iteration()
        cdtype = dtype_of(self.cfg.compute_dtype)

        # ---------------------------------------------------------- forward
        t0 = time.perf_counter()
        stem = jax.tree.map(jnp.asarray, self._stem_np)
        x, extras = self.model.embed(stem, batch)
        self._live_activation_bytes += x.size * x.dtype.itemsize
        saved: list[tuple[str, int, Any]] = []  # (group, layer, input x)
        for g in self.model.groups():
            x, extras = self.model.between_groups(g.name, x, extras, stem, batch)
            for i in range(g.length):
                self._moment(f"{g.name}.{i}", "FWD")
                names, ptree = self._access_layer(g.name, i, mgr, "device")
                saved.append((g.name, i, x))
                x, _aux = g.apply(ptree, x, extras, self.ctx)
                self._live_activation_bytes += x.size * x.dtype.itemsize
                self._release_layer(names, mgr, TensorState.HOLD_AFTER_FWD)
                self._moment(f"{g.name}.{i}.end", "FWD")
        met.fwd_s = time.perf_counter() - t0

        # --------------------------------------------------------- backward
        t0 = time.perf_counter()
        # reset param states to HOLD before BWD (Section 6.2)
        mgr.reset_states(TensorState.HOLD)
        loss, head_vjp = jax.vjp(
            lambda s, xx: self.model.head_loss(s, xx, batch), stem, x)
        met.loss = float(loss)
        stem_grad, gx = head_vjp(jnp.float32(1.0))
        grads_np: dict[str, np.ndarray] = {}
        groups = list(self.model.groups())
        for g, i, x_in in reversed(saved):
            grp = next(gg for gg in groups if gg.name == g)
            self._moment(f"{g}.{i}", "BWD")
            names, ptree = self._access_layer(g, i, mgr, "device")
            # activation checkpointing: recompute fwd inside vjp
            _, vjp_fn = jax.vjp(
                lambda p, xx: grp.apply(p, xx, extras, self.ctx)[0], ptree, x_in)
            gp, gx = vjp_fn(gx)
            # grad fp16 reuses the param fp16 chunk payload (Fig. 6):
            # after BWD of this operator the param values are overwritten.
            for n, gleaf in _leaves_with_names(gp, f"{g}.{i}"):
                view = mgr.tensor_view(n)
                view[...] = np.asarray(gleaf, np.float32)
            self._release_layer(names, mgr, TensorState.HOLD_AFTER_BWD)
            self._live_activation_bytes -= max(x_in.size * x_in.dtype.itemsize, 0)
            self._moment(f"{g}.{i}.end", "BWD")
        met.bwd_s = time.perf_counter() - t0
        met.h2d_bytes = self.pool.stats.h2d_bytes - h2d0
        met.d2h_bytes = self.pool.stats.d2h_bytes - d2h0

        # ------------------------------------------------------------- ADAM
        t0 = time.perf_counter()
        a_h2d0, a_d2h0 = self.pool.stats.h2d_bytes, self.pool.stats.d2h_bytes
        self._adam(stem_grad)
        met.adam_h2d_bytes = self.pool.stats.h2d_bytes - a_h2d0
        met.adam_d2h_bytes = self.pool.stats.d2h_bytes - a_d2h0
        met.adam_s = time.perf_counter() - t0

        # ------------------------------------- overlap / prefetch accounting
        pf = self.pool.prefetch
        met.hidden_h2d_bytes = pf.hidden_h2d_bytes - pf0.hidden_h2d_bytes
        met.critical_h2d_bytes = pf.critical_h2d_bytes - pf0.critical_h2d_bytes
        met.prefetch_hits = pf.hits - pf0.hits
        met.demand_misses = pf.demand_misses - pf0.demand_misses
        met.peak_device_bytes = self.pool.peak_device_bytes

        # ------------------------------------------------- end of iteration
        self._live_activation_bytes = 0
        if self.tracer.warmup:
            self.tracer.end_warmup()
            self._plan_placement()
            # per-stream OPT schedules over *device* references: a param
            # chunk's next device use may be in FWD/BWD (or ADAM when its
            # group updates in GPU margin space), an OS chunk's only at a
            # device-placed ADAM moment.  The warm-up ran all ADAM on the
            # host, so promote the host-side refs of groups the plan just
            # moved onto the device.
            promote: dict[str, set[int]] = {}
            if self.placement is not None and self.placement.os_device_groups:
                dev_chunks = self.placement.os_device_chunk_ids(self.cmap)
                promote = {s: dev_chunks for s in ("param", "p32", "m", "v")}
            by_stream = self.tracer.schedule_by_stream(promote_chunks=promote)
            self.params_mgr.register_moments(by_stream.get("param", {}))
            for name, m in self.os_mgrs.items():
                m.register_moments(by_stream.get(name, {}))
            if self.prefetcher is not None:
                self.prefetcher.install(
                    self.tracer.reference_sequence(by_stream))
        self.step_count += 1
        return met

    # ------------------------------------------------------------------ adam
    def _adam(self, stem_grad) -> None:
        b1, b2 = self.betas
        t = self.step_count + 1
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        dev_groups = self.placement.os_device_groups if self.placement else 0
        for g_idx in range(self.cmap.num_comm_groups):
            # device-aware operator placement: first `dev_groups` OS chunk
            # groups update on device (margin space), the rest on host
            comp_dev = "device" if g_idx < dev_groups else "host"
            for chunk_id in self.cmap.comm_group_chunk_ids(g_idx):
                tensors = self.cmap.chunk_tensors(chunk_id)
                if not tensors:
                    continue
                self._moment(f"adam.{chunk_id}", "ADAM")
                if self.tracer.warmup:
                    for s in ("param", "p32", "m", "v"):
                        self.tracer.record_chunk_use(chunk_id, stream=s,
                                                     dev=comp_dev)
                # grad chunk (reusing param chunk payload) converted fp32
                # on the fly on the computing device; all four streams'
                # chunks must co-reside for the update, so pin them — the
                # shared pool would otherwise be free to evict the earlier
                # ones while admitting the later ones.
                quad = [self.params_mgr, self.os_mgrs["p32"],
                        self.os_mgrs["m"], self.os_mgrs["v"]]
                pinned = []
                try:
                    payloads = []
                    for smgr in quad:
                        payloads.append(smgr.prepare_payload(chunk_id, comp_dev))
                        smgr.pin(chunk_id)
                        pinned.append(smgr)
                    grad_payload, p32, m, v = payloads
                    g = grad_payload
                    m[...] = b1 * m + (1 - b1) * g
                    v[...] = b2 * v + (1 - b2) * g * g
                    upd = (m / bc1) / (np.sqrt(v / bc2) + self.eps)
                    p32[...] = p32 - self.lr * upd
                    # updated param fp32 copied back into the param chunk
                    grad_payload[...] = p32
                finally:
                    for smgr in pinned:
                        smgr.unpin(chunk_id)
                for tn in tensors:
                    self.params_mgr.force_tensor_state(tn.name, TensorState.HOLD)
        # stem (embedding + norms) updates in place on its own device
        self._stem_np = jax.tree.map(
            lambda p, g: np.asarray(p - self.lr * np.asarray(g, np.float32)),
            self._stem_np, stem_grad)

    # -------------------------------------------------------------- placement
    def _plan_placement(self) -> None:
        if not self.device_aware_placement:
            self.placement = None
            return
        layer0 = self._group_tensor_names[self.model.groups()[0].name][0]
        working = sum(
            int(np.prod(self.cmap.placement(n).shape)) * 4 for n in layer0)
        margin = self.tracer.margin_space(working * 2)
        self.placement = plan_placement(
            margin_bytes=margin,
            num_local_groups=self.cmap.num_comm_groups,
            chunk_size_elems=self.cmap.chunk_size,
            param_fp16_local_bytes=self.cmap.capacity * 4,
            device_total_bytes=self.tracer.device_total_bytes,
            peak_nonmodel_bytes=self.tracer.peak_nonmodel_bytes,
            vocab_size=self.cfg.vocab_size, hidden=self.cfg.d_model,
            batch_tokens=0,
        )


def initialize_engine(model_func: Callable[[], tuple], config: dict):
    """Paper Listing 1:  model, optimizer = initialize_engine(...)

    ``model_func`` returns (model_cls, cfg); ``config`` carries the
    memory/optimizer settings.  The returned engine exposes the familiar
    loop surface: ``loss = model(batch); model.backward(loss);
    optimizer.step()`` — internally one fused :meth:`PatrickStarEngine.step`.
    """
    model_cls, cfg = model_func()
    engine = PatrickStarEngine(model_cls, cfg, **config)

    class _ModelFacade:
        def __init__(self, eng):
            self._eng = eng
            self._pending = None

        def __call__(self, batch):
            self._pending = batch
            return self  # loss proxy; materialized in backward()

        def backward(self, _loss_proxy):
            self._metrics = self._eng.step(self._pending)
            self.loss = self._metrics.loss

    class _OptimizerFacade:
        def __init__(self, eng):
            self._eng = eng

        def zero_grad(self):
            pass  # grads live in reused chunks; nothing to zero

        def step(self):
            pass  # fused into engine.step (ADAM stage)

    return _ModelFacade(engine), _OptimizerFacade(engine)
