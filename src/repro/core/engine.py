"""PatrickStarEngine — the paper's runtime, eagerly executed.

This is the faithful single-device system of Sections 6 and 8: chunked
model data managed over a bounded two-tier (device/host) memory space by
the :class:`~repro.core.manager.ChunkManager`, with

  * the tensor state machine driving chunk movement (Table 1, Fig. 7),
  * grad-fp16 chunks REUSING param-fp16 chunk payloads (Fig. 6),
  * a warm-up iteration feeding the RuntimeMemoryTracer (Section 8.1),
  * OPT/Belady chunk eviction from the traced moment schedule (8.3),
  * device-aware OS placement in GPU margin space + embedding kept on
    host (Section 8.2),
  * block-granular activation checkpointing (inputs saved, fwd recomputed
    inside jax.vjp during BWD — the re-COMPUTE transitions that make
    HOLD_AFTER_FWD/BWD states necessary).

On this container the "device" tier is simulated: payloads are numpy
buffers tagged device/host with byte-capacity enforcement and full
transfer accounting, so eviction-policy quality and data-movement volume
are measured exactly as the paper measures them.  Compute runs through
jax on CPU.  The API mirrors the paper's Listing 1.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import dtype_of
from repro.core.chunk import TensorSpec, build_chunk_map, search_chunk_size
from repro.core.manager import ChunkManager
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.state import TensorState
from repro.core.tracer import RuntimeMemoryTracer
from repro.models.api import Model
from repro.models.layers import AxisCtx


def _leaves_with_names(tree, prefix: str):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(prefix + jax.tree_util.keystr(path), leaf) for path, leaf in flat]


@dataclasses.dataclass
class EngineMetrics:
    fwd_s: float = 0.0
    bwd_s: float = 0.0
    adam_s: float = 0.0
    loss: float = 0.0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    adam_h2d_bytes: int = 0
    adam_d2h_bytes: int = 0

    @property
    def total_s(self) -> float:
        return self.fwd_s + self.bwd_s + self.adam_s

    @property
    def moved_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes + self.adam_h2d_bytes + self.adam_d2h_bytes


class PatrickStarEngine:
    def __init__(
        self,
        model_cls,
        cfg,
        *,
        device_memory_bytes: int,
        host_memory_bytes: int | None = None,
        policy: str = "opt",
        chunk_size: int | None = None,
        warmup_chunk_fraction: float = 0.2,
        lr: float = 1e-3,
        betas: tuple[float, float] = (0.9, 0.95),
        eps: float = 1e-8,
        seed: int = 0,
        device_aware_placement: bool = True,
        embedding_on_host: bool = True,
    ) -> None:
        self.cfg = cfg
        self.ctx = AxisCtx()  # single device, no mesh axes
        self.model: Model = model_cls(cfg, self.ctx)
        self.lr, self.betas, self.eps = lr, betas, eps
        self.device_aware_placement = device_aware_placement
        self.policy = policy

        params = self.model.init_params(jax.random.key(seed))
        # paper 8.2: embedding params are NOT chunk-managed
        self._stem_np = jax.tree.map(np.asarray, params["stem"])
        self.embedding_on_host = embedding_on_host

        # ---- chunk stream over all block-group tensors, model order -----
        named: list[tuple[str, np.ndarray]] = []
        self._group_tensor_names: dict[str, list[list[str]]] = {}
        for g in self.model.groups():
            stacked = params["groups"][g.name]
            per_layer: list[list[str]] = []
            for i in range(g.length):
                layer_tree = jax.tree.map(lambda t: np.asarray(t[i]), stacked)
                pairs = _leaves_with_names(layer_tree, f"{g.name}.{i}")
                per_layer.append([n for n, _ in pairs])
                named.extend(pairs)
            self._group_tensor_names[g.name] = per_layer
        self._layer_trees = {
            g.name: jax.tree_util.tree_structure(
                jax.tree.map(lambda t: t[0], params["groups"][g.name]))
            for g in self.model.groups()
        }

        specs = [TensorSpec(n, tuple(v.shape)) for n, v in named]
        if chunk_size is None:
            res = search_chunk_size(specs, nproc=1, align=256)
            chunk_size = res.chunk_size
        self.cmap = build_chunk_map(specs, chunk_size, nproc=1)

        # ---- two-tier managers: params(fp16-stream, grads reuse) + OS ----
        self.params_mgr = ChunkManager(
            self.cmap, dtype=np.float32, policy=policy, name="param",
            device_capacity_bytes=device_memory_bytes,
            host_capacity_bytes=host_memory_bytes)
        self.os_mgrs = {
            name: ChunkManager(self.cmap, dtype=np.float32, policy=policy,
                               name=name, device_capacity_bytes=device_memory_bytes,
                               host_capacity_bytes=host_memory_bytes)
            for name in ("p32", "m", "v")
        }
        # tracer over the simulated device
        self.tracer = RuntimeMemoryTracer(
            device_memory_bytes, warmup_chunk_fraction=warmup_chunk_fraction)
        # the chunkable budget must never drop below one operator's working
        # set (its chunks are all COMPUTE-pinned and cannot be evicted)
        max_layer_chunks = max(
            len({self.cmap.placement(n).chunk_id for n in layer})
            for layers in self._group_tensor_names.values() for layer in layers)
        floor = (max_layer_chunks + 1) * self.params_mgr.chunk_bytes
        for mgr in [self.params_mgr, *self.os_mgrs.values()]:
            mgr.set_chunkable_memory_fn(
                lambda: max(self.tracer.chunkable_memory(), floor))

        # initialize payloads: param fp16 stream + param fp32 copies (host)
        for name, val in named:
            view = self.params_mgr.access_tensor(name, "host")
            view[...] = np.asarray(val, np.float32)
            self.params_mgr.release_tensor(name, TensorState.HOLD)
            p32 = self.os_mgrs["p32"].access_tensor(name, "host")
            p32[...] = np.asarray(val, np.float32)
            self.os_mgrs["p32"].release_tensor(name, TensorState.HOLD)
            for s in ("m", "v"):
                self.os_mgrs[s].access_tensor(name, "host")
                self.os_mgrs[s].release_tensor(name, TensorState.HOLD)

        self.step_count = 0
        self.placement: PlacementPlan | None = None
        self._live_activation_bytes = 0
        self._moment_of_op: dict[str, int] = {}

    # ------------------------------------------------------------------ utils
    def _moment(self, op: str, phase: str) -> None:
        m = self.tracer.record_moment(op, phase, self._live_activation_bytes)
        for mgr in [self.params_mgr, *self.os_mgrs.values()]:
            mgr.set_moment(m)

    def _access_layer(self, gname: str, layer: int, mgr: ChunkManager,
                      dev: str, record: bool = True):
        names = self._group_tensor_names[gname][layer]
        arrs = []
        for n in names:
            if record and self.tracer.warmup:
                self.tracer.record_chunk_use(self.cmap.placement(n).chunk_id)
            # COPY at the numpy->jax boundary: jnp.asarray on CPU may be
            # zero-copy, and grad-fp16 reuse later overwrites this chunk
            # payload in place (Fig. 6) — an alias would corrupt captured
            # parameter values mid-backward.
            arrs.append(jnp.array(mgr.access_tensor(n, dev), copy=True))
        tree = jax.tree_util.tree_unflatten(self._layer_trees[gname], arrs)
        return names, tree

    def _release_layer(self, names, mgr: ChunkManager, state: TensorState):
        for n in names:
            mgr.release_tensor(n, state)

    # ------------------------------------------------------------------ step
    def step(self, batch: dict) -> EngineMetrics:
        met = EngineMetrics()
        mgr = self.params_mgr
        base = mgr.stats.total_bytes
        h2d0, d2h0 = mgr.stats.h2d_bytes, mgr.stats.d2h_bytes
        self.tracer.begin_iteration()
        cdtype = dtype_of(self.cfg.compute_dtype)

        # ---------------------------------------------------------- forward
        t0 = time.perf_counter()
        stem = jax.tree.map(jnp.asarray, self._stem_np)
        x, extras = self.model.embed(stem, batch)
        self._live_activation_bytes += x.size * x.dtype.itemsize
        saved: list[tuple[str, int, Any]] = []  # (group, layer, input x)
        for g in self.model.groups():
            x, extras = self.model.between_groups(g.name, x, extras, stem, batch)
            for i in range(g.length):
                self._moment(f"{g.name}.{i}", "FWD")
                names, ptree = self._access_layer(g.name, i, mgr, "device")
                saved.append((g.name, i, x))
                x, _aux = g.apply(ptree, x, extras, self.ctx)
                self._live_activation_bytes += x.size * x.dtype.itemsize
                self._release_layer(names, mgr, TensorState.HOLD_AFTER_FWD)
                self._moment(f"{g.name}.{i}.end", "FWD")
        met.fwd_s = time.perf_counter() - t0

        # --------------------------------------------------------- backward
        t0 = time.perf_counter()
        # reset param states to HOLD before BWD (Section 6.2)
        mgr.reset_states(TensorState.HOLD)
        loss, head_vjp = jax.vjp(
            lambda s, xx: self.model.head_loss(s, xx, batch), stem, x)
        met.loss = float(loss)
        stem_grad, gx = head_vjp(jnp.float32(1.0))
        grads_np: dict[str, np.ndarray] = {}
        groups = list(self.model.groups())
        for g, i, x_in in reversed(saved):
            grp = next(gg for gg in groups if gg.name == g)
            self._moment(f"{g}.{i}", "BWD")
            names, ptree = self._access_layer(g, i, mgr, "device")
            # activation checkpointing: recompute fwd inside vjp
            _, vjp_fn = jax.vjp(
                lambda p, xx: grp.apply(p, xx, extras, self.ctx)[0], ptree, x_in)
            gp, gx = vjp_fn(gx)
            # grad fp16 reuses the param fp16 chunk payload (Fig. 6):
            # after BWD of this operator the param values are overwritten.
            for n, gleaf in _leaves_with_names(gp, f"{g}.{i}"):
                view = mgr.tensor_view(n)
                view[...] = np.asarray(gleaf, np.float32)
            self._release_layer(names, mgr, TensorState.HOLD_AFTER_BWD)
            self._live_activation_bytes -= max(x_in.size * x_in.dtype.itemsize, 0)
            self._moment(f"{g}.{i}.end", "BWD")
        met.bwd_s = time.perf_counter() - t0
        met.h2d_bytes = mgr.stats.h2d_bytes - h2d0
        met.d2h_bytes = mgr.stats.d2h_bytes - d2h0

        # ------------------------------------------------------------- ADAM
        t0 = time.perf_counter()
        a_h2d0 = sum(m.stats.h2d_bytes for m in self.os_mgrs.values())
        a_d2h0 = sum(m.stats.d2h_bytes for m in self.os_mgrs.values())
        self._adam(stem_grad)
        met.adam_h2d_bytes = sum(m.stats.h2d_bytes for m in self.os_mgrs.values()) - a_h2d0
        met.adam_d2h_bytes = sum(m.stats.d2h_bytes for m in self.os_mgrs.values()) - a_d2h0
        met.adam_s = time.perf_counter() - t0

        # ------------------------------------------------- end of iteration
        self._live_activation_bytes = 0
        if self.tracer.warmup:
            self.tracer.end_warmup()
            sched = self.tracer.schedule()
            self.params_mgr.register_moments(sched)
            for m in self.os_mgrs.values():
                m.register_moments(sched)
            self._plan_placement()
        self.step_count += 1
        return met

    # ------------------------------------------------------------------ adam
    def _adam(self, stem_grad) -> None:
        b1, b2 = self.betas
        t = self.step_count + 1
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        dev_groups = self.placement.os_device_groups if self.placement else 0
        for g_idx in range(self.cmap.num_comm_groups):
            # device-aware operator placement: first `dev_groups` OS chunk
            # groups update on device (margin space), the rest on host
            comp_dev = "device" if g_idx < dev_groups else "host"
            for chunk_id in self.cmap.comm_group_chunk_ids(g_idx):
                tensors = self.cmap.chunk_tensors(chunk_id)
                if not tensors:
                    continue
                self._moment(f"adam.{chunk_id}", "ADAM")
                # grad chunk (reusing param chunk payload) converted fp32
                # on the fly on the computing device
                grad_payload = self.params_mgr.prepare_payload(chunk_id, comp_dev)
                p32 = self.os_mgrs["p32"].prepare_payload(chunk_id, comp_dev)
                m = self.os_mgrs["m"].prepare_payload(chunk_id, comp_dev)
                v = self.os_mgrs["v"].prepare_payload(chunk_id, comp_dev)
                g = grad_payload
                m[...] = b1 * m + (1 - b1) * g
                v[...] = b2 * v + (1 - b2) * g * g
                upd = (m / bc1) / (np.sqrt(v / bc2) + self.eps)
                p32[...] = p32 - self.lr * upd
                # updated param fp32 copied back into the param chunk
                grad_payload[...] = p32
                for tn in tensors:
                    self.params_mgr._tensor_state[tn.name] = TensorState.HOLD
        # stem (embedding + norms) updates in place on its own device
        self._stem_np = jax.tree.map(
            lambda p, g: np.asarray(p - self.lr * np.asarray(g, np.float32)),
            self._stem_np, stem_grad)

    # -------------------------------------------------------------- placement
    def _plan_placement(self) -> None:
        if not self.device_aware_placement:
            self.placement = None
            return
        layer0 = self._group_tensor_names[self.model.groups()[0].name][0]
        working = sum(
            int(np.prod(self.cmap.placement(n).shape)) * 4 for n in layer0)
        margin = self.tracer.margin_space(working * 2)
        self.placement = plan_placement(
            margin_bytes=margin,
            num_local_groups=self.cmap.num_comm_groups,
            chunk_size_elems=self.cmap.chunk_size,
            param_fp16_local_bytes=self.cmap.capacity * 4,
            device_total_bytes=self.tracer.device_total_bytes,
            peak_nonmodel_bytes=self.tracer.peak_nonmodel_bytes,
            vocab_size=self.cfg.vocab_size, hidden=self.cfg.d_model,
            batch_tokens=0,
        )


def initialize_engine(model_func: Callable[[], tuple], config: dict):
    """Paper Listing 1:  model, optimizer = initialize_engine(...)

    ``model_func`` returns (model_cls, cfg); ``config`` carries the
    memory/optimizer settings.  The returned engine exposes the familiar
    loop surface: ``loss = model(batch); model.backward(loss);
    optimizer.step()`` — internally one fused :meth:`PatrickStarEngine.step`.
    """
    model_cls, cfg = model_func()
    engine = PatrickStarEngine(model_cls, cfg, **config)

    class _ModelFacade:
        def __init__(self, eng):
            self._eng = eng
            self._pending = None

        def __call__(self, batch):
            self._pending = batch
            return self  # loss proxy; materialized in backward()

        def backward(self, _loss_proxy):
            self._metrics = self._eng.step(self._pending)
            self.loss = self._metrics.loss

    class _OptimizerFacade:
        def __init__(self, eng):
            self._eng = eng

        def zero_grad(self):
            pass  # grads live in reused chunks; nothing to zero

        def step(self):
            pass  # fused into engine.step (ADAM stage)

    return _ModelFacade(engine), _OptimizerFacade(engine)
