"""Chunked ZeRO parameter store for the compiled (pjit/shard_map) path.

This module maps PatrickStar's Section 7 onto JAX SPMD:

* A pytree of parameters is packed (append-style, ``core.chunk``) into a
  chunk store laid out ``[G, p, S]``:

    - ``S``  chunk size in elements,
    - ``p``  = ``nproc`` = size of the ZeRO (``data``) mesh axis,
    - ``G``  communication groups; **group g = chunks [g*p, (g+1)*p)** and
      rank ``r`` owns chunk ``g*p + r`` — exactly the paper's layout
      (Fig. 8).

  Sharding the middle axis over ``data`` gives every rank a ``[G, 1, S]``
  local shard; ``all_gather(tiled)`` over ``data`` reconstructs the chunk
  list *in chunk-id order*, which is the paper's all-gather fetch
  (Algorithm 1 / Fig. 9).  The autodiff **transpose of that all-gather is
  a reduce-scatter**, which is the paper's Algorithm 2 gradient path — so
  the 6(p-1)/p * M communication volume falls out of ``jax.grad``.

* Layer stacks used under ``jax.lax.scan`` use a leading layer axis:
  ``[L, G, p, S]``; the scan body gathers only its own layer's groups
  (per-layer fetch) and, under a ``jax.checkpoint`` policy that refuses to
  save gathered params, they are re-gathered during BWD — the compiled
  equivalent of HOLD_AFTER_FWD -> re-fetch.

Everything here is pure and jit-traceable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunk import (
    ChunkMapError,
    ChunkTensorMap,
    TensorSpec,
    build_chunk_map,
    search_chunk_size,
)

# TPU-friendly chunk alignment: payloads tile cleanly into (8,128) vregs
# and MXU-sized blocks; also keeps ICI messages well above the bandwidth
# saturation point (the paper's PCIe 4MB analogue).
CHUNK_ALIGN = 1024


def _names_and_specs(tree: Any) -> tuple[Any, list[str], list[Any]]:
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in leaves_with_path]
    leaves = [leaf for _, leaf in leaves_with_path]
    return treedef, names, leaves


@dataclasses.dataclass(frozen=True)
class ChunkLayout:
    """Static metadata binding a parameter pytree to a chunk store."""

    cmap: ChunkTensorMap
    treedef: Any = dataclasses.field(repr=False, hash=False, compare=False)
    names: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]
    dtype: Any  # store dtype (params: bf16; optimizer state: fp32)

    # ----------------------------------------------------------------- sizes
    @property
    def chunk_size(self) -> int:
        return self.cmap.chunk_size

    @property
    def nproc(self) -> int:
        return self.cmap.nproc

    @property
    def num_groups(self) -> int:
        return self.cmap.num_comm_groups

    @property
    def store_shape(self) -> tuple[int, int, int]:
        """[G, p, S] — shard axis 1 over the ZeRO ('data') mesh axis."""
        return (self.num_groups, self.nproc, self.chunk_size)

    @property
    def capacity(self) -> int:
        return self.cmap.capacity

    @property
    def payload_elems(self) -> int:
        return self.cmap.total_numel

    def store_spec(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.store_shape, self.dtype)

    # ---------------------------------------------------------------- offsets
    def flat_offset(self, name: str) -> int:
        p = self.cmap.placement(name)
        return p.chunk_id * self.chunk_size + p.offset


def make_layout(
    tree: Any,
    *,
    nproc: int,
    dtype: Any = jnp.bfloat16,
    chunk_size: int | None = None,
    memory_budget_elems: int | None = None,
) -> ChunkLayout:
    """Build a :class:`ChunkLayout` for a pytree of arrays/ShapeDtypeStructs.

    When ``chunk_size`` is None, runs the paper's offline chunk-size search
    (utilization-maximizing, alignment ``CHUNK_ALIGN``).
    """
    treedef, names, leaves = _names_and_specs(tree)
    specs = [TensorSpec(n, tuple(int(d) for d in l.shape)) for n, l in zip(names, leaves)]
    if chunk_size is None:
        res = search_chunk_size(
            specs,
            nproc=nproc,
            align=CHUNK_ALIGN,
            memory_budget_elems=memory_budget_elems,
        )
        chunk_size = res.chunk_size
    cmap = build_chunk_map(specs, chunk_size, nproc=nproc)
    return ChunkLayout(
        cmap=cmap,
        treedef=treedef,
        names=tuple(names),
        shapes=tuple(tuple(int(d) for d in l.shape) for l in leaves),
        dtype=dtype,
    )


# ---------------------------------------------------------------------------
# flatten / unflatten (pure, jit-traceable)
# ---------------------------------------------------------------------------


def flatten_to_store(layout: ChunkLayout, tree: Any) -> jax.Array:
    """Pack a parameter pytree into the ``[G, p, S]`` chunk store."""
    treedef, names, leaves = _names_and_specs(tree)
    if tuple(names) != layout.names:
        raise ChunkMapError("pytree does not match layout (leaf names differ)")
    flat = jnp.zeros((layout.capacity,), dtype=layout.dtype)
    for name, leaf in zip(names, leaves):
        off = layout.flat_offset(name)
        leaf = jnp.asarray(leaf, dtype=layout.dtype).reshape(-1)
        flat = jax.lax.dynamic_update_slice(flat, leaf, (off,))
    return flat.reshape(layout.store_shape)


def unflatten_from_flat(layout: ChunkLayout, flat: jax.Array, *, dtype: Any = None) -> Any:
    """Recover the parameter pytree from a flat chunk vector ``[capacity]``."""
    flat = flat.reshape(-1)
    leaves = []
    for name, shape in zip(layout.names, layout.shapes):
        off = layout.flat_offset(name)
        n = int(np.prod(shape)) if shape else 1
        leaf = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        if dtype is not None:
            leaf = leaf.astype(dtype)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def unflatten_from_store(layout: ChunkLayout, store: jax.Array, **kw) -> Any:
    return unflatten_from_flat(layout, store.reshape(-1), **kw)


# ---------------------------------------------------------------------------
# shard_map-side collectives (paper Section 7)
# ---------------------------------------------------------------------------


def gather_store(local_store: jax.Array, axis_name: str) -> jax.Array:
    """All-gather a local ``[..., G, 1, S]`` shard into the full flat chunk
    vector ``[..., G*p*S]`` (Algorithm 1 ``FetchRemoteChunks``).

    Must be called inside ``shard_map``.  The transpose of this op under
    ``jax.grad`` is a reduce-scatter of gradients onto the local shard
    (Algorithm 2 with ``is_allreduce=True``) — PatrickStar's exact
    communication pattern, at 6(p-1)/p*M total volume per step.
    """
    g, one, s = local_store.shape[-3:]
    assert one == 1, f"expected local shard with collapsed ZeRO axis, got {local_store.shape}"
    full = jax.lax.all_gather(local_store, axis_name, axis=-2, tiled=True)
    return full.reshape(*local_store.shape[:-3], -1)


def gather_params(
    layout: ChunkLayout,
    local_store: jax.Array,
    axis_name: str,
    *,
    dtype: Any = None,
) -> Any:
    """Fetch remote chunks and rebuild the parameter pytree (one layer)."""
    flat = gather_store(local_store, axis_name)
    return unflatten_from_flat(layout, flat, dtype=dtype)


# ---------------------------------------------------------------------------
# host/device split for device-aware OS placement (Section 8.2)
# ---------------------------------------------------------------------------


def split_groups(store: jax.Array, device_groups: int) -> tuple[jax.Array, jax.Array]:
    """Split a ``[G, p, S]`` (or ``[L, G, p, S]``) store along G into a
    device-resident head and a host-resident tail, per the placement plan."""
    axis = store.ndim - 3
    g = store.shape[axis]
    device_groups = max(0, min(device_groups, g))
    dev = jax.lax.slice_in_dim(store, 0, device_groups, axis=axis)
    host = jax.lax.slice_in_dim(store, device_groups, g, axis=axis)
    return dev, host


def merge_groups(dev: jax.Array, host: jax.Array) -> jax.Array:
    axis = dev.ndim - 3
    return jax.lax.concatenate([dev, host], dimension=axis)


# ---------------------------------------------------------------------------
# convenience: communication volume cost model (Section 7)
# ---------------------------------------------------------------------------


def comm_volume_bytes(layout, *, itemsize: int = 2) -> dict[str, float]:
    """The paper's analytic inter-GPU volume per iteration.

    chunked (PatrickStar):  2 all-gathers (FWD+BWD) + 1 reduce-scatter
       = 3 * (p-1)/p * 2M = 6(p-1)/p * M bytes (fp16/bf16)
    broadcast (ZeRO-Offload): 2 broadcasts at 2*(p-1)/p*2M each counted on
       the root's link + all-reduce-style grad path = 10(p-1)/p * M.

    ``layout`` may be a :class:`ChunkLayout` or an eager-plane
    :class:`~repro.core.chunk.ChunkTensorMap` (both expose ``nproc``,
    ``payload_elems`` and ``capacity``).  ``chunked_capacity_bytes`` is
    the same 3(p-1)/p model over the padded chunk-store capacity — what
    chunk-granular collectives *actually* move (a tiled ``all_gather`` of
    the [G, p, S] store carries padding too); the eager distributed
    engine's measured ledger matches it exactly, and it exceeds
    ``chunked_allgather_bytes`` by exactly the layout's fragmentation.
    """
    p = layout.nproc
    m_bytes = layout.payload_elems * itemsize
    cap_bytes = layout.capacity * itemsize
    frac = (p - 1) / p if p > 1 else 0.0
    return {
        "chunked_allgather_bytes": 3 * frac * m_bytes,
        "chunked_capacity_bytes": 3 * frac * cap_bytes,
        "broadcast_baseline_bytes": 5 * frac * m_bytes,
        "params_bytes": float(m_bytes),
    }
