"""Runtime chunk manager: heterogeneous placement, pinning, eviction.

This is the paper's runtime module (Sections 6.2, 8.3).  It owns the
payloads of all chunks of one *stream* group (param fp16 / param fp32 /
momentum / variance share a layout but have independent payloads) and
moves them between a bounded **device** tier (GPU in the paper, TPU HBM on
the target) and a **host** tier (CPU DRAM).

On this CPU-only container the two tiers are simulated faithfully:
payloads are numpy buffers tagged with their tier, tier capacities are
enforced in bytes, and every cross-tier move is accounted (bytes + count)
— so eviction-policy quality is measurable exactly the way the paper
measures it (CPU<->GPU data-movement volume).

Eviction (Section 8.3): when the device tier cannot host an incoming
chunk, evict a HOLD-like, unpinned chunk.  Policies:

  "opt"   Belady's OPT using the *future* reference moments collected by
          the runtime memory tracer in the warm-up iteration — evict the
          chunk whose next use is farthest in the future (the paper's
          choice).
  "lru"   least recently used (classic; no future knowledge).
  "fifo"  first-in-first-out.

Chunks in COMPUTE state or explicitly pinned (collective communication in
flight, Algorithm 1 lines 12/18) are never evicted.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import numpy as np

from repro.core.chunk import ChunkTensorMap
from repro.core.state import (
    ChunkState,
    TensorState,
    check_transition,
    derive_chunk_state,
)

Device = Literal["device", "host"]
EvictionPolicy = Literal["opt", "lru", "fifo"]


class OutOfMemory(RuntimeError):
    """Neither tier can host the chunk (the DeepSpeed failure mode, Fig. 10)."""


@dataclasses.dataclass
class TransferStats:
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    h2d_count: int = 0
    d2h_count: int = 0

    @property
    def total_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes

    def reset(self) -> None:
        self.h2d_bytes = self.d2h_bytes = 0
        self.h2d_count = self.d2h_count = 0


@dataclasses.dataclass
class _ChunkRecord:
    chunk_id: int
    payload: np.ndarray | None  # None <=> all tensors FREE, space released
    location: Device | None
    pinned: int = 0  # pin refcount
    last_use: int = -1  # for LRU
    arrival: int = -1  # for FIFO


class ChunkManager:
    """Manages payloads of one chunk stream over a two-tier memory space."""

    def __init__(
        self,
        cmap: ChunkTensorMap,
        *,
        dtype: np.dtype = np.dtype(np.float32),
        device_capacity_bytes: int | None = None,
        host_capacity_bytes: int | None = None,
        policy: EvictionPolicy = "opt",
        name: str = "chunks",
    ) -> None:
        self.cmap = cmap
        self.dtype = np.dtype(dtype)
        self.chunk_bytes = cmap.chunk_size * self.dtype.itemsize
        self.device_capacity = device_capacity_bytes
        self.host_capacity = host_capacity_bytes
        self.policy: EvictionPolicy = policy
        self.name = name
        self.stats = TransferStats()

        self._records = [
            _ChunkRecord(chunk_id=c, payload=None, location=None)
            for c in range(cmap.num_chunks)
        ]
        self._tensor_state: dict[str, TensorState] = {
            p.name: TensorState.FREE for p in cmap.placements
        }
        # clock advances on every access; used by LRU/FIFO and as the
        # "moment" cursor for OPT when no tracer moments are registered.
        self._clock = 0
        # OPT future-reference schedule: chunk_id -> sorted list of moments
        # at which this chunk is used (from the memory tracer's warm-up).
        self._moments: dict[int, list[int]] = {}
        self._current_moment = 0
        # optional callback letting the tracer shrink the device tier by
        # the live non-model footprint at the current moment.
        self._chunkable_device_bytes: Callable[[], int | None] | None = None

    # ------------------------------------------------------------ accounting
    def device_bytes_used(self) -> int:
        return sum(
            self.chunk_bytes
            for r in self._records
            if r.payload is not None and r.location == "device"
        )

    def host_bytes_used(self) -> int:
        return sum(
            self.chunk_bytes
            for r in self._records
            if r.payload is not None and r.location == "host"
        )

    def location(self, chunk_id: int) -> Device | None:
        return self._records[chunk_id].location

    def tensor_state(self, name: str) -> TensorState:
        return self._tensor_state[name]

    def chunk_state(self, chunk_id: int) -> ChunkState:
        names = [p.name for p in self.cmap.chunk_tensors(chunk_id)]
        return derive_chunk_state(self._tensor_state[n] for n in names)

    # -------------------------------------------------------------- schedule
    def register_moments(self, moments: dict[int, list[int]]) -> None:
        """Install the warm-up reference schedule used by OPT eviction."""
        self._moments = {c: sorted(ms) for c, ms in moments.items()}

    def set_moment(self, moment: int) -> None:
        self._current_moment = moment

    def set_chunkable_memory_fn(self, fn: Callable[[], int | None]) -> None:
        """Tracer hook: returns the device bytes currently usable for chunks."""
        self._chunkable_device_bytes = fn

    def _device_budget(self) -> int | None:
        budget = self.device_capacity
        if self._chunkable_device_bytes is not None:
            dyn = self._chunkable_device_bytes()
            if dyn is not None:
                budget = dyn if budget is None else min(budget, dyn)
        return budget

    # ------------------------------------------------------------- tensor API
    def access_tensor(self, name: str, comp_dev: Device = "device") -> np.ndarray:
        """Algorithm 1 (single-process part): bring the tensor's chunk to
        ``comp_dev``, mark the tensor COMPUTE, return a view of its payload."""
        p = self.cmap.placement(name)
        rec = self._ensure_on(p.chunk_id, comp_dev)
        old = self._tensor_state[name]
        check_transition(old, TensorState.COMPUTE)
        self._tensor_state[name] = TensorState.COMPUTE
        view = rec.payload[p.offset : p.offset + p.numel]
        if old is TensorState.FREE:
            view[...] = 0  # Algorithm 1 line 31
        return view.reshape(p.shape)

    def release_tensor(self, name: str, target_state: TensorState) -> None:
        """Algorithm 2 (single-process part)."""
        old = self._tensor_state[name]
        check_transition(old, target_state)
        self._tensor_state[name] = target_state
        if target_state is TensorState.FREE:
            self._maybe_release_chunk(self.cmap.placement(name).chunk_id)

    def reset_states(self, target: TensorState = TensorState.HOLD) -> None:
        """Reset all non-FREE tensors (e.g. to HOLD before BWD, Section 6.2)."""
        for name, s in self._tensor_state.items():
            if s is not TensorState.FREE:
                check_transition(s, target)
                self._tensor_state[name] = target

    def tensor_view(self, name: str) -> np.ndarray:
        """Read-only style access without a state change (debug/checkpoint)."""
        p = self.cmap.placement(name)
        rec = self._records[p.chunk_id]
        if rec.payload is None:
            raise KeyError(f"tensor {name}: chunk {p.chunk_id} has no payload")
        return rec.payload[p.offset : p.offset + p.numel].reshape(p.shape)

    # -------------------------------------------------------------- chunk API
    def pin(self, chunk_id: int) -> None:
        self._records[chunk_id].pinned += 1

    def unpin(self, chunk_id: int) -> None:
        rec = self._records[chunk_id]
        if rec.pinned <= 0:
            raise RuntimeError(f"chunk {chunk_id} is not pinned")
        rec.pinned -= 1

    def prepare_payload(self, chunk_id: int, comp_dev: Device = "device") -> np.ndarray:
        """Materialize (if FREE) and move a chunk to ``comp_dev``."""
        return self._ensure_on(chunk_id, comp_dev).payload

    def ensure_on(self, chunk_id: int, dev: Device) -> np.ndarray:
        return self._ensure_on(chunk_id, dev).payload

    def free_chunk(self, chunk_id: int) -> None:
        """Drop a chunk's payload, forcing all its tensors to FREE."""
        for p in self.cmap.chunk_tensors(chunk_id):
            self._tensor_state[p.name] = TensorState.FREE
        rec = self._records[chunk_id]
        rec.payload = None
        rec.location = None

    # --------------------------------------------------------------- internals
    def _maybe_release_chunk(self, chunk_id: int) -> None:
        if self.chunk_state(chunk_id) is ChunkState.FREE:
            rec = self._records[chunk_id]
            rec.payload = None
            rec.location = None

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _ensure_on(self, chunk_id: int, dev: Device) -> _ChunkRecord:
        rec = self._records[chunk_id]
        now = self._tick()
        rec.last_use = now
        if rec.payload is None:
            self._make_room(dev, exclude=chunk_id)
            rec.payload = np.zeros(self.cmap.chunk_size, dtype=self.dtype)
            rec.location = dev
            rec.arrival = now
            return rec
        if rec.location != dev:
            self._make_room(dev, exclude=chunk_id)
            if dev == "device":
                self.stats.h2d_bytes += self.chunk_bytes
                self.stats.h2d_count += 1
            else:
                self.stats.d2h_bytes += self.chunk_bytes
                self.stats.d2h_count += 1
            rec.location = dev
            rec.arrival = now
        return rec

    def _capacity(self, dev: Device) -> int | None:
        return self._device_budget() if dev == "device" else self.host_capacity

    def _used(self, dev: Device) -> int:
        return self.device_bytes_used() if dev == "device" else self.host_bytes_used()

    def _make_room(self, dev: Device, *, exclude: int) -> None:
        cap = self._capacity(dev)
        if cap is None:
            return
        while self._used(dev) + self.chunk_bytes > cap:
            victim = self._pick_victim(dev, exclude=exclude)
            if victim is None:
                raise OutOfMemory(
                    f"{self.name}: cannot fit chunk on {dev}: "
                    f"used={self._used(dev)} cap={cap} and no evictable chunk"
                )
            self._evict(victim, dev)

    def _evictable(self, dev: Device, exclude: int) -> list[_ChunkRecord]:
        out = []
        for rec in self._records:
            if rec.chunk_id == exclude or rec.payload is None or rec.location != dev:
                continue
            if rec.pinned > 0:
                continue
            if self.chunk_state(rec.chunk_id) is ChunkState.COMPUTE:
                continue
            out.append(rec)
        return out

    def _pick_victim(self, dev: Device, *, exclude: int) -> int | None:
        cands = self._evictable(dev, exclude)
        if not cands:
            return None
        if self.policy == "fifo":
            return min(cands, key=lambda r: r.arrival).chunk_id
        if self.policy == "lru":
            return min(cands, key=lambda r: r.last_use).chunk_id
        # OPT / Belady: farthest next use according to the tracer schedule.
        def next_use(rec: _ChunkRecord) -> int:
            ms = self._moments.get(rec.chunk_id)
            if not ms:
                return 2**62  # never used again -> perfect victim
            import bisect

            i = bisect.bisect_right(ms, self._current_moment)
            return ms[i] if i < len(ms) else 2**62

        return max(cands, key=next_use).chunk_id

    def _evict(self, chunk_id: int, from_dev: Device) -> None:
        rec = self._records[chunk_id]
        if self.chunk_state(chunk_id) is ChunkState.FREE:
            rec.payload = None
            rec.location = None
            return
        to_dev: Device = "host" if from_dev == "device" else "device"
        cap = self._capacity(to_dev)
        if cap is not None and self._used(to_dev) + self.chunk_bytes > cap:
            # try to cascade-evict on the destination tier
            victim = self._pick_victim(to_dev, exclude=chunk_id)
            if victim is None:
                raise OutOfMemory(
                    f"{self.name}: eviction target {to_dev} full and no victim"
                )
            self._evict(victim, to_dev)
        if from_dev == "device":
            self.stats.d2h_bytes += self.chunk_bytes
            self.stats.d2h_count += 1
        else:
            self.stats.h2d_bytes += self.chunk_bytes
            self.stats.h2d_count += 1
        rec.location = to_dev
        rec.arrival = self._tick()
