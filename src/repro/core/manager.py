"""Runtime chunk manager: a per-stream view of the unified memory space.

This is the paper's runtime module (Sections 6.2, 8.3).  One
:class:`ChunkManager` owns the payloads and tensor states of one *stream*
(param fp16 / param fp32 / momentum / variance share a layout but have
independent payloads).  All capacity budgeting, transfer accounting and
eviction live in the shared :class:`~repro.core.memory.HeteroMemory`
pool the stream registers with — so a device-tier miss in one stream can
evict a chunk of *any* stream, the paper's single heterogeneous
CPU+GPU memory space.  Constructing a manager without an explicit
``pool`` creates a private single-stream pool, which preserves the
historical standalone behaviour (and API) exactly.

On this CPU-only container the two tiers are simulated faithfully:
payloads are numpy buffers tagged with their tier, tier capacities are
enforced in bytes, and every cross-tier move is accounted (bytes + count)
— so eviction-policy quality is measurable exactly the way the paper
measures it (CPU<->GPU data-movement volume).

Per-stream usage counters are incremental (kept in lock-step with the
pool's global counters), so ``device_bytes_used()`` is O(1) and the
eviction loop never rescans the chunk list to learn the tier occupancy.
Chunk states are likewise tracked incrementally per chunk, making
``chunk_state`` O(1) instead of a scan over the chunk's tensors.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable

import numpy as np

from repro.core.chunk import ChunkTensorMap
from repro.core.memory import (
    Device,
    EvictionPolicy,
    HeteroMemory,
    OutOfMemory,
    Tenant,
    TransferStats,
)
from repro.core.state import (
    ChunkState,
    TensorState,
    check_transition,
)

__all__ = [
    "ChunkManager",
    "Device",
    "EvictionPolicy",
    "HeteroMemory",
    "OutOfMemory",
    "Tenant",
    "TransferStats",
]


@dataclasses.dataclass
class _ChunkRecord:
    chunk_id: int
    payload: np.ndarray | None  # None <=> all tensors FREE, space released
    location: Device | None
    pinned: int = 0  # pin refcount
    last_use: int = -1  # for LRU
    arrival: int = -1  # for FIFO


class ChunkManager:
    """Manages one chunk stream inside a shared two-tier memory space."""

    def __init__(
        self,
        cmap: ChunkTensorMap,
        *,
        dtype: np.dtype = np.dtype(np.float32),
        device_capacity_bytes: int | None = None,
        host_capacity_bytes: int | None = None,
        policy: EvictionPolicy | None = None,
        name: str = "chunks",
        pool: HeteroMemory | None = None,
        tenant: "Tenant | None" = None,
    ) -> None:
        self.cmap = cmap
        self.dtype = np.dtype(dtype)
        self.chunk_bytes = cmap.chunk_size * self.dtype.itemsize
        if tenant is not None:
            if pool is None:
                pool = tenant.pool
            elif tenant.pool is not pool:
                raise ValueError(
                    f"tenant {tenant.name!r} belongs to a different pool")
            # tenant-qualified pool-wide stream name: two tenants can then
            # both own e.g. a "param" stream without colliding
            name = tenant.qualify(name)
        self.name = name
        if pool is None:
            pool = HeteroMemory(
                device_capacity_bytes=device_capacity_bytes,
                host_capacity_bytes=host_capacity_bytes,
                policy=policy if policy is not None else "opt",
            )
        elif (device_capacity_bytes is not None or host_capacity_bytes is not None
              or policy is not None):
            raise ValueError(
                "capacity and eviction policy are owned by the shared pool; "
                "do not pass device/host_capacity_bytes or policy together "
                "with pool="
            )
        self.pool = pool
        pool.register_stream(self, tenant)
        self.stats = TransferStats()  # this stream's share of pool.stats

        self._records = [
            _ChunkRecord(chunk_id=c, payload=None, location=None)
            for c in range(cmap.num_chunks)
        ]
        self._tensor_state: dict[str, TensorState] = {
            p.name: TensorState.FREE for p in cmap.placements
        }
        # incremental per-chunk state tallies -> O(1) chunk_state
        self._chunk_compute: Counter[int] = Counter()
        self._chunk_hold: Counter[int] = Counter()
        self._chunk_released: Counter[int] = Counter()
        # incremental per-stream tier usage (pool keeps the global sums)
        self._device_used = 0
        self._host_used = 0
        self._slow_used = 0
        self._peak_device_used = 0  # this stream's device high-water mark

    # ------------------------------------------------- pool-compat properties
    @property
    def device_capacity(self) -> int | None:
        return self.pool.device_capacity

    @property
    def host_capacity(self) -> int | None:
        return self.pool.host_capacity

    @property
    def slow_capacity(self) -> int | None:
        return self.pool.slow_capacity

    @property
    def policy(self) -> EvictionPolicy:
        return self.pool.policy

    # ------------------------------------------------------------ accounting
    def device_bytes_used(self) -> int:
        return self._device_used

    def host_bytes_used(self) -> int:
        return self._host_used

    def slow_bytes_used(self) -> int:
        return self._slow_used

    def peak_device_bytes(self) -> int:
        """This stream's lifetime device high-water mark (the pool keeps
        the cross-stream mark) — e.g. the activation plane's real device
        footprint for honest margin accounting."""
        return self._peak_device_used

    def location(self, chunk_id: int) -> Device | None:
        return self._records[chunk_id].location

    def tensor_state(self, name: str) -> TensorState:
        return self._tensor_state[name]

    def chunk_state(self, chunk_id: int) -> ChunkState:
        if self._chunk_compute[chunk_id] > 0:
            return ChunkState.COMPUTE
        if self._chunk_hold[chunk_id] > 0:
            return ChunkState.HOLD
        if self._chunk_released[chunk_id] > 0:
            return ChunkState.RELEASED
        return ChunkState.FREE

    def _set_state(self, name: str, new: TensorState) -> None:
        """Single mutation point keeping the per-chunk tallies in sync."""
        old = self._tensor_state[name]
        if old is new:
            return
        chunk_id = self.cmap.placement(name).chunk_id
        if old is TensorState.COMPUTE:
            self._chunk_compute[chunk_id] -= 1
        elif old is TensorState.RELEASED:
            self._chunk_released[chunk_id] -= 1
        elif old is not TensorState.FREE:
            self._chunk_hold[chunk_id] -= 1
        if new is TensorState.COMPUTE:
            self._chunk_compute[chunk_id] += 1
        elif new is TensorState.RELEASED:
            self._chunk_released[chunk_id] += 1
        elif new is not TensorState.FREE:
            self._chunk_hold[chunk_id] += 1
        self._tensor_state[name] = new
        tel = self.pool.telemetry
        if tel is not None:
            tl = self.pool.timeline
            tel.state(name, old=old.name, new=new.name, stream=self.name,
                      tenant=self.tenant.name, chunk_id=chunk_id,
                      ts=tl.now if tl is not None else None,
                      moment=self.tenant.current_moment,
                      rank=self.pool.telemetry_rank)

    # -------------------------------------------------------------- schedule
    def register_moments(self, moments: dict[int, list[int]]) -> None:
        """Install this stream's warm-up reference schedule (OPT eviction)."""
        self.pool.register_moments(self.name, moments)

    def set_moment(self, moment: int) -> None:
        self.tenant.set_moment(moment)

    def set_chunkable_memory_fn(self, fn: Callable[[], int | None],
                                basis_bytes: int | None = None) -> None:
        """Tracer hook: returns the device bytes currently usable for chunks."""
        self.pool.set_chunkable_memory_fn(fn, tenant=self.tenant,
                                          basis_bytes=basis_bytes)

    # ------------------------------------------------------------- tensor API
    def access_tensor(self, name: str, comp_dev: Device = "device") -> np.ndarray:
        """Algorithm 1 (single-process part): bring the tensor's chunk to
        ``comp_dev``, mark the tensor COMPUTE, return a view of its payload."""
        p = self.cmap.placement(name)
        old = self._tensor_state[name]
        if old is TensorState.RELEASED:
            # zero-filling a remote parameter would corrupt the model; the
            # engine must run the group's all-gather (Algorithm 1 line 12)
            # before any of its tensors enters COMPUTE.
            raise RuntimeError(
                f"tensor {name}: chunk {p.chunk_id} is RELEASED (owned by "
                f"rank {self.cmap.chunk_owner(p.chunk_id)}); fetch the "
                f"communication group by all-gather before accessing it"
            )
        rec = self.pool.ensure_on(self, p.chunk_id, comp_dev)
        check_transition(old, TensorState.COMPUTE)
        self._set_state(name, TensorState.COMPUTE)
        view = rec.payload[p.offset : p.offset + p.numel]
        if old is TensorState.FREE:
            view[...] = 0  # Algorithm 1 line 31
        return view.reshape(p.shape)

    def release_tensor(self, name: str, target_state: TensorState) -> None:
        """Algorithm 2 (single-process part)."""
        old = self._tensor_state[name]
        check_transition(old, target_state)
        self._set_state(name, target_state)
        if target_state is TensorState.FREE:
            self._maybe_release_chunk(self.cmap.placement(name).chunk_id)

    def force_tensor_state(self, name: str, target_state: TensorState) -> None:
        """Unchecked state overwrite (grad->param payload swap in ADAM)."""
        self._set_state(name, target_state)

    def reset_states(self, target: TensorState = TensorState.HOLD) -> None:
        """Reset all resident tensors (e.g. to HOLD before BWD, Section
        6.2).  FREE and RELEASED tensors hold no local payload and keep
        their state — a remote chunk stays released until its group is
        re-fetched."""
        for name, s in self._tensor_state.items():
            if not s.is_payload_free:
                check_transition(s, target)
                self._set_state(name, target)

    def tensor_view(self, name: str) -> np.ndarray:
        """Read-only style access without a state change (debug/checkpoint)."""
        p = self.cmap.placement(name)
        rec = self._records[p.chunk_id]
        if rec.payload is None:
            raise KeyError(f"tensor {name}: chunk {p.chunk_id} has no payload")
        return rec.payload[p.offset : p.offset + p.numel].reshape(p.shape)

    # -------------------------------------------- dynamic streams (serving)
    def add_tensor(self, name: str, shape: tuple[int, ...],
                   chunk_id: int | None = None):
        """Map a new tensor into a dynamically-populated stream (KV): the
        map assigns (or recycles) a chunk, the record table grows to
        cover it, and the tensor starts FREE — its first access
        zero-fills (Algorithm 1 line 31), which is exactly a fresh
        decode cache.  An explicit ``chunk_id`` pins the tensor to that
        id (stable slot->chunk binding for the compiled serving plane)."""
        from repro.core.chunk import TensorSpec

        p = self.cmap.add_tensor(TensorSpec(name, tuple(shape)), chunk_id)
        while len(self._records) < self.cmap.num_chunks:
            self._records.append(_ChunkRecord(
                chunk_id=len(self._records), payload=None, location=None))
        self._tensor_state[name] = TensorState.FREE
        return p

    def remove_tensor(self, name: str) -> None:
        """Unmap a dynamic tensor (request completed): payload released,
        bytes uncharged, chunk id recycled for the next admission."""
        chunk_id = self.cmap.placement(name).chunk_id
        self._set_state(name, TensorState.FREE)
        del self._tensor_state[name]
        self.pool.release_payload(self, chunk_id)
        self.cmap.remove_tensor(name)

    # -------------------------------------------------------------- chunk API
    def pin(self, chunk_id: int) -> None:
        self._records[chunk_id].pinned += 1

    def unpin(self, chunk_id: int) -> None:
        rec = self._records[chunk_id]
        if rec.pinned <= 0:
            raise RuntimeError(f"chunk {chunk_id} is not pinned")
        rec.pinned -= 1

    def prepare_payload(self, chunk_id: int, comp_dev: Device = "device") -> np.ndarray:
        """Materialize (if FREE) and move a chunk to ``comp_dev``."""
        return self.pool.ensure_on(self, chunk_id, comp_dev).payload

    def ensure_on(self, chunk_id: int, dev: Device) -> np.ndarray:
        return self.pool.ensure_on(self, chunk_id, dev).payload

    def free_chunk(self, chunk_id: int) -> None:
        """Drop a chunk's payload, forcing all its tensors to FREE."""
        for p in self.cmap.chunk_tensors(chunk_id):
            self._set_state(p.name, TensorState.FREE)
        self.pool.release_payload(self, chunk_id)

    # ------------------------------------------- remote chunks (Section 7)
    def mark_released(self, chunk_id: int) -> None:
        """Enter the remote lifecycle: drop the local replica's payload and
        put every tensor of the chunk in RELEASED (Algorithm 1 line 18 /
        Algorithm 2 line 14 — after the group's post-FWD/BWD transition,
        and at init for chunks this rank does not own)."""
        for p in self.cmap.chunk_tensors(chunk_id):
            check_transition(self._tensor_state[p.name], TensorState.RELEASED)
            self._set_state(p.name, TensorState.RELEASED)
        self.pool.release_payload(self, chunk_id)

    def materialize_chunk(self, chunk_id: int, comp_dev: Device = "device",
                          pin: bool = False) -> np.ndarray:
        """All-gather landing pad: allocate the chunk's payload on
        ``comp_dev`` (evicting through the pool like any admission — the
        pool books no H2D, materialization moves no tier bytes) and move
        its tensors RELEASED -> HOLD.  The caller copies the owner's bytes
        in and accounts the collective.  ``pin`` holds the chunk resident
        while the collective is in flight (Algorithm 1 line 12)."""
        rec = self.pool.ensure_on(self, chunk_id, comp_dev)
        if pin:
            self.pin(chunk_id)
        for p in self.cmap.chunk_tensors(chunk_id):
            if self._tensor_state[p.name] is TensorState.RELEASED:
                self._set_state(p.name, TensorState.HOLD)
        return rec.payload

    def comm_group_state_complete(self, group: int, state: TensorState) -> bool:
        """Algorithm 2's group-complete query: True iff every tensor of
        every chunk in communication group ``group`` is in ``state``
        (padding chunks vacuously complete, empty groups are not)."""
        tensors = self.cmap.comm_group_tensors(group)
        if not tensors:
            return False
        return all(self._tensor_state[p.name] is state for p in tensors)

    # --------------------------------------------------------------- internals
    def _maybe_release_chunk(self, chunk_id: int) -> None:
        if self.chunk_state(chunk_id) is ChunkState.FREE:
            self.pool.release_payload(self, chunk_id)
