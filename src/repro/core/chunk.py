"""Chunk / tensor->chunk mapping schema (PatrickStar Section 6.1).

Model-data tensors are packed append-style into fixed-size chunks, in the
order the model defines them (the N-ary storage model): the first tensor
starts at offset 0 of chunk 0; each following tensor is placed right after
the previous one; a tensor that does not fit in the remaining space of the
current chunk opens a new chunk (tensors never straddle chunks).

The same layout is shared by the four model-data streams (param fp16,
param fp32, momentum, variance), so the offset of a parameter's OS tensors
equals the offset of its fp16 tensor — "the offsets in the chunk list of
param fp16, param fp32, momentum, and variance tensors of the same
parameter are consistent", which keeps ADAM fully local under ZeRO.

Grad fp16 has *no* chunk list: gradients reuse the param-fp16 chunk space
(Section 6.2).

For the data-parallel runtime, the chunk count is padded up to a multiple
of ``nproc`` so chunks divide evenly into communication groups of
``nproc`` chunks (Section 7).  ``group_boundaries`` optionally force the
packer to close the current group before specific tensors, so that a
scanned layer stack starts on a communication-group boundary (this is the
TPU adaptation that makes per-layer all-gather inside ``lax.scan``
possible).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A logical model-data tensor to be packed into chunks."""

    name: str
    shape: tuple[int, ...]

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass(frozen=True)
class TensorPlacement:
    """Where a tensor lives inside the chunk list."""

    name: str
    shape: tuple[int, ...]
    chunk_id: int
    offset: int  # element offset inside the chunk

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


class ChunkMapError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class ChunkTensorMap:
    """The chunk<->tensor mapping schema produced by the preprocessing stage."""

    chunk_size: int  # elements per chunk
    placements: tuple[TensorPlacement, ...]
    num_chunks: int  # padded to a multiple of nproc
    num_payload_chunks: int  # chunks actually containing tensors
    nproc: int

    # ---------------------------------------------------------------- lookup
    def placement(self, name: str) -> TensorPlacement:
        return self._by_name()[name]

    def _by_name(self) -> dict[str, TensorPlacement]:
        if not hasattr(self, "_by_name_cache"):
            object.__setattr__(
                self, "_by_name_cache", {p.name: p for p in self.placements}
            )
        return self._by_name_cache  # type: ignore[attr-defined]

    def chunk_tensors(self, chunk_id: int) -> list[TensorPlacement]:
        return list(self._by_chunk().get(chunk_id, ()))

    def _by_chunk(self) -> dict[int, tuple[TensorPlacement, ...]]:
        """chunk_id -> placements index, built once (chunk_tensors is called
        per eviction candidate; a linear scan there made eviction O(n^2))."""
        if not hasattr(self, "_by_chunk_cache"):
            idx: dict[int, list[TensorPlacement]] = {}
            for p in self.placements:
                idx.setdefault(p.chunk_id, []).append(p)
            object.__setattr__(
                self,
                "_by_chunk_cache",
                {c: tuple(ps) for c, ps in idx.items()},
            )
        return self._by_chunk_cache  # type: ignore[attr-defined]

    # ------------------------------------------------------------ statistics
    @property
    def total_numel(self) -> int:
        return sum(p.numel for p in self.placements)

    @property
    def capacity(self) -> int:
        return self.num_chunks * self.chunk_size

    @property
    def utilization(self) -> float:
        """Fraction of chunk capacity that holds real tensor data."""
        return self.total_numel / self.capacity if self.capacity else 1.0

    @property
    def fragmentation(self) -> float:
        return 1.0 - self.utilization

    @property
    def payload_elems(self) -> int:
        """Elements of real tensor data (M in the paper's volume model);
        same meaning as :attr:`repro.core.zero.ChunkLayout.payload_elems`,
        so the analytic ``comm_volume_bytes`` accepts either layout."""
        return self.total_numel

    @property
    def num_comm_groups(self) -> int:
        return self.num_chunks // self.nproc

    def comm_group(self, chunk_id: int) -> int:
        return chunk_id // self.nproc

    def comm_group_chunk_ids(self, group: int) -> range:
        return range(group * self.nproc, (group + 1) * self.nproc)

    def owner_rank(self, chunk_id: int) -> int:
        """Process that owns this chunk under the ZeRO split (Section 7):
        rank r owns chunk ``g*p + r`` of every communication group g."""
        return chunk_id % self.nproc

    def chunk_owner(self, chunk_id: int) -> int:
        """Alias of :meth:`owner_rank` (the distributed runtime's name)."""
        return self.owner_rank(chunk_id)

    def local_chunk_ids(self, rank: int) -> list[int]:
        return [c for c in range(self.num_chunks) if c % self.nproc == rank]

    def comm_group_tensors(self, group: int) -> list[TensorPlacement]:
        """All tensor placements of a communication group's chunks (padding
        chunks contribute nothing) — the unit Algorithm 2's post-FWD/BWD
        group-complete check and the all-gather fetch operate on."""
        out: list[TensorPlacement] = []
        for c in self.comm_group_chunk_ids(group):
            out.extend(self._by_chunk().get(c, ()))
        return out

    def tensor_comm_group(self, name: str) -> int:
        return self.comm_group(self.placement(name).chunk_id)


def build_chunk_map(
    tensors: Sequence[TensorSpec],
    chunk_size: int,
    *,
    nproc: int = 1,
    group_boundaries: Iterable[str] = (),
) -> ChunkTensorMap:
    """Pack ``tensors`` (in order) into chunks of ``chunk_size`` elements.

    ``group_boundaries``: names of tensors before which the packer pads to
    the next *communication-group* boundary (a multiple of ``nproc``
    chunks).  Tensors larger than ``chunk_size`` are rejected — the paper's
    schema never splits a tensor across chunks (the chunk-size search is
    responsible for picking a feasible size).
    """
    if chunk_size <= 0:
        raise ChunkMapError(f"chunk_size must be positive, got {chunk_size}")
    boundaries = set(group_boundaries)
    placements: list[TensorPlacement] = []
    chunk_id = 0
    offset = 0
    started = False
    for t in tensors:
        if t.numel > chunk_size:
            raise ChunkMapError(
                f"tensor {t.name} ({t.numel} elems) exceeds chunk size {chunk_size}"
            )
        if t.name in boundaries and started and not (offset == 0 and chunk_id % nproc == 0):
            # close the current communication group
            chunk_id = ((chunk_id + (1 if offset > 0 else 0) + nproc - 1) // nproc) * nproc
            offset = 0
        if offset + t.numel > chunk_size:
            chunk_id += 1
            offset = 0
        placements.append(
            TensorPlacement(name=t.name, shape=t.shape, chunk_id=chunk_id, offset=offset)
        )
        offset += t.numel
        started = True
    num_payload = chunk_id + (1 if offset > 0 else 0)
    num_payload = max(num_payload, 1)
    num_chunks = ((num_payload + nproc - 1) // nproc) * nproc
    return ChunkTensorMap(
        chunk_size=chunk_size,
        placements=tuple(placements),
        num_chunks=num_chunks,
        num_payload_chunks=num_payload,
        nproc=nproc,
    )


def build_act_chunk_map(
    names: Sequence[str], numel: int, *, align: int = 256
) -> ChunkTensorMap:
    """Chunk map for the activation stream: one checkpointed layer input
    per chunk.

    Activations differ from model data in two ways that shape the layout:
    they are all the same size (every layer's saved input is the embed
    output's shape), and they are rank-local (never all-gathered or
    reduce-scattered), so the map is built with ``nproc=1`` — act chunks
    have no communication groups.  The chunk size is the activation numel
    rounded up to ``align``, so exactly one activation occupies each
    chunk and FWD-write / BWD-read / free maps 1:1 onto chunk
    materialize / access / release.
    """
    size = int(math.ceil(max(numel, 1) / align) * align)
    specs = [TensorSpec(n, (numel,)) for n in names]
    return build_chunk_map(specs, size, nproc=1)


def pages_for(positions: int, page_tokens: int | None) -> int:
    """KV pages covering ``positions`` decode positions: ``ceil(positions
    / page_tokens)``, at least one.  An unpaged stream (``page_tokens is
    None``) is one page spanning the whole horizon."""
    if page_tokens is None:
        return 1
    return max(1, -(-int(positions) // int(page_tokens)))


class DynamicChunkMap:
    """Mutable chunk<->tensor map for *dynamically populated* streams.

    The four model-data streams and the activation stream have layouts
    fixed for a whole iteration (the act stream is rebuilt wholesale on a
    batch-shape change).  The serving plane's KV stream is different: a
    sequence's KV chunks are allocated when its request is **admitted**
    and freed when it **completes**, while other sequences' chunks live
    on — the map must grow and shrink tensor-by-tensor mid-flight.

    Layout: one tensor per chunk (every KV tensor is chunk-sized by
    construction, exactly like the act stream's one-activation-per-chunk
    rule), chunk ids of removed tensors are recycled through a free list
    so the id space — and with it the manager's record table — stays
    bounded by the peak concurrent tensor count.  ``nproc`` is fixed at 1:
    KV state is rank-local, it is never all-gathered or reduce-scattered,
    so there are no communication groups.

    **Paging** (``page_tokens=``): with a page size the stream's unit
    becomes a per-position-block page — a sequence at position ``p``
    holds :func:`pages_for` ``(p)`` chunks per (group, layer) instead of
    one whole-horizon chunk, and :meth:`pages_for` is the map-level page
    math admission reasons with.  The map itself stays one-tensor-per-
    chunk; pages are simply more (smaller) tensors.

    **Reserved ids** (:meth:`reserve_ids`): the compiled serving plane
    pins padded batch slot ``s`` to a fixed chunk-id *range*.  Reserving
    the range takes those ids out of default allocation and out of
    free-list recycling permanently: a removed tensor on a reserved id
    does NOT return to the free list, so background default allocation
    can never collide with a live slot's pinned page range — reserved
    ids are only ever (re)used by an explicit ``chunk_id=`` bind.

    Invariant: every id below the high-water mark is exactly one of
    occupied / free / reserved; reserved ids may also sit above it.

    The query surface mirrors :class:`ChunkTensorMap` (``placement`` /
    ``chunk_tensors`` / ``num_chunks`` / ``chunk_size`` ...), so
    :class:`~repro.core.manager.ChunkManager` and the pool consume either
    interchangeably.
    """

    nproc = 1

    def __init__(self, chunk_size: int, *,
                 page_tokens: int | None = None) -> None:
        if chunk_size <= 0:
            raise ChunkMapError(f"chunk_size must be positive, got {chunk_size}")
        if page_tokens is not None and page_tokens < 1:
            raise ChunkMapError(
                f"page_tokens must be >= 1, got {page_tokens}")
        self.chunk_size = chunk_size
        self.page_tokens = page_tokens
        self._by_name: dict[str, TensorPlacement] = {}
        self._by_chunk: dict[int, TensorPlacement] = {}
        self._free: list[int] = []
        self._reserved: set[int] = set()
        self._next_chunk = 0

    # ----------------------------------------------------------------- pages
    def pages_for(self, positions: int) -> int:
        """Pages a sequence holding ``positions`` cache positions needs
        per (group, layer) under this map's page size."""
        return pages_for(positions, self.page_tokens)

    # ------------------------------------------------------------- reserve
    def reserve_ids(self, ids: Iterable[int]) -> None:
        """Withdraw ``ids`` from default allocation and from free-list
        recycling (idempotent).  A reserved id is bound only through an
        explicit ``add_tensor(..., chunk_id=)``, and removing such a
        tensor keeps the id reserved — the compiled plane's slot page
        ranges stay collision-free however many sequences churn."""
        for i in ids:
            if i < 0:
                raise ChunkMapError(f"chunk_id must be >= 0, got {i}")
            if i in self._reserved:
                continue
            if i in self._by_chunk:
                raise ChunkMapError(
                    f"chunk {i} holds {self._by_chunk[i].name}; a live "
                    f"chunk cannot be reserved")
            if i < self._next_chunk:
                self._free.remove(i)
            self._reserved.add(i)

    # ---------------------------------------------------------------- mutate
    def add_tensor(self, spec: TensorSpec,
                   chunk_id: int | None = None) -> TensorPlacement:
        """Map a tensor into a chunk of its own.

        With ``chunk_id=None`` the id is recycled LIFO from the free list
        (or the id space grows).  An explicit ``chunk_id`` pins the tensor
        to that id — the compiled serving plane binds padded batch slot
        ``s`` to a fixed id range so a slot's chunks are *stable across
        admissions*: re-binding a slot to a new sequence touches the same
        chunk ids and therefore never changes any compiled-step shape.
        """
        if spec.name in self._by_name:
            raise ChunkMapError(f"tensor {spec.name} already mapped")
        if spec.numel > self.chunk_size:
            raise ChunkMapError(
                f"tensor {spec.name} ({spec.numel} elems) exceeds chunk size "
                f"{self.chunk_size}")
        if chunk_id is not None:
            if chunk_id < 0:
                raise ChunkMapError(f"chunk_id must be >= 0, got {chunk_id}")
            if chunk_id in self._by_chunk:
                raise ChunkMapError(
                    f"chunk {chunk_id} already holds "
                    f"{self._by_chunk[chunk_id].name}")
            if chunk_id >= self._next_chunk:
                # ids between the old high-water mark and the requested id
                # become free (the record table must stay dense) — except
                # reserved ones, which stay bindable-by-pin only
                self._free.extend(i for i in range(self._next_chunk, chunk_id)
                                  if i not in self._reserved)
                self._next_chunk = chunk_id + 1
            elif chunk_id not in self._reserved:
                self._free.remove(chunk_id)
        else:
            if self._free:
                chunk_id = self._free.pop()
            else:
                chunk_id = self._next_chunk
                while chunk_id in self._reserved:
                    chunk_id += 1
                self._next_chunk = chunk_id + 1
        p = TensorPlacement(name=spec.name, shape=spec.shape,
                            chunk_id=chunk_id, offset=0)
        self._by_name[spec.name] = p
        self._by_chunk[chunk_id] = p
        return p

    def remove_tensor(self, name: str) -> int:
        """Unmap a tensor; its chunk id goes back to the free list —
        unless it is reserved, in which case it stays out of default
        allocation and waits for the next explicit pin."""
        p = self._by_name.pop(name)
        del self._by_chunk[p.chunk_id]
        if p.chunk_id not in self._reserved:
            self._free.append(p.chunk_id)
        return p.chunk_id

    # ---------------------------------------------------------------- lookup
    def placement(self, name: str) -> TensorPlacement:
        return self._by_name[name]

    def chunk_tensors(self, chunk_id: int) -> list[TensorPlacement]:
        p = self._by_chunk.get(chunk_id)
        return [p] if p is not None else []

    @property
    def placements(self) -> tuple[TensorPlacement, ...]:
        return tuple(self._by_name.values())

    @property
    def num_chunks(self) -> int:
        """High-water chunk-id bound (recycled ids included): the record
        table a manager must be able to index."""
        return self._next_chunk

    @property
    def num_payload_chunks(self) -> int:
        return len(self._by_chunk)

    @property
    def total_numel(self) -> int:
        return sum(p.numel for p in self._by_name.values())

    @property
    def capacity(self) -> int:
        return self.num_payload_chunks * self.chunk_size

    def chunk_owner(self, chunk_id: int) -> int:
        return 0  # rank-local stream: this process owns everything

    def comm_group(self, chunk_id: int) -> int:
        raise ChunkMapError("dynamic (rank-local) streams have no comm groups")


def build_kv_chunk_map(numel: int, *, align: int = 256,
                       page_tokens: int | None = None) -> DynamicChunkMap:
    """Empty dynamic map for the serving KV stream: one (sequence, layer,
    page) cache per chunk, sized for the largest layer page rounded to
    ``align`` (the same vreg-tiling alignment as the act stream).  With
    ``page_tokens`` the unit is a position-block page instead of a whole
    decode horizon."""
    size = int(math.ceil(max(numel, 1) / align) * align)
    return DynamicChunkMap(size, page_tokens=page_tokens)


# ---------------------------------------------------------------------------
# Chunk-size search (Section 9.1, Table 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkSizeSearchResult:
    chunk_size: int
    utilization: float
    num_chunks: int
    candidates: tuple[tuple[int, float], ...]  # (size, utilization) per feasible size


def search_chunk_size(
    tensors: Sequence[TensorSpec],
    *,
    nproc: int = 1,
    group_boundaries: Iterable[str] = (),
    search_range: Sequence[int] | None = None,
    memory_budget_elems: int | None = None,
    align: int = 1,
) -> ChunkSizeSearchResult:
    """Offline search for the chunk size with minimal fragmentation.

    Mirrors the paper's lightweight pre-training search: it never allocates
    payloads, only runs the mapping schema for each candidate size and
    scores utilization.  ``memory_budget_elems`` rejects sizes whose padded
    capacity exceeds the heterogeneous memory budget (the "some chunk size
    settings do not work" effect in Fig. 12).  ``align`` forces candidate
    sizes to a hardware alignment (we use 1024 = 8*128 on TPU so chunk
    payloads tile cleanly into (8,128) vregs).

    The paper searches 128..512 in "model units" (i.e. scaled by hidden
    size); callers pass an explicit element range instead.
    """
    largest = max((t.numel for t in tensors), default=1)
    if search_range is None:
        lo = max(largest, 1)
        search_range = [lo + k * max(lo // 8, align) for k in range(0, 13)]
    candidates: list[tuple[int, float]] = []
    best: tuple[float, int, ChunkTensorMap] | None = None
    for raw in search_range:
        size = int(math.ceil(raw / align) * align)
        if size < largest:
            continue
        try:
            cmap = build_chunk_map(
                tensors, size, nproc=nproc, group_boundaries=group_boundaries
            )
        except ChunkMapError:
            continue
        if memory_budget_elems is not None and cmap.capacity > memory_budget_elems:
            continue  # infeasible on this budget
        candidates.append((size, cmap.utilization))
        key = (cmap.utilization, -size)
        if best is None or key > (best[0], -best[1]):
            best = (cmap.utilization, size, cmap)
    if best is None:
        raise ChunkMapError(
            "no feasible chunk size in search range "
            f"(largest tensor {largest} elems, budget {memory_budget_elems})"
        )
    util, size, cmap = best
    return ChunkSizeSearchResult(
        chunk_size=size,
        utilization=util,
        num_chunks=cmap.num_chunks,
        candidates=tuple(candidates),
    )
