"""Device-aware operator/chunk placement (PatrickStar Section 8.2).

Two decisions are made from the warm-up statistics:

1. **OS chunks in GPU margin space.**  After forward/backward, the device
   keeps ``margin = total - peak_nonmodel - param_fp16_working_set`` bytes
   free.  As many optimizer-state chunk *groups* as fit are pinned to the
   device so that their ADAM update runs there without any host traffic;
   the rest stay on the host and ADAM for them runs host-side (the
   ZeRO-Offload default for *all* OS).  A group is a (param fp32,
   momentum, variance) triple sharing one layout slot, so one group costs
   ``3 * chunk_bytes_fp32`` (+ the transient fp32 grad conversion buffer).

2. **Embedding on host.**  Embedding parameters are O(V*H) but their
   activations are O(B*H); when V is large the parameters should never
   move.  ``embedding_on_host`` returns True when the embedding's chunk
   traffic would exceed its activation traffic.

The same policy object drives both runtimes: the eager engine pins chunks
accordingly, and the compiled path splits the OS chunk store into a
device-resident and a host-resident (``pinned_host`` memory kind) part at
lowering time.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    # number of OS chunk groups resident on device (out of num_local_groups)
    os_device_groups: int
    num_local_groups: int
    margin_bytes: int
    embedding_on_host: bool
    # >0: margin chunks; <0: param-fp16 chunks spilled to host (Table 4)
    margin_or_spill_groups: int
    # device bytes reserved for the activation stream's working set (the
    # act chunks that must co-reside with compute during FWD/BWD); margin
    # OS groups only claim what is left after this reservation
    act_reserved_bytes: int = 0
    # host-resident OS groups whose steady-state home is the slow
    # (NVMe-class) tier: they exceed the host budget left after the param
    # fp16 spill, so between their ADAM visits they rest one tier further
    # down instead of making the config inadmissible.  0 on two-tier plans.
    os_slow_groups: int = 0

    @property
    def os_device_fraction(self) -> float:
        if self.num_local_groups == 0:
            return 0.0
        return self.os_device_groups / self.num_local_groups

    def os_device_chunk_ids(self, cmap) -> set[int]:
        """Chunk ids of the OS groups placed in GPU margin space.  Their
        ADAM updates run device-side after warm-up, so the warm-up's
        host-side reference moments for these chunks must be promoted to
        device references in the OPT/prefetch schedules."""
        return {
            c
            for g_idx in range(self.os_device_groups)
            for c in cmap.comm_group_chunk_ids(g_idx)
        }

    def os_slow_chunk_ids(self, cmap) -> set[int]:
        """Chunk ids of the OS groups whose steady-state home is the slow
        tier (the last ``os_slow_groups`` groups: the margin-placed ones
        come first, host-placed next, overflow last)."""
        return {
            c
            for g_idx in range(self.num_local_groups - self.os_slow_groups,
                               self.num_local_groups)
            for c in cmap.comm_group_chunk_ids(g_idx)
        }


def plan_placement(
    *,
    margin_bytes: int,
    num_local_groups: int,
    chunk_size_elems: int,
    param_fp16_local_bytes: int,
    device_total_bytes: int,
    peak_nonmodel_bytes: int,
    vocab_size: int = 0,
    hidden: int = 0,
    batch_tokens: int = 0,
    act_working_bytes: int = 0,
    host_capacity_bytes: int | None = None,
    slow_capacity_bytes: int | None = None,
) -> PlacementPlan:
    """Derive the placement plan from warm-up statistics.

    ``margin_bytes`` should come from ``RuntimeMemoryTracer.margin_space``.
    ``act_working_bytes`` is the activation stream's device working set
    (chunk-managed checkpointed inputs pinned alongside compute); it is
    carved out of the margin BEFORE optimizer-state groups claim it, so a
    margin-placed OS group can never force the act chunks an operator is
    reading/writing off the device.

    With a bounded host (``host_capacity_bytes``) and a slow tier present
    (``slow_capacity_bytes``), host-placed OS groups that do not fit the
    host budget left after the param-fp16 spill overflow to the slow tier
    (``os_slow_groups``) instead of making the configuration
    inadmissible — the ZeRO-Infinity direction.  Without a slow tier the
    plan is unchanged: overflow remains the pool's OutOfMemory to raise.

    On a shared multi-tenant pool the caller passes its *tenant's* tier
    shares (``PoolLease.host_bytes`` / ``slow_bytes`` — soft budgets,
    falling back to the pool caps), not the raw pool capacities: each
    tenant plans inside its own share and the pool's common overflow
    region absorbs transients at eviction-priority cost.
    """
    # one OS group = param fp32 + momentum + variance, all fp32
    group_bytes = 3 * chunk_size_elems * 4
    os_margin_bytes = max(margin_bytes - act_working_bytes, 0)
    os_device_groups = 0
    if group_bytes > 0:
        os_device_groups = max(
            0, min(num_local_groups, os_margin_bytes // group_bytes))

    # Table 4 diagnostic: positive margin groups, or negative spilled
    # param-fp16 groups when even the fp16 working set does not fit.
    fp16_budget = device_total_bytes - peak_nonmodel_bytes
    if param_fp16_local_bytes > fp16_budget > 0:
        spill_bytes = param_fp16_local_bytes - fp16_budget
        spill_groups = -(-spill_bytes // max(2 * chunk_size_elems, 1))  # ceil
        margin_or_spill = -int(spill_groups)
    else:
        margin_or_spill = int(os_device_groups)

    # Embedding placement: moving O(V*H) params vs O(B*H) activations.
    emb_on_host = bool(vocab_size and batch_tokens and vocab_size > batch_tokens)

    # Third-tier overflow: host-placed OS groups beyond what the host
    # budget can hold (after the fp16 spill it must absorb) rest on the
    # slow tier between ADAM visits.
    os_slow_groups = 0
    if slow_capacity_bytes is not None and host_capacity_bytes is not None:
        host_groups = num_local_groups - int(os_device_groups)
        spill_fp16 = max(param_fp16_local_bytes - max(fp16_budget, 0), 0)
        host_os_budget = max(host_capacity_bytes - spill_fp16, 0)
        fit = host_os_budget // group_bytes if group_bytes > 0 else host_groups
        os_slow_groups = int(max(0, host_groups - fit))

    return PlacementPlan(
        os_device_groups=int(os_device_groups),
        num_local_groups=num_local_groups,
        margin_bytes=int(margin_bytes),
        embedding_on_host=emb_on_host,
        margin_or_spill_groups=margin_or_spill,
        act_reserved_bytes=int(act_working_bytes),
        os_slow_groups=os_slow_groups,
    )
