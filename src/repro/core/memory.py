"""Unified heterogeneous memory space (PatrickStar Sections 6.2, 8).

The paper's central design point is that **all** model-data chunks — param
fp16, param fp32, momentum and variance — live in ONE CPU+GPU
heterogeneous memory space with a single device budget, orchestrated by
the warm-up statistics.  :class:`HeteroMemory` is that space: it owns the
device/host byte budgets, incremental usage counters, the unified
:class:`TransferStats`, and the eviction policies (opt/lru/fifo), while
:class:`~repro.core.manager.ChunkManager` is a per-stream *view* that
registers its chunks with the pool.  Eviction therefore sees cross-stream
pressure: admitting a param chunk may push an optimizer-state chunk to the
host, exactly as in the paper's single space — the seed's
one-full-budget-per-stream managers could jointly oversubscribe the
device 4x and never competed with each other.

On top of the pool sits :class:`SchedulePrefetcher`, the schedule-driven
half of the design (the overlap technique of ZeRO-Infinity / AutoHete):
after the warm-up iteration the tracer's moment schedule is a total order
of future chunk references, so at every moment the next-k references can
be *staged* onto the device ahead of the operator that needs them.  The
container has no real async copy engine, so staging is simulated-async:
every H2D transfer is classified as **hidden** (issued by the prefetcher
ahead of demand, i.e. overlappable with compute) or **critical-path**
(a demand miss the operator must wait for).  Staging runs only on OPT
pools (it consumes the same future-reference schedule) and is
conservative: into free space, or by replaying the exact eviction Belady
would perform at the avoided miss (a victim not needed before the staged
chunk's use and farthest as seen from that moment among ALL residents);
when no such victim exists it refuses to stage.  On the engine's
scan-shaped traces this conserves total transfer volume exactly
(asserted in benchmarks/eviction.py), converting critical-path bytes
into hidden bytes instead of adding traffic; on arbitrary interleavings
residency can still shift between stage and use, and the prefetcher's
in-flight cap bounds the excess.

Eviction (Section 8.3): when the device tier cannot host an incoming
chunk, evict a HOLD-like, unpinned chunk of *any* stream.  Policies:

  "opt"   Belady's OPT using the *future* reference moments collected by
          the runtime memory tracer in the warm-up iteration — evict the
          chunk whose next use is farthest in the future (the paper's
          choice).  Schedules are per-stream: an OS chunk is only
          referenced again at its ADAM moment, a param chunk at its next
          FWD/BWD/ADAM use.
  "lru"   least recently used (classic; no future knowledge).
  "fifo"  first-in-first-out.

Chunks in COMPUTE state or explicitly pinned (collective communication in
flight, Algorithm 1 lines 12/18) are never evicted.

On the distributed plane (Section 7) every rank owns one of these pools;
:class:`CollectiveStats` sits alongside :class:`TransferStats` as the
rank's cross-rank ledger (all-gather fetches of remote chunks, grad
reduce-scatter, the stem all-reduce), and :class:`GatherPrefetcher` is
the collective analogue of :class:`SchedulePrefetcher` — it stages
upcoming remote-group all-gathers instead of H2D copies, with the same
hidden/critical split.
"""

from __future__ import annotations

import bisect
import dataclasses
from collections import Counter
from typing import TYPE_CHECKING, Callable, Iterable, Literal

import numpy as np

from repro.core.state import ChunkState
from repro.core.telemetry import Telemetry, default_hub
from repro.core.timeline import TransferTimeline

if TYPE_CHECKING:  # pragma: no cover - import cycle with manager.py
    from repro.core.manager import ChunkManager, _ChunkRecord

Device = Literal["device", "host", "slow"]
EvictionPolicy = Literal["opt", "lru", "fifo"]

# Tier stack, fastest first.  "slow" is the NVMe-class tier behind host
# memory (ZeRO-Infinity direction); it only exists on pools constructed
# with ``slow_capacity_bytes``.  Chunks move between ADJACENT tiers only:
# device<->host over the h2d/d2h lanes, host<->slow over h2s/s2h — a
# slow-resident chunk reaches the device via a two-hop route through host.
TIER_ORDER: tuple[Device, ...] = ("device", "host", "slow")

# DMA lane for a single-hop move between adjacent tiers.
_LINKS: dict[tuple[Device, Device], str] = {
    ("host", "device"): "h2d",
    ("device", "host"): "d2h",
    ("host", "slow"): "h2s",
    ("slow", "host"): "s2h",
}

_NEVER = 2**62  # "no known future use" sentinel for OPT


class OutOfMemory(RuntimeError):
    """Neither tier can host the chunk (the DeepSpeed failure mode, Fig. 10)."""


@dataclasses.dataclass
class TransferStats:
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    h2d_count: int = 0
    d2h_count: int = 0
    # host<->slow lanes; identically zero on two-tier pools
    h2s_bytes: int = 0
    s2h_bytes: int = 0
    h2s_count: int = 0
    s2h_count: int = 0

    @property
    def total_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes + self.h2s_bytes + self.s2h_bytes

    def reset(self) -> None:
        self.h2d_bytes = self.d2h_bytes = 0
        self.h2d_count = self.d2h_count = 0
        self.h2s_bytes = self.s2h_bytes = 0
        self.h2s_count = self.s2h_count = 0


@dataclasses.dataclass
class CollectiveStats:
    """Cross-rank communication ledger of one rank's pool (Section 7).

    Sits alongside :class:`TransferStats` (H2D/D2H is the *offload* plane,
    collectives are the *inter-process* plane): ``allgather_bytes`` counts
    bytes this rank RECEIVES fetching remote chunks ((p-1) chunks per
    communication group, padding included — exactly what a tiled
    ``lax.all_gather`` of the [G, p, S] store moves), and
    ``reduce_scatter_bytes`` counts grad bytes this rank SENDS to chunk
    owners ((p-1) non-owned chunks per group).  Both conventions make a
    rank's per-step total equal the paper's analytic 3(p-1)/p * M model
    (asserted in benchmarks/comm_volume.py).  ``allreduce_bytes`` tracks
    the stem (embedding/norm) grad all-reduce, which the paper keeps
    OUTSIDE chunk management (Section 8.2) — kept in a separate counter so
    the chunked-volume parity stays exact.  Like H2D bytes, all-gather
    bytes are split hidden (staged ahead by the gather prefetcher,
    overlappable) vs critical-path (a demand fetch the operator waits on).
    """

    allgather_bytes: int = 0
    reduce_scatter_bytes: int = 0
    allreduce_bytes: int = 0
    allgather_count: int = 0
    reduce_scatter_count: int = 0
    hidden_allgather_bytes: int = 0
    critical_allgather_bytes: int = 0

    @property
    def chunk_collective_bytes(self) -> int:
        """Chunked-plane volume (the analytic model's 3(p-1)/p * M)."""
        return self.allgather_bytes + self.reduce_scatter_bytes

    @property
    def total_bytes(self) -> int:
        return self.chunk_collective_bytes + self.allreduce_bytes

    def reset(self) -> None:
        self.allgather_bytes = self.reduce_scatter_bytes = 0
        self.allreduce_bytes = 0
        self.allgather_count = self.reduce_scatter_count = 0
        self.hidden_allgather_bytes = self.critical_allgather_bytes = 0


@dataclasses.dataclass
class PrefetchStats:
    """Overlap accounting for the simulated-async staging queue.

    Every H2D byte is either *hidden* (issued by the prefetcher before the
    consuming operator, overlappable with compute) or *critical-path* (a
    demand miss).  ``hidden + critical == TransferStats.h2d_bytes`` holds
    at all times.
    """

    hidden_h2d_bytes: int = 0
    critical_h2d_bytes: int = 0
    hits: int = 0  # device access found the chunk already staged
    demand_misses: int = 0  # device access had to move the chunk itself
    staged_transfers: int = 0  # H2D transfers issued by the prefetcher
    wasted_stages: int = 0  # staged chunks evicted before first use

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.demand_misses
        return self.hits / total if total else 0.0

    def reset(self) -> None:
        self.hidden_h2d_bytes = self.critical_h2d_bytes = 0
        self.hits = self.demand_misses = 0
        self.staged_transfers = self.wasted_stages = 0


_DEFAULT_TENANT = "default"


class Tenant:
    """One consumer of a shared :class:`HeteroMemory` pool (Angel-PTM
    direction: a single memory manager hosting many jobs).

    A tenant sits between the pool and its :class:`ChunkManager` streams:
    every stream registers under exactly one tenant, and the pool keeps a
    tenant-scoped mirror of the accounting it already keeps per stream —
    :class:`TransferStats`, :class:`PrefetchStats`, per-tier usage and the
    device high-water marks.  Two knobs give the co-tenancy semantics:

    ``priority``
        Victim selection shields higher-priority tenants: as long as such
        a tenant sits *within* its soft budget on a tier, a lower-priority
        tenant's demand can never evict its chunks there (serving's
        latency-critical kv pages outrank the trainer's cold optimizer
        states).  Same-or-higher-priority requesters see no shield.
    ``*_budget_bytes`` (per tier, all optional)
        *Soft* budgets.  They do not gate admission — the pool's tiers are
        one shared space with a common overflow region — but they anchor
        the eviction policy twice: within-budget residency of a
        higher-priority tenant is protected (above), and chunks of a
        tenant *over* its soft budget are reclaimed first, so the overflow
        region drains before anyone's in-budget residency is touched.

    Every pool starts with the ``"default"`` tenant (priority 0, no
    budgets); single-tenant pools never leave it, and with only the
    default tenant registered every rule above degenerates to the
    historical single-owner behavior bit-for-bit (same victims, same
    counters, same OOM points).

    Each tenant also owns a *moment cursor*: OPT schedules are per-stream
    and stream names are tenant-qualified (:meth:`qualify`), so one
    tenant's warm-up clock never positions another tenant's chunks in
    time — cross-tenant OPT comparisons normalize to distance-from-own-
    cursor instead of absolute moments.
    """

    def __init__(
        self,
        pool: "HeteroMemory",
        name: str,
        *,
        priority: int = 0,
        device_budget_bytes: int | None = None,
        host_budget_bytes: int | None = None,
        slow_budget_bytes: int | None = None,
    ) -> None:
        self.pool = pool
        self.name = name
        self.priority = priority
        self.device_budget_bytes = device_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        self.slow_budget_bytes = slow_budget_bytes
        self.stats = TransferStats()
        self.prefetch = PrefetchStats()
        self._device_used = 0
        self._host_used = 0
        self._slow_used = 0
        self.peak_device_bytes = 0
        self._step_peak_device_bytes = 0
        self.current_moment = 0

    @property
    def is_default(self) -> bool:
        return self.name == _DEFAULT_TENANT

    @property
    def timeline_ns(self) -> str | None:
        """Moment namespace on a shared :class:`TransferTimeline` (the
        default tenant uses the unnamed namespace — byte-compatible with
        single-tenant pools that never mention tenants)."""
        return None if self.is_default else self.name

    def qualify(self, stream: str) -> str:
        """Pool-wide stream name for this tenant's ``stream``.  Identity
        for the default tenant (historical names), ``"tenant:stream"``
        otherwise — two engines can then both own a "param" stream."""
        return stream if self.is_default else f"{self.name}:{stream}"

    # ------------------------------------------------------------ accounting
    def device_bytes_used(self) -> int:
        return self._device_used

    def host_bytes_used(self) -> int:
        return self._host_used

    def slow_bytes_used(self) -> int:
        return self._slow_used

    def bytes_used(self, dev: Device) -> int:
        if dev == "device":
            return self._device_used
        return self._host_used if dev == "host" else self._slow_used

    def soft_budget(self, dev: Device) -> int | None:
        if dev == "device":
            return self.device_budget_bytes
        return (self.host_budget_bytes if dev == "host"
                else self.slow_budget_bytes)

    def over_budget(self, dev: Device) -> bool:
        """Holding more than the soft budget on this tier (no budget
        configured -> never over: nothing staked out, nothing to drain)."""
        b = self.soft_budget(dev)
        return b is not None and self.bytes_used(dev) > b

    def protected_on(self, dev: Device) -> bool:
        """Within a *configured* soft budget on this tier: lower-priority
        tenants cannot evict this tenant's chunks there."""
        b = self.soft_budget(dev)
        return b is not None and self.bytes_used(dev) <= b

    def take_step_peak_device_bytes(self) -> int:
        """Tenant-scoped analogue of the pool method: high-water mark since
        the previous call, re-armed at current usage."""
        peak = self._step_peak_device_bytes
        self._step_peak_device_bytes = self._device_used
        return peak

    def snapshot(self) -> tuple[TransferStats, PrefetchStats]:
        """Point-in-time copies of this tenant's transfer and prefetch
        counters — the per-step delta baseline both engines take."""
        return (dataclasses.replace(self.stats),
                dataclasses.replace(self.prefetch))

    # -------------------------------------------------------------- schedule
    def set_moment(self, moment: int) -> None:
        """Advance this tenant's moment cursor (and its namespace on the
        shared timeline).  Other tenants' clocks are untouched."""
        self.current_moment = moment
        if self.pool.timeline is not None:
            self.pool.timeline.advance_to_moment(moment,
                                                 tenant=self.timeline_ns)


class HeteroMemory:
    """The shared tiered (device/host[/slow]) chunk memory space.

    Streams (:class:`ChunkManager` views) register themselves; the pool
    owns every byte-accounting and movement decision.  Usage counters are
    incremental — ``device_bytes_used`` is O(1), not a scan — and are
    mirrored per-stream on each manager.

    By default the space is the paper's two-tier device/host budget.
    Passing ``slow_capacity_bytes`` appends an NVMe-class third tier
    behind host memory (the ZeRO-Infinity direction): host evictions
    demote to the slow tier instead of bouncing back to the device, and a
    slow-resident chunk promotes on demand via a two-hop s2h + h2d route.
    ``slow_capacity_bytes=None`` keeps the pool behavior-identical to the
    two-tier space.
    """

    def __init__(
        self,
        *,
        device_capacity_bytes: int | None = None,
        host_capacity_bytes: int | None = None,
        slow_capacity_bytes: int | None = None,
        policy: EvictionPolicy = "opt",
    ) -> None:
        self.device_capacity = device_capacity_bytes
        self.host_capacity = host_capacity_bytes
        self.slow_capacity = slow_capacity_bytes
        # ordered tier stack, fastest first; the slow tier exists only
        # when given a capacity (an unbounded NVMe tier would make the
        # unbounded host tier unreachable as an eviction target).
        self.tiers: tuple[Device, ...] = (
            TIER_ORDER if slow_capacity_bytes is not None
            else TIER_ORDER[:2])
        self.policy: EvictionPolicy = policy
        self.stats = TransferStats()  # unified, all streams
        self.prefetch = PrefetchStats()
        # cross-rank communication ledger (all zeros for single-rank pools)
        self.collectives = CollectiveStats()
        self._streams: dict[str, "ChunkManager"] = {}
        self._device_used = 0
        self._host_used = 0
        self._slow_used = 0
        # prefetchers holding installed reference queues over this pool;
        # unregister_stream drops their refs so recycled DynamicChunkMap
        # ids of a later stream never collide with stale entries.
        self._prefetchers: list["SchedulePrefetcher"] = []
        self.peak_device_bytes = 0  # cumulative (lifetime) high-water mark
        self._step_peak_device_bytes = 0  # high-water mark since last take_
        # clock advances on every access; used by LRU/FIFO and as the
        # "moment" cursor for OPT when no tracer moments are registered.
        self._clock = 0
        # OPT future-reference schedules, one per stream:
        # stream -> chunk_id -> sorted list of reference moments.
        self._moments: dict[str, dict[int, list[int]]] = {}
        # tenants: every stream belongs to exactly one.  The pool starts
        # with the "default" tenant (priority 0, no soft budgets);
        # single-tenant pools never leave it and keep the historical
        # single-owner behavior bit-for-bit.
        self._default_tenant = Tenant(self, _DEFAULT_TENANT)
        self._tenants: dict[str, Tenant] = {
            _DEFAULT_TENANT: self._default_tenant}
        # cross-tenant eviction ledger: (victim_tenant, requesting_tenant)
        # -> chunks demoted.  The co-tenancy protection guarantee is
        # checkable as evictions[(hi, lo)] == 0 while ``hi`` stays within
        # its soft budgets (asserted in benchmarks/cotenancy.py).
        self.evictions: Counter[tuple[str, str]] = Counter()
        # optional callbacks letting each tenant's tracer shrink the
        # device tier by its live non-model footprint; the deduction is
        # measured against that tenant's device share.
        self._chunkable_fns: dict[
            str, tuple[Tenant, Callable[[], int | None], int | None]] = {}
        # tenants whose soft budget shielded candidates in the most recent
        # victim scan — names a multi-tenant OOM refusal in make_room.
        self._blocked_by: set[str] = set()
        # chunks brought to device by the prefetcher, awaiting their use
        self._staged: set[tuple[str, int]] = set()
        # optional transfer timeline: every tier move is enqueued on a
        # finite-bandwidth DMA engine and hidden bytes in excess of the
        # consuming operator's compute window surface as stall seconds.
        self.timeline: TransferTimeline | None = None
        # >0 while the staging path runs: evictions it cascades are
        # overlappable (issued ahead of demand), not consumer waits.
        self._staging = 0
        # telemetry hub (None == disabled, one predicate per call site).
        # An explicit set_telemetry wins; the module-level default hub —
        # installed e.g. by the benchmark runner's --trace-dir — is
        # picked up at construction so unmodified call sites emit too.
        self.telemetry: Telemetry | None = default_hub()
        self.telemetry_rank: int | None = None
        if self.telemetry is not None:
            self.telemetry.attach_pool(self)

    # --------------------------------------------------------------- tenants
    @property
    def default_tenant(self) -> Tenant:
        return self._default_tenant

    @property
    def tenants(self) -> dict[str, Tenant]:
        return dict(self._tenants)

    @property
    def _current_moment(self) -> int:
        """Historical single-tenant cursor — the default tenant's clock."""
        return self._default_tenant.current_moment

    def create_tenant(
        self,
        name: str,
        *,
        priority: int = 0,
        device_budget_bytes: int | None = None,
        host_budget_bytes: int | None = None,
        slow_budget_bytes: int | None = None,
    ) -> Tenant:
        """Add a named tenant with per-tier soft budgets and an eviction
        priority (see :class:`Tenant`).  Streams register under it via
        ``ChunkManager(..., tenant=)`` / :meth:`PoolLease.stream`."""
        if not name or ":" in name:
            raise ValueError(
                f"invalid tenant name {name!r} (non-empty, no ':')")
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already exists")
        t = Tenant(self, name, priority=priority,
                   device_budget_bytes=device_budget_bytes,
                   host_budget_bytes=host_budget_bytes,
                   slow_budget_bytes=slow_budget_bytes)
        self._tenants[name] = t
        return t

    def staged_count(self, tenant: Tenant | None = None) -> int:
        """In-flight staged chunks, pool-wide or for one tenant (the
        prefetcher in-flight caps are per tenant on shared pools — one
        tenant's staging burst must not throttle another's)."""
        if tenant is None:
            return len(self._staged)
        return sum(1 for s, _c in self._staged
                   if s in self._streams and self._streams[s].tenant is tenant)

    # --------------------------------------------------------------- streams
    def register_stream(self, mgr: "ChunkManager",
                        tenant: Tenant | None = None) -> None:
        if mgr.name in self._streams:
            raise ValueError(f"stream name {mgr.name!r} already registered")
        t = tenant or self._default_tenant
        if t.pool is not self:
            raise ValueError(
                f"tenant {t.name!r} belongs to a different pool")
        mgr.tenant = t
        self._streams[mgr.name] = mgr

    def unregister_stream(self, name: str) -> None:
        """Detach a stream and release every byte it holds (used when the
        activation stream is rebuilt for a new batch shape: act chunk
        layouts are batch-dependent, unlike the four model-data streams).
        Installed prefetcher queues drop the stream's references too — a
        later stream reusing the name (and recycled chunk ids) must never
        be staged off a stale schedule."""
        mgr = self._streams.pop(name, None)
        if mgr is None:
            raise KeyError(
                f"stream {name!r} is not registered with this pool "
                f"(known streams: {sorted(self._streams)})")
        for rec in mgr._records:
            if rec.payload is not None:
                self._uncharge(mgr, rec.location, mgr.chunk_bytes)
                rec.payload = None
                rec.location = None
            self._staged.discard((name, rec.chunk_id))
            if self.timeline is not None:
                self.timeline.cancel((name, rec.chunk_id))
        self._moments.pop(name, None)
        for pf in self._prefetchers:
            pf.drop_stream(name)

    @property
    def streams(self) -> dict[str, "ChunkManager"]:
        return dict(self._streams)

    # ------------------------------------------------------------ accounting
    def device_bytes_used(self) -> int:
        return self._device_used

    def host_bytes_used(self) -> int:
        return self._host_used

    def slow_bytes_used(self) -> int:
        return self._slow_used

    def _charge(self, mgr: "ChunkManager", dev: Device, nbytes: int) -> None:
        t = mgr.tenant
        if dev == "device":
            self._device_used += nbytes
            mgr._device_used += nbytes
            t._device_used += nbytes
            if mgr._device_used > mgr._peak_device_used:
                mgr._peak_device_used = mgr._device_used
            if t._device_used > t.peak_device_bytes:
                t.peak_device_bytes = t._device_used
            if t._device_used > t._step_peak_device_bytes:
                t._step_peak_device_bytes = t._device_used
            if self._device_used > self.peak_device_bytes:
                self.peak_device_bytes = self._device_used
            if self._device_used > self._step_peak_device_bytes:
                self._step_peak_device_bytes = self._device_used
        elif dev == "host":
            self._host_used += nbytes
            mgr._host_used += nbytes
            t._host_used += nbytes
        else:
            self._slow_used += nbytes
            mgr._slow_used += nbytes
            t._slow_used += nbytes

    def _uncharge(self, mgr: "ChunkManager", dev: Device, nbytes: int) -> None:
        t = mgr.tenant
        if dev == "device":
            self._device_used -= nbytes
            mgr._device_used -= nbytes
            t._device_used -= nbytes
        elif dev == "host":
            self._host_used -= nbytes
            mgr._host_used -= nbytes
            t._host_used -= nbytes
        else:
            self._slow_used -= nbytes
            mgr._slow_used -= nbytes
            t._slow_used -= nbytes

    def take_step_peak_device_bytes(self) -> int:
        """Device-tier high-water mark since the previous call, then re-arm
        at the *current* usage — per-step (not cumulative) peak, so
        benchmarks see per-phase pressure instead of a monotone max."""
        peak = self._step_peak_device_bytes
        self._step_peak_device_bytes = self._device_used
        return peak

    def check_invariants(self) -> None:
        """Recompute usage from the records and compare with the O(1)
        counters, and assert no tier budget is exceeded (test/debug hook;
        never needed on the hot path)."""
        dev = host = slow = 0
        by_tenant: dict[str, list[int]] = {
            name: [0, 0, 0] for name in self._tenants}
        for mgr in self._streams.values():
            mdev = mhost = mslow = 0
            for rec in mgr._records:
                if rec.payload is None:
                    continue
                if rec.location == "device":
                    mdev += mgr.chunk_bytes
                elif rec.location == "host":
                    mhost += mgr.chunk_bytes
                else:
                    mslow += mgr.chunk_bytes
            assert mdev == mgr._device_used, (mgr.name, mdev, mgr._device_used)
            assert mhost == mgr._host_used, (mgr.name, mhost, mgr._host_used)
            assert mslow == mgr._slow_used, (mgr.name, mslow, mgr._slow_used)
            acc = by_tenant[mgr.tenant.name]
            acc[0] += mdev
            acc[1] += mhost
            acc[2] += mslow
            dev += mdev
            host += mhost
            slow += mslow
        # tenant mirrors agree with their streams' sums, and the tenants'
        # sums agree with the pool totals (per-tenant counters sum to pool
        # usage — the co-tenancy accounting invariant).
        for name, t in self._tenants.items():
            tdev, thost, tslow = by_tenant[name]
            assert tdev == t._device_used, (name, tdev, t._device_used)
            assert thost == t._host_used, (name, thost, t._host_used)
            assert tslow == t._slow_used, (name, tslow, t._slow_used)
        assert dev == self._device_used, (dev, self._device_used)
        assert host == self._host_used, (host, self._host_used)
        assert slow == self._slow_used, (slow, self._slow_used)
        # bound against the STATIC capacities: host->device spills may by
        # design exceed the dynamic chunkable budget of the current moment
        # (margin-space overflow), and that budget also legally shrinks
        # between an admission and this check.
        if self.device_capacity is not None:
            assert self._device_used <= self.device_capacity, (
                self._device_used, self.device_capacity)
        if self.host_capacity is not None:
            assert self._host_used <= self.host_capacity, (
                self._host_used, self.host_capacity)
        if self.slow_capacity is not None:
            assert self._slow_used <= self.slow_capacity, (
                self._slow_used, self.slow_capacity)

    # ------------------------------------------------------------ collectives
    def account_allgather(self, nbytes: int, *, hidden: bool = False,
                          group: int | None = None) -> None:
        """Book bytes this rank received in a chunk-group all-gather.
        ``hidden`` marks a prefetcher-staged gather (overlappable), else
        the fetch is on the consuming operator's critical path.  With a
        timeline attached the gather also lands on the collective lane:
        a hidden gather's rendezvous key is ``("gather", group)`` — the
        consuming layer waits on it, so a gather issued too late for its
        overlap window surfaces as gather-stall seconds."""
        self.collectives.allgather_bytes += nbytes
        self.collectives.allgather_count += 1
        if hidden:
            self.collectives.hidden_allgather_bytes += nbytes
        else:
            self.collectives.critical_allgather_bytes += nbytes
        if self.timeline is not None:
            key = ("gather", group) if (hidden and group is not None) else None
            self.timeline.record_collective(nbytes, critical=not hidden,
                                            key=key)
        tel = self.telemetry
        if tel is not None:
            ts, dur = self._last_window()
            tel.collective("allgather", nbytes=nbytes, stream="param",
                           tenant=None, hidden=hidden, ts=ts, dur=dur,
                           moment=self._current_moment,
                           rank=self.telemetry_rank, group=group)

    def account_reduce_scatter(self, nbytes: int) -> None:
        """Book grad bytes this rank sent to chunk owners (Algorithm 2).
        On the timeline the reduce-scatter is overlappable (the paper
        overlaps it with remaining BWD compute); it still occupies the
        collective lane, so it delays any gather queued behind it."""
        self.collectives.reduce_scatter_bytes += nbytes
        self.collectives.reduce_scatter_count += 1
        if self.timeline is not None:
            self.timeline.record_collective(nbytes, critical=False)
        tel = self.telemetry
        if tel is not None:
            ts, dur = self._last_window()
            tel.collective("reduce_scatter", nbytes=nbytes, stream="param",
                           tenant=None, hidden=True, ts=ts, dur=dur,
                           moment=self._current_moment,
                           rank=self.telemetry_rank)

    def account_allreduce(self, nbytes: int) -> None:
        """Book non-chunk (stem) grad all-reduce bytes."""
        self.collectives.allreduce_bytes += nbytes
        if self.timeline is not None:
            self.timeline.record_collective(nbytes, critical=False,
                                            stream="stem")
        tel = self.telemetry
        if tel is not None:
            ts, dur = self._last_window()
            tel.collective("allreduce", nbytes=nbytes, stream="stem",
                           tenant=None, hidden=True, ts=ts, dur=dur,
                           moment=self._current_moment,
                           rank=self.telemetry_rank)

    # -------------------------------------------------------------- schedule
    def register_moments(self, stream: str, moments: dict[int, list[int]]) -> None:
        """Install a stream's warm-up reference schedule for OPT eviction."""
        self._moments[stream] = {c: sorted(ms) for c, ms in moments.items()}

    def set_moment(self, moment: int) -> None:
        """Advance the *default tenant's* moment cursor (the single-tenant
        entry point; engines on named tenants call their
        :meth:`Tenant.set_moment`)."""
        self._default_tenant.set_moment(moment)

    def set_timeline(self, timeline: TransferTimeline | None) -> None:
        """Attach a transfer timeline: every tier move (and collective)
        from here on is enqueued on its DMA engines."""
        self.timeline = timeline
        if timeline is not None and self.telemetry is not None:
            timeline.set_telemetry(self.telemetry, rank=self.telemetry_rank)

    def set_telemetry(self, telemetry: Telemetry | None, *,
                      rank: int | None = None) -> None:
        """Attach a telemetry hub: every tier move, eviction decision,
        prefetch phase, collective and OOM from here on emits a
        structured event, and the hub's flight recorder is appended to
        OutOfMemory reports.  ``rank`` tags every event (and Chrome-trace
        track) on distributed pools.  Re-pointing a pool (e.g. an explicit
        ``telemetry=`` overriding an adopted default hub) detaches it from
        the previous hub so each hub's counter ground truth covers exactly
        the pools whose events it holds."""
        if self.telemetry is not None and self.telemetry is not telemetry:
            self.telemetry.detach_pool(self)
        self.telemetry = telemetry
        self.telemetry_rank = rank
        if telemetry is not None:
            telemetry.attach_pool(self)
        if self.timeline is not None:
            self.timeline.set_telemetry(telemetry, rank=rank)

    def _now(self) -> float | None:
        """Event timestamp: the simulated clock when a timeline is
        attached, None (moment-index ordering) otherwise."""
        return self.timeline.now if self.timeline is not None else None

    def _last_window(self) -> tuple[float | None, float]:
        """(start ts, duration) of the transfer the timeline recorded
        last — the slice the matching telemetry event occupies."""
        if self.timeline is None:
            return None, 0.0
        start, end = self.timeline.last_window
        return start, end - start

    def set_chunkable_memory_fn(self, fn: Callable[[], int | None],
                                tenant: Tenant | None = None,
                                basis_bytes: int | None = None) -> None:
        """Tracer hook: returns the device bytes currently usable for the
        tenant's chunks.  On shared pools each tenant installs its own fn;
        the shortfall it reports (vs ``basis_bytes``, the device share the
        fn measures against — its lease/planning share) shrinks the
        pool-wide admission budget."""
        t = tenant or self._default_tenant
        self._chunkable_fns[t.name] = (t, fn, basis_bytes)

    def device_budget(self) -> int | None:
        if not self._chunkable_fns:
            return self.device_capacity
        if self.device_capacity is None:
            # unbounded tier: the throttle IS the budget (tightest wins)
            dyns = [fn() for _t, fn, _b in self._chunkable_fns.values()]
            dyns = [d for d in dyns if d is not None]
            return min(dyns) if dyns else None
        # each tenant's fn reports its chunkable bytes against its own
        # device share (the basis registered with the fn, else its soft
        # budget, else the whole tier); the shortfall is that tenant's
        # live non-model footprint and shrinks the shared tier for
        # everyone.  Single tenant: basis == cap, and
        # cap - max(0, cap - dyn) == min(cap, dyn), the historical value.
        budget = self.device_capacity
        for t, fn, basis in self._chunkable_fns.values():
            dyn = fn()
            if dyn is None:
                continue
            if basis is None:
                basis = t.device_budget_bytes
            if basis is None:
                basis = self.device_capacity
            budget -= max(0, basis - dyn)
        return budget

    def _next_use(self, stream: str, chunk_id: int, at: int | None = None) -> int:
        ms = self._moments.get(stream, {}).get(chunk_id)
        if not ms:
            return _NEVER  # never used again -> perfect victim
        if at is None:
            # the stream's own tenant clock: one tenant's schedule is
            # meaningless under another tenant's moment cursor
            mgr = self._streams.get(stream)
            at = (mgr.tenant.current_moment if mgr is not None
                  else self._default_tenant.current_moment)
        # bisect_left: a reference AT the query moment is still upcoming
        # (several chunks share one operator moment and are accessed in
        # sequence after it is recorded) — treating it as past would mark
        # a chunk the running operator needs as a perfect victim.
        i = bisect.bisect_left(ms, at)
        return ms[i] if i < len(ms) else _NEVER

    # --------------------------------------------------------------- paging
    def tick(self) -> int:
        self._clock += 1
        return self._clock

    def ensure_on(self, mgr: "ChunkManager", chunk_id: int, dev: Device) -> "_ChunkRecord":
        """Demand paging: bring a stream's chunk to ``dev`` (Algorithm 1)."""
        rec = mgr._records[chunk_id]
        now = self.tick()
        rec.last_use = now
        key = (mgr.name, chunk_id)
        if rec.payload is None:
            self.make_room(dev, mgr.chunk_bytes, exclude=key)
            rec.payload = np.zeros(mgr.cmap.chunk_size, dtype=mgr.dtype)
            rec.location = dev
            rec.arrival = now
            self._charge(mgr, dev, mgr.chunk_bytes)
            return rec
        if rec.location != dev:
            if key in self._staged:
                # staged chunks live on the device, so this move is d2h:
                # the chunk was pulled host-side before its device use and
                # the staged H2D will be re-paid later — a wasted stage.
                for pf in (self.prefetch, mgr.tenant.prefetch):
                    pf.wasted_stages += 1
                self._staged.discard(key)
                if self.timeline is not None:
                    self.timeline.cancel(key)
                tel = self.telemetry
                if tel is not None:
                    tel.prefetch("stale", stream=mgr.name,
                                 tenant=mgr.tenant.name, chunk_id=chunk_id,
                                 nbytes=mgr.chunk_bytes, ts=self._now(),
                                 moment=mgr.tenant.current_moment,
                                 rank=self.telemetry_rank, why="left-device")
            # moves run between adjacent tiers only: a slow<->device
            # demand routes through host (s2h + h2d, both legs waited on).
            # Pin across the route: ``exclude`` shields the chunk from
            # direct victim picks, but an eviction CASCADE excludes only
            # its own incoming chunk — without the pin it could demote
            # this record off its mid-route tier (e.g. the h2d leg's
            # make_room bouncing it host->slow right before the move).
            rec.pinned += 1
            try:
                for hop in self._route(rec.location, dev):
                    # the chunk vacates its source tier as it lands on
                    # the next: let the capacity checks along the
                    # eviction cascade see those bytes as in flight,
                    # else a full mid-route tier deadlocks on the
                    # chunk's own (pinned, departing) residency
                    src = rec.location
                    self._uncharge(mgr, src, mgr.chunk_bytes)
                    try:
                        self.make_room(hop, mgr.chunk_bytes, exclude=key)
                    finally:
                        self._charge(mgr, src, mgr.chunk_bytes)
                    self._move(mgr, rec, hop, kind="demand")
            finally:
                rec.pinned -= 1
        elif dev == "device" and key in self._staged:
            for pf in (self.prefetch, mgr.tenant.prefetch):
                pf.hits += 1
            self._staged.discard(key)
            if self.timeline is not None:
                # the consumer arrived: a staged transfer still on the
                # wire stalls it for the remainder — hidden bytes beyond
                # the overlap window surface instead of disappearing.
                self.timeline.wait_for(key)
            tel = self.telemetry
            if tel is not None:
                tel.prefetch("hit", stream=mgr.name, tenant=mgr.tenant.name,
                             chunk_id=chunk_id, nbytes=mgr.chunk_bytes,
                             ts=self._now(),
                             moment=mgr.tenant.current_moment,
                             rank=self.telemetry_rank)
        return rec

    def release_payload(self, mgr: "ChunkManager", chunk_id: int) -> None:
        """Drop a chunk's payload and release its bytes (tensors all FREE)."""
        rec = mgr._records[chunk_id]
        if rec.payload is not None:
            self._uncharge(mgr, rec.location, mgr.chunk_bytes)
        rec.payload = None
        rec.location = None
        self._staged.discard((mgr.name, chunk_id))
        if self.timeline is not None:
            self.timeline.cancel((mgr.name, chunk_id))

    def _capacity(self, dev: Device) -> int | None:
        """Admission budget of a tier (device is dynamically throttled)."""
        if dev == "device":
            return self.device_budget()
        return self.host_capacity if dev == "host" else self.slow_capacity

    def _static_capacity(self, dev: Device) -> int | None:
        """Hard tier bound, ignoring the dynamic device throttle (the
        spill-destination limit: margin-space overflow may exceed the
        chunkable budget of the moment but never the physical tier)."""
        if dev == "device":
            return self.device_capacity
        return self.host_capacity if dev == "host" else self.slow_capacity

    def _used(self, dev: Device) -> int:
        if dev == "device":
            return self._device_used
        return self._host_used if dev == "host" else self._slow_used

    def _route(self, from_dev: Device, to_dev: Device) -> list[Device]:
        """Hop sequence from ``from_dev`` to ``to_dev`` walking adjacent
        tiers (device<->host<->slow): one hop between neighbours, two via
        host for the slow<->device pair."""
        if {from_dev, to_dev} == {"device", "slow"}:
            return ["host", to_dev]
        return [to_dev]

    def _evict_target(self, from_dev: Device) -> Device:
        """Eviction demotes one tier down the stack; the bottom tier
        bounces back up (two-tier: host->device, the paper's margin-space
        overflow; three-tier: slow->host)."""
        i = self.tiers.index(from_dev)
        return self.tiers[i + 1] if i + 1 < len(self.tiers) else self.tiers[i - 1]

    def _account_transfer(self, mgr: "ChunkManager", *, link: str) -> None:
        for st in (self.stats, mgr.stats, mgr.tenant.stats):
            if link == "h2d":
                st.h2d_bytes += mgr.chunk_bytes
                st.h2d_count += 1
            elif link == "d2h":
                st.d2h_bytes += mgr.chunk_bytes
                st.d2h_count += 1
            elif link == "h2s":
                st.h2s_bytes += mgr.chunk_bytes
                st.h2s_count += 1
            else:
                st.s2h_bytes += mgr.chunk_bytes
                st.s2h_count += 1

    def _move(
        self,
        mgr: "ChunkManager",
        rec: "_ChunkRecord",
        to_dev: Device,
        *,
        kind: str,  # "demand" | "evict" | "stage"
        after: float | None = None,
    ) -> float | None:
        """The single tier-move bookkeeping point: transfer stats, the
        hidden/critical H2D split, byte counters, location and arrival.
        ``hidden + critical == h2d`` holds because every H2D goes through
        here with exactly one classification.  Moves span exactly one DMA
        link (adjacent tiers); multi-hop routes chain calls, passing the
        previous leg's returned completion time as ``after`` so the
        timeline serializes the legs.  Returns the timeline completion
        time of this leg (None without a timeline)."""
        link = _LINKS[(rec.location, to_dev)]
        self._account_transfer(mgr, link=link)
        if link == "h2d":
            for pf in (self.prefetch, mgr.tenant.prefetch):
                if kind == "stage":
                    pf.hidden_h2d_bytes += mgr.chunk_bytes
                    pf.staged_transfers += 1
                else:
                    # demand misses and evictions bounced back to the
                    # device are traffic the consuming operator waits on
                    pf.critical_h2d_bytes += mgr.chunk_bytes
                    if kind == "demand":
                        pf.demand_misses += 1
        end: float | None = None
        if self.timeline is not None:
            key = (mgr.name, rec.chunk_id)
            if link == "h2d":
                if kind == "stage":
                    end = self.timeline.record_h2d(
                        mgr.chunk_bytes, stream=mgr.name, critical=False,
                        key=key, start_after=after)
                else:
                    end = self.timeline.record_h2d(
                        mgr.chunk_bytes, stream=mgr.name, critical=True,
                        start_after=after)
            elif link == "s2h":
                # the fetch direction of the slow lane: a demand promotion
                # waits on it; a staged two-hop overlaps (the h2d leg
                # chained ``after`` it carries the rendezvous key).
                end = self.timeline.record_s2h(
                    mgr.chunk_bytes, stream=mgr.name,
                    critical=kind != "stage" and self._staging == 0,
                    start_after=after)
            else:
                # d2h / h2s, the demotion directions: issued by the
                # staging path (making room ahead of demand) they are
                # overlappable; a demand-path eviction blocks the
                # admission that triggered it.
                record = (self.timeline.record_d2h if link == "d2h"
                          else self.timeline.record_h2s)
                end = record(mgr.chunk_bytes, stream=mgr.name,
                             critical=self._staging == 0, start_after=after)
        tel = self.telemetry
        if tel is not None:
            # "bounce": an eviction moving UP the tier stack (the
            # bottom-tier overflow escape, e.g. host->device on two-tier
            # pools) rather than demoting down it.
            cause = ("bounce" if kind == "evict"
                     and TIER_ORDER.index(to_dev)
                     < TIER_ORDER.index(rec.location) else kind)
            if link == "h2d":
                crit = kind != "stage"
            elif link == "s2h":
                crit = kind != "stage" and self._staging == 0
            else:
                crit = self._staging == 0
            ts, dur = self._last_window()
            tel.move(link, stream=mgr.name, tenant=mgr.tenant.name,
                     chunk_id=rec.chunk_id, nbytes=mgr.chunk_bytes,
                     cause=cause, critical=crit, ts=ts, dur=dur,
                     moment=mgr.tenant.current_moment,
                     rank=self.telemetry_rank)
            if link == "h2d" and kind == "demand":
                tel.prefetch("miss", stream=mgr.name, tenant=mgr.tenant.name,
                             chunk_id=rec.chunk_id, nbytes=mgr.chunk_bytes,
                             ts=ts, moment=mgr.tenant.current_moment,
                             rank=self.telemetry_rank)
        self._uncharge(mgr, rec.location, mgr.chunk_bytes)
        rec.location = to_dev
        self._charge(mgr, to_dev, mgr.chunk_bytes)
        rec.arrival = self.tick()
        return end

    def _usage_report(self) -> str:
        """Per-tier, per-stream usage breakdown for OutOfMemory messages.
        On multi-tenant pools streams group under their tenant, each
        tenant annotated with its tier usage (and soft budget when set) —
        a refusal must be explainable per tenant, not just per stream."""
        lines = []
        multi = len(self._tenants) > 1
        for dev in self.tiers:
            cap = self._static_capacity(dev)
            if multi:
                groups = []
                for tname, t in sorted(self._tenants.items()):
                    per_t = ", ".join(
                        f"{name}={self._stream_used(mgr, dev)}"
                        for name, mgr in sorted(self._streams.items())
                        if mgr.tenant is t)
                    if not per_t:
                        continue
                    budget = t.soft_budget(dev)
                    used = t.bytes_used(dev)
                    head = (f"{tname}[{used}/{budget}]" if budget is not None
                            else f"{tname}[{used}]")
                    groups.append(f"{head}: {per_t}")
                per = "; ".join(groups)
            else:
                per = ", ".join(
                    f"{name}={self._stream_used(mgr, dev)}"
                    for name, mgr in sorted(self._streams.items()))
            lines.append(
                f"  {dev}: used={self._used(dev)}"
                f"/{'unbounded' if cap is None else cap}"
                + (f" ({per})" if per else ""))
        return "tier usage by stream:\n" + "\n".join(lines)

    @staticmethod
    def _stream_used(mgr: "ChunkManager", dev: Device) -> int:
        if dev == "device":
            return mgr._device_used
        return mgr._host_used if dev == "host" else mgr._slow_used

    def _oom(self, reason: str, detail: str) -> OutOfMemory:
        """Build an :class:`OutOfMemory`: the usage report as always, plus
        — with a hub attached — an ``oom`` event (naming any shielding
        tenants) and the flight recorder's last 32 events, so eviction-
        shield deadlocks are diagnosable post-mortem."""
        msg = f"{detail}\n{self._usage_report()}"
        tel = self.telemetry
        if tel is not None:
            tel.oom(reason, ts=self._now(), rank=self.telemetry_rank,
                    blocked_by=sorted(self._blocked_by))
            msg = f"{msg}\n{tel.flight_report(32)}"
        return OutOfMemory(msg)

    def make_room(
        self, dev: Device, nbytes: int, *, exclude: tuple[str, int]
    ) -> None:
        # the requesting tenant — the incoming chunk's owner — drives the
        # priority shield at this hop
        emgr = self._streams.get(exclude[0])
        req = emgr.tenant if emgr is not None else self._default_tenant
        # a requester with a soft budget on this tier keeps ITSELF inside
        # it when it can: its own coldest chunks demote first — the
        # eviction pressure a solo engine's pool cap exerts, reproduced
        # against the tenant share on shared pools (otherwise a budgeted
        # tenant would sprawl into the peer's headroom and its "budgets
        # hold" guarantee would be vacuous).  Soft: with no own victim
        # the overflow stands; budgets never hard-gate admission.
        budget = req.soft_budget(dev)
        if budget is not None:
            rounds = sum(len(m._records) for m in self._streams.values()) + 1
            while req.bytes_used(dev) + nbytes > budget and rounds > 0:
                victim = self._pick_victim(dev, exclude=exclude, within=req)
                if victim is None:
                    break
                rounds -= 1
                self._evict(*victim, from_dev=dev, by=req)
        cap = self._capacity(dev)
        if cap is None:
            return
        # bound the loop: with every other tier full an eviction can bounce
        # its cascade right back (net-zero progress), so "no progress in
        # #chunks rounds" is a genuine capacity failure, not bad luck.
        rounds = sum(len(m._records) for m in self._streams.values()) + 1
        while self._used(dev) + nbytes > cap:
            victim = self._pick_victim(dev, exclude=exclude, by=req)
            if victim is None:
                blocked = ""
                if self._blocked_by:
                    blocked = (
                        "; candidates remain but are shielded by the soft "
                        "budget of higher-priority tenant(s): "
                        + ", ".join(sorted(self._blocked_by)))
                raise self._oom(
                    "no-evictable",
                    f"unified pool: cannot fit {nbytes} bytes on {dev}: "
                    f"used={self._used(dev)} cap={cap} and no evictable "
                    f"chunk (every resident is pinned, in COMPUTE, or the "
                    f"incoming chunk itself){blocked}")
            if rounds <= 0:
                raise self._oom(
                    "no-progress",
                    f"unified pool: cannot fit {nbytes} bytes on {dev}: "
                    f"used={self._used(dev)} cap={cap}; evictable chunks "
                    f"remain but eviction made no net progress (cascades "
                    f"bounce between full tiers)")
            rounds -= 1
            self._evict(*victim, from_dev=dev, by=req)

    def _evictable(
        self, dev: Device, exclude: tuple[str, int],
        by: Tenant | None = None,
        within: "Tenant | None" = None,
    ) -> list[tuple["ChunkManager", "_ChunkRecord"]]:
        out = []
        self._blocked_by = set()
        for mgr in self._streams.values():
            if within is not None and mgr.tenant is not within:
                # self-eviction-to-budget scan: only the requester's own
                # residency is a candidate
                continue
            for rec in mgr._records:
                if (mgr.name, rec.chunk_id) == exclude:
                    continue
                if rec.payload is None or rec.location != dev:
                    continue
                if rec.pinned > 0:
                    continue
                if mgr.chunk_state(rec.chunk_id) is ChunkState.COMPUTE:
                    continue
                t = mgr.tenant
                if (by is not None and t is not by
                        and t.priority > by.priority and t.protected_on(dev)):
                    # priority shield: a higher-priority tenant within its
                    # soft budget never loses a chunk to a lower-priority
                    # tenant's demand
                    self._blocked_by.add(t.name)
                    continue
                out.append((mgr, rec))
        return out

    def _pick_victim(
        self, dev: Device, *, exclude: tuple[str, int],
        by: Tenant | None = None,
        within: "Tenant | None" = None,
    ) -> tuple["ChunkManager", "_ChunkRecord"] | None:
        cands = self._evictable(dev, exclude, by, within)
        if not cands:
            return None
        # tenants over their soft budget give up chunks first (the shared
        # overflow region drains before anyone's in-budget residency);
        # single-tenant pools never configure budgets, so the urgency key
        # is constant and the historical ordering — ties included — is
        # preserved exactly.
        if self.policy == "fifo":
            return min(cands, key=lambda mr: (
                0 if mr[0].tenant.over_budget(dev) else 1, mr[1].arrival))
        if self.policy == "lru":
            return min(cands, key=lambda mr: (
                0 if mr[0].tenant.over_budget(dev) else 1, mr[1].last_use))
        # OPT / Belady: farthest next use according to the tracer
        # schedule.  Cross-tenant moment clocks are incomparable absolute
        # values (a serving tenant's moments grow without bound while a
        # trainer's reset each step), so compare the *distance* from each
        # chunk's own tenant cursor — a constant offset within one tenant,
        # hence argmax- and tie-break-identical on single-tenant pools.
        return max(cands, key=lambda mr: (
            0 if not mr[0].tenant.over_budget(dev) else 1,
            self._next_use(mr[0].name, mr[1].chunk_id)
            - mr[0].tenant.current_moment))

    def _evict(
        self,
        mgr: "ChunkManager",
        rec: "_ChunkRecord",
        *,
        from_dev: Device,
        by: Tenant | None = None,
        _depth: int = 0,
    ) -> None:
        if _depth > sum(len(m._records) for m in self._streams.values()):
            # cascades bouncing between full tiers would otherwise
            # recurse forever; this is a genuine capacity fail
            raise self._oom(
                "cascade-cycle",
                "unified pool: eviction cascade cycled — every tier full")
        key = (mgr.name, rec.chunk_id)
        if key in self._staged:
            for pf in (self.prefetch, mgr.tenant.prefetch):
                pf.wasted_stages += 1
            self._staged.discard(key)
            if self.timeline is not None:
                self.timeline.cancel(key)
            tel = self.telemetry
            if tel is not None:
                tel.prefetch("stale", stream=mgr.name, tenant=mgr.tenant.name,
                             chunk_id=rec.chunk_id, nbytes=mgr.chunk_bytes,
                             ts=self._now(),
                             moment=mgr.tenant.current_moment,
                             rank=self.telemetry_rank, why="evicted")
        if mgr.chunk_state(rec.chunk_id) is ChunkState.FREE:
            self.release_payload(mgr, rec.chunk_id)
            return
        if by is not None:
            # who-demoted-whom ledger (FREE releases above lose nothing
            # and are not evictions in the accountable sense)
            self.evictions[(mgr.tenant.name, by.name)] += 1
        to_dev = self._evict_target(from_dev)
        tel = self.telemetry
        if tel is not None:
            vt = mgr.tenant
            tel.evict(victim=vt.name,
                      requester=by.name if by is not None else vt.name,
                      policy=self.policy,
                      urgency=("over-budget" if vt.over_budget(from_dev)
                               else "in-budget"),
                      stream=mgr.name, chunk_id=rec.chunk_id,
                      nbytes=mgr.chunk_bytes, src=from_dev, dst=to_dev,
                      ts=self._now(), moment=vt.current_moment,
                      rank=self.telemetry_rank)
        # spill destination bound: a bottom-tier bounce (two-tier:
        # host->device, the paper's margin-space overflow of Fig. 10's
        # host-too-small case) is limited by the *static* tier capacity,
        # not by the dynamic chunkable budget that throttles ordinary
        # admissions.  Cascade size-aware: with heterogeneous per-stream
        # chunk sizes one small victim can leave the destination still
        # over budget, so keep evicting until the incoming chunk actually
        # fits (a single-victim cascade silently overflowed the tier).
        cap = self._static_capacity(to_dev)
        if cap is not None:
            rounds = sum(len(m._records) for m in self._streams.values()) + 1
            while self._used(to_dev) + mgr.chunk_bytes > cap:
                # at this hop the incoming chunk is the demoted victim, so
                # ITS tenant is the requester for the priority shield
                victim = self._pick_victim(to_dev, exclude=key,
                                           by=mgr.tenant)
                if victim is None:
                    raise self._oom(
                        "target-full",
                        f"unified pool: eviction target {to_dev} full and "
                        f"no victim")
                if rounds <= 0:
                    raise self._oom(
                        "target-no-progress",
                        f"unified pool: eviction target {to_dev} full and "
                        f"cascades make no net progress")
                rounds -= 1
                self._evict(*victim, from_dev=to_dev, by=mgr.tenant,
                            _depth=_depth + 1)
        self._move(mgr, rec, to_dev, kind="evict")

    # -------------------------------------------------------------- staging
    def stage(self, stream: str, chunk_id: int) -> bool:
        """Simulated-async prefetch: move a chunk to the device ahead of its
        use, classifying the H2D as *hidden*.  OPT-policy pools only —
        staging is driven by the future-reference schedule, and letting it
        evict under lru/fifo would inject that future knowledge into the
        baseline policies (and skew their measured volume).

        Conservative: stages only into free space, or by replaying the
        eviction demand paging would perform at the chunk's use moment
        ``t`` — a victim must not be referenced before ``t`` (else staging
        would thrash a sooner-needed chunk), must be the farthest-next-use
        *as seen from t* among ALL device residents (Belady's pick at the
        avoided miss), and otherwise staging is refused.  On the engine's
        scan-shaped traces this conserves total transfer volume exactly
        (asserted in benchmarks/eviction.py); on arbitrary interleavings
        residency can still shift between the stage and the use, so the
        in-flight cap in :class:`SchedulePrefetcher` bounds any excess.
        Returns True if the chunk is on-device and marked staged."""
        if self.policy != "opt":
            return False
        mgr = self._streams.get(stream)
        if mgr is None:
            return False  # dynamic stream unregistered after refs installed
        if not 0 <= chunk_id < len(mgr._records):
            # a stale reference from before the stream was rebuilt: a new
            # stream reusing the name may have fewer chunks than the ids
            # an old schedule mentions (DynamicChunkMap recycles ids)
            return False
        rec = mgr._records[chunk_id]
        key = (stream, chunk_id)
        if rec.payload is None or rec.location == "device":
            return False  # nothing to hide (materialization moves no bytes)
        if mgr.chunk_state(chunk_id) is ChunkState.FREE:
            return False
        t_use = self._next_use(stream, chunk_id)
        if t_use == _NEVER:
            return False  # no known future device use: nothing to front-run
        self._staging += 1
        try:
            return self._stage_locked(mgr, rec, key, t_use)
        finally:
            self._staging -= 1

    def _stage_locked(self, mgr: "ChunkManager", rec: "_ChunkRecord",
                      key: tuple[str, int], t_use: int) -> bool:
        # a budgeted tenant's staging makes room against the TIGHTER of
        # the shared tier cap and its own device soft budget — speculative
        # prefetch must not sprawl past the share its demand path defends
        budget = mgr.tenant.soft_budget("device")

        def _need_room() -> bool:
            cap = self._capacity("device")
            if cap is not None and self._used("device") + mgr.chunk_bytes > cap:
                return True
            return (budget is not None
                    and mgr.tenant.bytes_used("device") + mgr.chunk_bytes
                    > budget)

        while _need_room():
            # one sweep over device residents: collect the best evictable
            # victim (not needed before t_use, farthest as seen from it)
            # and the farthest-from-t_use value over ALL residents — if
            # any unevictable resident beats the victim, demand paging at
            # t_use would pick that one instead, so refuse to diverge.
            best: tuple["ChunkManager", "_ChunkRecord"] | None = None
            best_at_use = -1
            resident_max = -1
            for omgr in self._streams.values():
                if omgr.tenant is not mgr.tenant:
                    # staging stays tenant-scoped: a tenant's warm-up
                    # prefetch reasons in its own moment clock and must
                    # never reclaim another tenant's residency — cross-
                    # tenant space is taken only on the demand path, under
                    # the priority shield.
                    continue
                for orec in omgr._records:
                    if orec.payload is None or orec.location != "device":
                        continue
                    if (omgr.name, orec.chunk_id) == key:
                        continue
                    nu_at_use = self._next_use(
                        omgr.name, orec.chunk_id, at=t_use)
                    resident_max = max(resident_max, nu_at_use)
                    if self._next_use(omgr.name, orec.chunk_id) <= t_use:
                        continue  # needed before the staged chunk's use
                    if orec.pinned > 0:
                        continue
                    if omgr.chunk_state(orec.chunk_id) is ChunkState.COMPUTE:
                        continue
                    if nu_at_use > best_at_use:
                        best_at_use = nu_at_use
                        best = (omgr, orec)
            if best is None or best_at_use < resident_max:
                return False
            self._evict(*best, from_dev="device", by=mgr.tenant)
        # a slow-resident chunk needs a two-hop stage: s2h onto the host,
        # then h2d chained after it on the timeline.  Host room is made
        # under the staging flag, so any demotions it cascades stay
        # overlappable.
        after: float | None = None
        if rec.location == "slow":
            self.make_room("host", mgr.chunk_bytes, exclude=key)
            after = self._move(mgr, rec, "host", kind="stage")
        self._move(mgr, rec, "device", kind="stage", after=after)
        self._staged.add(key)
        tel = self.telemetry
        if tel is not None:
            tel.prefetch("issue", stream=mgr.name, tenant=mgr.tenant.name,
                         chunk_id=rec.chunk_id, nbytes=mgr.chunk_bytes,
                         ts=self._now(), moment=mgr.tenant.current_moment,
                         rank=self.telemetry_rank, use_at=t_use)
        return True


class SchedulePrefetcher:
    """Schedule-driven staging queue over a :class:`HeteroMemory` pool.

    After warm-up the tracer yields the iteration's full reference
    sequence ``(moment, stream, chunk_id)``.  ``advance(m)`` stages every
    reference in the window ``(m, m + lookahead]`` — the next-k chunk
    references per stream — before the operator at moment ``m`` runs, so
    their H2D transfers overlap that operator's compute (simulated-async:
    the pool books them as hidden bytes).

    **Bandwidth-aware mode** (``timeline=`` set and durations installed):
    issue depth and issue *time* are chosen against the timeline's
    projected idle windows instead of the fixed ``lookahead`` /
    ``max_inflight``.  Walking upcoming references in schedule order, a
    reference is staged now iff its projected completion (H2D queue
    backlog + wire time) fits inside the compute window until its use
    moment — i.e. the transfer is *actually hidable* — or it is within
    the base ``lookahead`` anyway (an imminent reference gains partial
    overlap even when it cannot fully hide).  The walk stops at the
    first reference that is neither: issuing it now would only park a
    late transfer and occupy memory.  Byte volume stays neutral — every
    stage still goes through the pool's conservative ``stage()`` rule —
    but lead time adapts to bandwidth, which is what cuts stall seconds
    (asserted in benchmarks/timeline.py)."""

    def __init__(
        self, pool: HeteroMemory, *, lookahead: int = 6, max_inflight: int = 2,
        timeline: TransferTimeline | None = None, bw_inflight_cap: int = 16,
        bw_horizon: int = 64, tenant: Tenant | None = None,
    ) -> None:
        self.pool = pool
        # the tenant whose schedule this queue serves: in-flight caps
        # count only its staged chunks and the bandwidth-aware policy
        # reads its moment namespace on a shared timeline.  None (the
        # historical single-owner construction) behaves pool-wide.
        self.tenant = tenant
        self._ns = tenant.timeline_ns if tenant is not None else None
        self.lookahead = lookahead
        # staged-but-not-yet-consumed chunks are capped: staging far past
        # the working set only parks chunks where the next demand miss
        # evicts them again (wasted transfers on tight budgets).
        self.max_inflight = max_inflight
        self.timeline = timeline
        # bandwidth-aware mode still bounds device residency, just looser:
        # depth is chosen by the overlap window, the cap is the backstop.
        self.bw_inflight_cap = bw_inflight_cap
        self.bw_horizon = bw_horizon  # max refs scanned per advance
        self._moments: list[int] = []
        self._refs: list[tuple[int, str, int]] = []
        # the pool tells us when a stream detaches so the queue never
        # stages a later same-named stream off a stale schedule
        pool._prefetchers.append(self)

    @property
    def installed(self) -> bool:
        return bool(self._refs)

    def install(self, refs: Iterable[tuple[int, str, int]]) -> None:
        """``refs``: (moment, stream, chunk_id) for one whole iteration."""
        self._refs = sorted(refs)
        self._moments = [m for m, _, _ in self._refs]

    def drop_stream(self, stream: str) -> None:
        """Forget every queued reference of a detached stream (called by
        :meth:`HeteroMemory.unregister_stream`): a rebuilt stream reusing
        the name recycles chunk ids, so stale refs could stage the wrong
        chunk."""
        if not self._refs:
            return
        self._refs = [r for r in self._refs if r[1] != stream]
        self._moments = [m for m, _, _ in self._refs]

    @property
    def bandwidth_aware(self) -> bool:
        return (self.timeline is not None
                and self.timeline.has_durations_for(self._ns))

    def advance(self, moment: int) -> int:
        """Stage upcoming references; returns how many chunks were staged."""
        if not self._refs or self.lookahead <= 0:
            return 0
        if self.bandwidth_aware:
            return self._advance_bandwidth_aware(moment)
        lo = bisect.bisect_right(self._moments, moment)
        hi = bisect.bisect_right(self._moments, moment + self.lookahead)
        staged = 0
        for m, stream, chunk_id in self._refs[lo:hi]:
            if self.pool.staged_count(self.tenant) >= self.max_inflight:
                break
            if self.pool.stage(stream, chunk_id):
                staged += 1
        return staged

    def _advance_bandwidth_aware(self, moment: int) -> int:
        tl = self.timeline
        assert tl is not None
        lo = bisect.bisect_right(self._moments, moment)
        staged = 0
        for m, stream, chunk_id in self._refs[lo:lo + self.bw_horizon]:
            if self.pool.staged_count(self.tenant) >= self.bw_inflight_cap:
                break
            mgr = self.pool._streams.get(stream)
            if mgr is None or not 0 <= chunk_id < len(mgr._records):
                continue
            if (stream, chunk_id) in self.pool._staged:
                continue
            ready = tl.projected_ready_s("h2d", mgr.chunk_bytes)
            if mgr._records[chunk_id].location == "slow":
                # two-hop stage: the chunk must first cross the slow lane,
                # so its projected landing sums both links' backlogs
                ready += tl.projected_ready_s("s2h", mgr.chunk_bytes)
            if ready <= tl.time_until(m, tenant=self._ns):
                # fits inside the projected idle window before its use
                if self.pool.stage(stream, chunk_id):
                    staged += 1
            elif m <= moment + self.lookahead:
                # imminent: cannot fully hide, but issuing now still
                # converts part of the wait into overlap
                if self.pool.stage(stream, chunk_id):
                    staged += 1
            else:
                # neither hidable nor imminent: the H2D queue is already
                # saturated past this reference's window — stop issuing
                break
        return staged


class GatherPrefetcher:
    """Schedule-driven staging of upcoming remote-group *all-gathers*.

    The distributed eager plane has a second kind of fetch the paper
    overlaps with compute (Section 7 / Fig. 9): a chunk whose owner is a
    remote rank arrives by collective, not by H2D.  After warm-up, the
    tracer's reference sequence tells us which communication group every
    upcoming operator reads, so the driver can issue the group's
    all-gather ahead of the consuming operator — those bytes are booked
    *hidden* in :class:`CollectiveStats`, while demand gathers triggered
    inside an access are *critical-path*.  ``fetch_group(group)`` is the
    driver's collective (it must return True iff a gather actually ran;
    resident groups return False and don't count against the in-flight
    cap).

    The in-flight cap is **global across calls**, mirroring
    :class:`SchedulePrefetcher`'s ``pool._staged`` check: a staged gather
    materializes (p-1)/p of a whole group on every rank and those bytes
    stay resident until the group's replicas are dropped after its
    post-FWD/BWD transition, so the driver must :meth:`retire` the group
    at that drop — only then does a staging slot free up.  (A per-call
    counter would let up to ``lookahead`` unconsumed groups pile up
    across consecutive ``advance()`` calls, silently exceeding the
    documented memory bound.)

    In **bandwidth-aware mode** (``timeline=`` plus ``group_bytes``) the
    issue depth follows the collective lane's projected idle window, the
    same policy as :class:`SchedulePrefetcher`: gather a group ahead iff
    its wire time fits the compute until its consuming moment (or it is
    within the base lookahead), stop at the first group that is neither.
    The in-flight *memory* bound still applies via ``bw_inflight_cap``
    (each staged gather holds (p-1)/p of a group on every rank)."""

    def __init__(
        self,
        fetch_group: Callable[[int], bool],
        *,
        lookahead: int = 2,
        max_inflight: int = 1,
        timeline: TransferTimeline | None = None,
        group_bytes: int = 0,
        bw_inflight_cap: int = 4,
        bw_horizon: int = 16,
    ) -> None:
        self.fetch_group = fetch_group
        self.lookahead = lookahead
        # a staged gather materializes (p-1)/p of a whole group on every
        # rank at once, so in-flight gathers are capped much tighter than
        # in-flight H2D stages.
        self.max_inflight = max_inflight
        self.timeline = timeline
        self.group_bytes = group_bytes
        self.bw_inflight_cap = bw_inflight_cap
        self.bw_horizon = bw_horizon
        self._moments: list[int] = []
        self._refs: list[tuple[int, int]] = []
        # groups staged by this prefetcher whose replicas are still held
        # (gathered, not yet dropped post-FWD/BWD) — the in-flight set
        # the cap bounds.
        self._inflight: set[int] = set()

    @property
    def installed(self) -> bool:
        return bool(self._refs)

    @property
    def inflight(self) -> frozenset[int]:
        """Staged-but-not-yet-dropped groups (test/debug surface)."""
        return frozenset(self._inflight)

    def install(self, group_refs: Iterable[tuple[int, int]]) -> None:
        """``group_refs``: (moment, comm_group) of one whole iteration —
        one entry per (moment, group), already deduplicated."""
        self._refs = sorted(set(group_refs))
        self._moments = [m for m, _ in self._refs]
        self._inflight.clear()

    def retire(self, group: int) -> None:
        """The staged group's replicas were dropped (post-FWD release or
        post-BWD reduce-scatter): its staging slot frees up."""
        self._inflight.discard(group)

    @property
    def bandwidth_aware(self) -> bool:
        return (self.timeline is not None and self.timeline.has_durations
                and self.group_bytes > 0)

    def advance(self, moment: int) -> int:
        """Gather upcoming remote groups; returns how many gathers ran."""
        if not self._refs or self.lookahead <= 0:
            return 0
        if self.bandwidth_aware:
            return self._advance_bandwidth_aware(moment)
        lo = bisect.bisect_right(self._moments, moment)
        hi = bisect.bisect_right(self._moments, moment + self.lookahead)
        fetched = 0
        for _m, group in self._refs[lo:hi]:
            if len(self._inflight) >= self.max_inflight:
                break
            if group in self._inflight:
                continue
            if self.fetch_group(group):
                self._inflight.add(group)
                fetched += 1
        return fetched

    def _advance_bandwidth_aware(self, moment: int) -> int:
        tl = self.timeline
        assert tl is not None
        lo = bisect.bisect_right(self._moments, moment)
        fetched = 0
        for m, group in self._refs[lo:lo + self.bw_horizon]:
            if len(self._inflight) >= self.bw_inflight_cap:
                break
            if group in self._inflight:
                continue
            ready = tl.projected_ready_s("coll", self.group_bytes)
            if ready <= tl.time_until(m) or m <= moment + self.lookahead:
                if self.fetch_group(group):
                    self._inflight.add(group)
                    fetched += 1
            else:
                break
        return fetched


@dataclasses.dataclass
class PoolLease:
    """One engine's handle on a :class:`HeteroMemory` pool.

    Both engines build their memory plane through :func:`acquire_pool`
    so the owned-pool path (budget args -> private ``HeteroMemory``) and
    the external-pool path (shared pool + :class:`Tenant`) cannot drift:
    the lease resolves the tier *shares* the engine should plan against
    (tenant soft budgets, falling back to the pool caps), constructs its
    tenant-tagged streams, and installs its tenant-scoped prefetcher.

    ``device_bytes``/``host_bytes``/``slow_bytes`` are the engine's
    planning shares — for an owned pool they equal the pool caps; for a
    shared pool they are the tenant's soft budgets (the pool itself only
    enforces the hard tier caps; shares bound *planning*, the overflow
    region absorbs transients).
    """

    pool: "HeteroMemory"
    tenant: Tenant
    device_bytes: int | None
    host_bytes: int | None
    slow_bytes: int | None
    timeline: TransferTimeline | None
    owned: bool

    def qualify(self, stream: str) -> str:
        return self.tenant.qualify(stream)

    def stream(self, name, cmap, *, dtype=np.float32):
        """A :class:`ChunkManager` on this lease's pool under its tenant
        (the manager tenant-qualifies ``name`` itself)."""
        from repro.core.manager import ChunkManager

        return ChunkManager(cmap, dtype=dtype, name=name,
                            pool=self.pool, tenant=self.tenant)

    def prefetcher(self, *, lookahead: int,
                   bandwidth_aware: bool = True) -> SchedulePrefetcher | None:
        """Tenant-scoped OPT prefetcher (None under lru/fifo policies —
        they have no schedule to follow)."""
        if self.pool.policy != "opt":
            return None
        return SchedulePrefetcher(
            self.pool, lookahead=lookahead,
            timeline=self.timeline if bandwidth_aware else None,
            tenant=self.tenant)


def acquire_pool(
    *,
    pool: "HeteroMemory | None" = None,
    tenant: Tenant | None = None,
    device_memory_bytes: int | None = None,
    host_memory_bytes: int | None = None,
    slow_memory_bytes: int | None = None,
    policy: EvictionPolicy = "opt",
    timeline: TransferTimeline | None = None,
) -> PoolLease:
    """Resolve an engine's memory plane to a :class:`PoolLease`.

    Two modes, one construction path (so they cannot drift):

    * **Owned** (``pool=None``): build a private :class:`HeteroMemory`
      from the budget args — the historical single-tenant constructor
      path, running on the pool's default tenant.
    * **External** (``pool=`` given): join a shared pool under
      ``tenant`` (default tenant if omitted).  The budget args then only
      *override* the engine's planning shares; tier capacities belong to
      the pool, and the timeline must already be attached to it.
    """
    if pool is None:
        if tenant is not None:
            raise ValueError("tenant= requires an external pool=")
        if device_memory_bytes is None:
            raise ValueError(
                "an owned pool needs device_memory_bytes= (pass pool= to "
                "join an existing one)")
        pool = HeteroMemory(
            device_capacity_bytes=device_memory_bytes,
            host_capacity_bytes=host_memory_bytes,
            slow_capacity_bytes=slow_memory_bytes,
            policy=policy)
        if timeline is not None:
            pool.set_timeline(timeline)
        return PoolLease(pool, pool.default_tenant, device_memory_bytes,
                         host_memory_bytes, slow_memory_bytes,
                         timeline, owned=True)
    t = tenant if tenant is not None else pool.default_tenant
    if t.pool is not pool:
        raise ValueError(
            f"tenant {t.name!r} belongs to a different pool")
    if timeline is not None and timeline is not pool.timeline:
        raise ValueError(
            "external pools own their timeline: attach it with "
            "pool.set_timeline() before constructing engines on it")
    dev = (device_memory_bytes if device_memory_bytes is not None
           else t.device_budget_bytes)
    if dev is None:
        dev = pool.device_capacity
    host = (host_memory_bytes if host_memory_bytes is not None
            else t.host_budget_bytes)
    if host is None:
        host = pool.host_capacity
    slow = (slow_memory_bytes if slow_memory_bytes is not None
            else t.slow_budget_bytes)
    if slow is None:
        slow = pool.slow_capacity
    return PoolLease(pool, t, dev, host, slow, pool.timeline, owned=False)
