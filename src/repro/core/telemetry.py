"""Unified telemetry plane for the chunk memory system.

Every subsystem emits structured :class:`TelemetryEvent` records into one
:class:`Telemetry` hub: chunk moves per DMA hop (``h2d``/``d2h``/``h2s``/
``s2h`` with stream, tenant, chunk id, bytes and *cause* — demand / evict /
stage / bounce), tensor state transitions, eviction decisions (victim,
requester, policy, urgency), prefetch lifecycle (issue / hit / miss /
stale), collectives, stall and compute segments from the transfer
timeline, and begin/end *span* events for steps, moments, serving rounds
and per-rank phases.

Clock semantics
---------------
Events are timestamped on the :class:`~repro.core.timeline.TransferTimeline`
simulated clock (seconds) whenever a timeline is attached at the emit
site; sites with no timeline record ``ts=None`` and rely on the moment
index (and the global sequence number) for ordering.  The Chrome-trace
exporter uses the simulated clock when every placeable event carries one,
and falls back to sequence-number timestamps otherwise — the decision is
global, so timestamps are always monotone per track.

Conservation
------------
The event log is *falsifiable*: byte totals derived from move events must
equal the pool's :class:`~repro.core.memory.TransferStats` counters
exactly, stall seconds derived from stall events must equal the
:class:`~repro.core.timeline.StepTimeline` lanes exactly, and the
hidden/critical H2D split derived from move causes must equal
:class:`~repro.core.memory.PrefetchStats`.  ``assert_conservation()``
checks all of it against every attached pool/timeline and raises on any
mismatch.  Byte counters are integers (exact by construction); stall
fields are float left-folds of the *same* number sequence in the same
order on both sides, so they are bit-identical too.

Cost discipline: a disabled hub (``telemetry=None``, the default
everywhere) costs exactly one predicate per call site, keeping every
existing code path byte-identical.
"""

from __future__ import annotations

import dataclasses
import json
from collections import Counter, deque
from typing import Any

MOVE_LANES = ("h2d", "d2h", "h2s", "s2h")
ALL_LANES = MOVE_LANES + ("coll",)
PREFETCH_PHASES = ("issue", "hit", "miss", "stale")


@dataclasses.dataclass
class TelemetryEvent:
    """One structured record in the event log.

    ``kind`` is the taxonomy bucket (move / evict / oom / prefetch /
    collective / state / stall / compute / span / snapshot / mark);
    ``name`` is the kind-specific subject (the lane for moves/stalls, the
    prefetch phase, the collective op, the span track, ...).  ``attrs``
    holds kind-specific details (cause, victim, policy, ph, ...).
    """

    seq: int
    kind: str
    name: str
    ts: float | None = None
    dur: float = 0.0
    moment: int | None = None
    stream: str | None = None
    tenant: str | None = None
    rank: int | None = None
    chunk_id: int | None = None
    nbytes: int = 0
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    def format(self) -> str:
        """One human-readable flight-recorder line."""
        if self.ts is not None:
            clock = f"t={self.ts:.6f}s"
        elif self.moment is not None:
            clock = f"m={self.moment}"
        else:
            clock = f"#{self.seq}"
        bits = [f"[{clock}]", self.kind, self.name]
        if self.rank is not None:
            bits.append(f"rank={self.rank}")
        if self.tenant is not None:
            bits.append(f"tenant={self.tenant}")
        if self.stream is not None:
            bits.append(f"stream={self.stream}")
        if self.chunk_id is not None:
            bits.append(f"chunk={self.chunk_id}")
        if self.nbytes:
            bits.append(f"bytes={self.nbytes}")
        if self.dur:
            bits.append(f"dur={self.dur:.6f}s")
        bits.extend(f"{k}={v}" for k, v in self.attrs.items())
        return " ".join(bits)


class Telemetry:
    """The hub: an append-only event log plus a bounded ring buffer
    (the flight recorder) and per-step metric snapshots.

    ``capture_states`` gates tensor state-transition events, by far the
    most voluminous kind — benchmarks exporting long traces turn them
    off; tests that assert on them leave the default on.
    """

    def __init__(self, *, ring_capacity: int = 256,
                 capture_states: bool = True) -> None:
        self.events: list[TelemetryEvent] = []
        self.ring: deque[TelemetryEvent] = deque(maxlen=ring_capacity)
        self.snapshots: list[dict[str, Any]] = []
        self.capture_states = capture_states
        self._seq = 0
        self._pools: list[Any] = []
        self._timelines: list[Any] = []
        self._spans: dict[tuple[int | None, str], list[TelemetryEvent]] = {}

    # ------------------------------------------------------------ registry
    def attach_pool(self, pool: Any) -> None:
        if not any(p is pool for p in self._pools):
            self._pools.append(pool)

    def detach_pool(self, pool: Any) -> None:
        self._pools = [p for p in self._pools if p is not pool]

    def attach_timeline(self, timeline: Any) -> None:
        if not any(t is timeline for t in self._timelines):
            self._timelines.append(timeline)

    def detach_timeline(self, timeline: Any) -> None:
        self._timelines = [t for t in self._timelines if t is not timeline]

    # ---------------------------------------------------------------- emit
    def emit(self, kind: str, name: str, *, ts: float | None = None,
             dur: float = 0.0, moment: int | None = None,
             stream: str | None = None, tenant: str | None = None,
             rank: int | None = None, chunk_id: int | None = None,
             nbytes: int = 0, **attrs: Any) -> TelemetryEvent:
        ev = TelemetryEvent(
            seq=self._seq, kind=kind, name=name, ts=ts, dur=dur,
            moment=moment, stream=stream, tenant=tenant, rank=rank,
            chunk_id=chunk_id, nbytes=nbytes, attrs=attrs)
        self._seq += 1
        self.events.append(ev)
        self.ring.append(ev)
        return ev

    # -------------------------------------------------------- typed events
    def move(self, lane: str, *, stream: str, tenant: str | None,
             chunk_id: int, nbytes: int, cause: str, critical: bool,
             ts: float | None = None, dur: float = 0.0,
             moment: int | None = None,
             rank: int | None = None) -> TelemetryEvent:
        assert lane in MOVE_LANES, lane
        return self.emit("move", lane, ts=ts, dur=dur, moment=moment,
                         stream=stream, tenant=tenant, rank=rank,
                         chunk_id=chunk_id, nbytes=nbytes, cause=cause,
                         critical=critical)

    def evict(self, *, victim: str, requester: str, policy: str,
              urgency: str, stream: str, chunk_id: int, nbytes: int,
              src: str, dst: str, ts: float | None = None,
              moment: int | None = None,
              rank: int | None = None) -> TelemetryEvent:
        return self.emit("evict", victim, ts=ts, moment=moment,
                         stream=stream, tenant=victim, rank=rank,
                         chunk_id=chunk_id, nbytes=nbytes,
                         requester=requester, policy=policy,
                         urgency=urgency, src=src, dst=dst)

    def prefetch(self, phase: str, *, stream: str, tenant: str | None,
                 chunk_id: int | None = None, nbytes: int = 0,
                 ts: float | None = None, moment: int | None = None,
                 rank: int | None = None, **attrs: Any) -> TelemetryEvent:
        assert phase in PREFETCH_PHASES, phase
        return self.emit("prefetch", phase, ts=ts, moment=moment,
                         stream=stream, tenant=tenant, rank=rank,
                         chunk_id=chunk_id, nbytes=nbytes, **attrs)

    def collective(self, op: str, *, nbytes: int, stream: str,
                   tenant: str | None, hidden: bool = False,
                   ts: float | None = None, dur: float = 0.0,
                   moment: int | None = None, rank: int | None = None,
                   **attrs: Any) -> TelemetryEvent:
        return self.emit("collective", op, ts=ts, dur=dur, moment=moment,
                         stream=stream, tenant=tenant, rank=rank,
                         nbytes=nbytes, hidden=hidden, **attrs)

    def state(self, tensor: str, *, old: str, new: str, stream: str,
              tenant: str | None, chunk_id: int,
              ts: float | None = None, moment: int | None = None,
              rank: int | None = None) -> TelemetryEvent | None:
        if not self.capture_states:
            return None
        return self.emit("state", tensor, ts=ts, moment=moment,
                         stream=stream, tenant=tenant, rank=rank,
                         chunk_id=chunk_id, old=old, new=new)

    def stall(self, lane: str, *, stream: str, seconds: float,
              ts: float | None = None, moment: int | None = None,
              tenant: str | None = None,
              rank: int | None = None) -> TelemetryEvent:
        return self.emit("stall", lane, ts=ts, dur=seconds, moment=moment,
                         stream=stream, tenant=tenant, rank=rank)

    def compute(self, *, moment: int, seconds: float,
                tenant: str | None = None, ts: float | None = None,
                rank: int | None = None) -> TelemetryEvent:
        return self.emit("compute", f"m{moment}", ts=ts, dur=seconds,
                         moment=moment, tenant=tenant, rank=rank)

    def oom(self, reason: str, *, stream: str | None = None,
            tenant: str | None = None, blocked_by: list[str] | None = None,
            ts: float | None = None, moment: int | None = None,
            rank: int | None = None, **attrs: Any) -> TelemetryEvent:
        return self.emit("oom", reason, ts=ts, moment=moment,
                         stream=stream, tenant=tenant, rank=rank,
                         blocked_by=list(blocked_by or ()), **attrs)

    def mark(self, name: str, *, ts: float | None = None,
             rank: int | None = None, **attrs: Any) -> TelemetryEvent:
        return self.emit("mark", name, ts=ts, rank=rank, **attrs)

    # ----------------------------------------------------------- span API
    def begin_span(self, track: str, label: str, *,
                   ts: float | None = None, moment: int | None = None,
                   tenant: str | None = None,
                   rank: int | None = None) -> TelemetryEvent:
        ev = self.emit("span", track, ts=ts, moment=moment, tenant=tenant,
                       rank=rank, ph="B", label=label)
        self._spans.setdefault((rank, track), []).append(ev)
        return ev

    def end_span(self, track: str, *, ts: float | None = None,
                 rank: int | None = None) -> TelemetryEvent:
        stack = self._spans.get((rank, track))
        assert stack, f"end_span on empty track {track!r} (rank={rank})"
        begin = stack.pop()
        return self.emit("span", track, ts=ts, rank=rank, ph="E",
                         label=begin.attrs["label"])

    def switch_span(self, track: str, label: str, *,
                    ts: float | None = None, moment: int | None = None,
                    tenant: str | None = None,
                    rank: int | None = None) -> TelemetryEvent:
        """End the open span on ``track`` (if any) and begin ``label``."""
        if self._spans.get((rank, track)):
            self.end_span(track, ts=ts, rank=rank)
        return self.begin_span(track, label, ts=ts, moment=moment,
                               tenant=tenant, rank=rank)

    def close_span(self, track: str, *, ts: float | None = None,
                   rank: int | None = None) -> None:
        if self._spans.get((rank, track)):
            self.end_span(track, ts=ts, rank=rank)

    def open_spans(self) -> list[tuple[int | None, str, str]]:
        return [(rank, track, ev.attrs["label"])
                for (rank, track), stack in self._spans.items()
                for ev in stack]

    # ----------------------------------------------------------- snapshots
    def snapshot(self, label: str, *, ts: float | None = None,
                 rank: int | None = None,
                 **metrics: Any) -> dict[str, Any]:
        snap = {"label": label, "ts": ts, "rank": rank, **metrics}
        self.snapshots.append(snap)
        self.emit("snapshot", label, ts=ts, rank=rank, **metrics)
        return snap

    # ----------------------------------------------------- flight recorder
    def flight_record(self, n: int = 32) -> list[TelemetryEvent]:
        return list(self.ring)[-n:]

    def flight_report(self, n: int = 32) -> str:
        evs = self.flight_record(n)
        if not evs:
            return "flight recorder: (empty)"
        lines = [f"flight recorder (last {len(evs)} events):"]
        lines.extend("  " + ev.format() for ev in evs)
        return "\n".join(lines)

    # ------------------------------------------------------ derived totals
    def lane_bytes(self) -> dict[str, int]:
        """Per-lane transferred bytes derived from move events."""
        out = {lane: 0 for lane in MOVE_LANES}
        for ev in self.events:
            if ev.kind == "move":
                out[ev.name] += ev.nbytes
        return out

    def lane_counts(self) -> dict[str, int]:
        out = {lane: 0 for lane in MOVE_LANES}
        for ev in self.events:
            if ev.kind == "move":
                out[ev.name] += 1
        return out

    def h2d_split(self) -> tuple[int, int]:
        """(hidden, critical) H2D bytes derived from move causes: staged
        transfers ride the prefetch lane, everything else is critical."""
        hidden = critical = 0
        for ev in self.events:
            if ev.kind == "move" and ev.name == "h2d":
                if ev.attrs.get("cause") == "stage":
                    hidden += ev.nbytes
                else:
                    critical += ev.nbytes
        return hidden, critical

    def stall_totals(self) -> dict[str, float]:
        """Per-lane stall seconds derived from stall events, accumulated
        in event order (the same left-fold the timeline performs)."""
        out = {lane: 0.0 for lane in ALL_LANES}
        for ev in self.events:
            if ev.kind == "stall":
                out[ev.name] += ev.dur
        return out

    def compute_total(self) -> float:
        total = 0.0
        for ev in self.events:
            if ev.kind == "compute":
                total += ev.dur
        return total

    def collective_bytes(self) -> dict[str, int]:
        out: Counter[str] = Counter()
        for ev in self.events:
            if ev.kind == "collective":
                out[ev.name] += ev.nbytes
        return dict(out)

    def prefetch_counts(self) -> dict[str, int]:
        """Prefetch lifecycle event counts; each phase maps 1:1 onto a
        :class:`~repro.core.memory.PrefetchStats` counter (issue ->
        staged_transfers, hit -> hits, miss -> demand_misses, stale ->
        wasted_stages)."""
        out = {phase: 0 for phase in PREFETCH_PHASES}
        for ev in self.events:
            if ev.kind == "prefetch":
                out[ev.name] += 1
        return out

    def step_segments(self) -> list[list[TelemetryEvent]]:
        """Split the log into per-step segments on ``take_step`` marks.
        Each segment *includes* its closing mark (which carries the
        StepTimeline lane totals for exact per-step comparison)."""
        segs: list[list[TelemetryEvent]] = []
        cur: list[TelemetryEvent] = []
        for ev in self.events:
            cur.append(ev)
            if ev.kind == "mark" and ev.name == "take_step":
                segs.append(cur)
                cur = []
        if cur:
            segs.append(cur)
        return segs

    # --------------------------------------------------------- validation
    def counter_totals(self) -> dict[str, Any]:
        """Ground-truth totals aggregated over every attached pool and
        timeline — the numbers the event log must conserve."""
        bytes_ = {lane: 0 for lane in MOVE_LANES}
        counts = {lane: 0 for lane in MOVE_LANES}
        hidden = critical = 0
        pf_counts = {phase: 0 for phase in PREFETCH_PHASES}
        coll: Counter[str] = Counter()
        for pool in self._pools:
            st = pool.stats
            for lane in MOVE_LANES:
                bytes_[lane] += getattr(st, f"{lane}_bytes")
                counts[lane] += getattr(st, f"{lane}_count")
            pf = pool.prefetch
            hidden += pf.hidden_h2d_bytes
            critical += pf.critical_h2d_bytes
            pf_counts["issue"] += pf.staged_transfers
            pf_counts["hit"] += pf.hits
            pf_counts["miss"] += pf.demand_misses
            pf_counts["stale"] += pf.wasted_stages
            cs = pool.collectives
            coll["allgather"] += cs.allgather_bytes
            coll["reduce_scatter"] += cs.reduce_scatter_bytes
            coll["allreduce"] += cs.allreduce_bytes
        stalls = {lane: 0.0 for lane in ALL_LANES}
        for tl in self._timelines:
            for lane, s in tl.total_stalls.items():
                stalls[lane] += s
        return {"lane_bytes": bytes_, "lane_counts": counts,
                "hidden_h2d_bytes": hidden, "critical_h2d_bytes": critical,
                "prefetch_counts": pf_counts,
                "collective_bytes": {k: v for k, v in coll.items() if v},
                "stall_seconds": stalls}

    def assert_conservation(self) -> None:
        """Event-derived totals must equal the attached counters EXACTLY.

        Bytes are ints; stall seconds match bit-for-bit because both
        sides accumulate the identical float sequence in the same order.
        """
        truth = self.counter_totals()
        got_bytes = self.lane_bytes()
        assert got_bytes == truth["lane_bytes"], (
            f"lane byte conservation violated: events={got_bytes} "
            f"counters={truth['lane_bytes']}")
        got_counts = self.lane_counts()
        assert got_counts == truth["lane_counts"], (
            f"lane count conservation violated: events={got_counts} "
            f"counters={truth['lane_counts']}")
        hidden, critical = self.h2d_split()
        assert hidden == truth["hidden_h2d_bytes"], (
            f"hidden h2d {hidden} != {truth['hidden_h2d_bytes']}")
        assert critical == truth["critical_h2d_bytes"], (
            f"critical h2d {critical} != {truth['critical_h2d_bytes']}")
        got_pf = self.prefetch_counts()
        assert got_pf == truth["prefetch_counts"], (
            f"prefetch conservation violated: events={got_pf} "
            f"counters={truth['prefetch_counts']}")
        got_coll = self.collective_bytes()
        assert got_coll == truth["collective_bytes"], (
            f"collective conservation violated: events={got_coll} "
            f"counters={truth['collective_bytes']}")
        ranks = [tl.telemetry_rank for tl in self._timelines]
        if len(set(ranks)) == len(ranks):
            # each timeline's stall events form an uninterleaved (per
            # rank) subsequence, so the event-order fold reproduces the
            # timeline's own accumulation bit-for-bit: assert EXACT
            # per-timeline equality.
            for tl in self._timelines:
                got = {lane: 0.0 for lane in ALL_LANES}
                for ev in self.events:
                    if ev.kind == "stall" and ev.rank == tl.telemetry_rank:
                        got[ev.name] += ev.dur
                assert got == tl.total_stalls, (
                    f"stall conservation violated (rank="
                    f"{tl.telemetry_rank}): events={got} "
                    f"counters={tl.total_stalls}")
        else:
            # several timelines share a rank key (e.g. sequential runs
            # logged into one hub): summing across them re-associates the
            # float fold, so allow rounding at the last bits only.
            import math

            got_stalls = self.stall_totals()
            for lane in ALL_LANES:
                assert math.isclose(
                    got_stalls[lane], truth["stall_seconds"][lane],
                    rel_tol=1e-9, abs_tol=1e-12), (
                    f"stall conservation violated on {lane}: "
                    f"events={got_stalls[lane]} "
                    f"counters={truth['stall_seconds'][lane]}")

    def assert_balanced_spans(self) -> None:
        """Every begin has a matching end and no track interleaves."""
        stacks: dict[tuple[int | None, str], list[str]] = {}
        for ev in self.events:
            if ev.kind != "span":
                continue
            key = (ev.rank, ev.name)
            if ev.attrs["ph"] == "B":
                stacks.setdefault(key, []).append(ev.attrs["label"])
            else:
                stack = stacks.get(key)
                assert stack, f"unmatched span end on {key}: {ev.format()}"
                top = stack.pop()
                assert top == ev.attrs["label"], (
                    f"interleaved spans on {key}: end {ev.attrs['label']!r}"
                    f" while {top!r} open")
        leftovers = {k: v for k, v in stacks.items() if v}
        assert not leftovers, f"unclosed spans: {leftovers}"

    # ------------------------------------------------------- chrome export
    def chrome_trace(self) -> dict[str, Any]:
        """Export the log as Chrome ``trace_event`` JSON (object format),
        viewable in Perfetto / chrome://tracing.  Tracks: one per DMA
        lane (rank-prefixed under distributed engines), a ``wall`` track
        perfectly tiled by compute and stall slices, B/E span tracks for
        steps / moments / rounds / per-rank phases, and instant tracks
        for evictions, prefetch lifecycle, state flips, OOMs and marks.
        """
        placeable = ("move", "collective", "stall", "compute", "span")
        use_clock = all(ev.ts is not None for ev in self.events
                        if ev.kind in placeable)
        if use_clock:
            # Several timelines logging into one hub each start their
            # simulated clock at zero; if that would make any track's
            # timestamps regress, fall back to sequence numbers.
            base_track = {"collective": "dma:coll", "stall": "wall",
                          "compute": "wall"}
            last: dict[tuple[int | None, str], float] = {}
            for ev in self.events:
                if ev.kind not in placeable:
                    continue
                tr = base_track.get(ev.kind) or (
                    f"dma:{ev.name}" if ev.kind == "move" else ev.name)
                key = (ev.rank, tr)
                if ev.ts < last.get(key, float("-inf")):
                    use_clock = False
                    break
                last[key] = ev.ts

        def us(ev: TelemetryEvent) -> float:
            base = ev.ts if use_clock else float(ev.seq)
            return base * 1e6

        pid = 1
        tids: dict[str, int] = {}
        out: list[dict[str, Any]] = []

        def tid(track: str) -> int:
            t = tids.get(track)
            if t is None:
                t = tids[track] = len(tids) + 1
                out.append({"ph": "M", "pid": pid, "tid": t,
                            "name": "thread_name",
                            "args": {"name": track}})
            return t

        def track(ev: TelemetryEvent, base: str) -> str:
            return f"rank{ev.rank}/{base}" if ev.rank is not None else base

        for ev in self.events:
            if ev.kind == "move":
                out.append({
                    "ph": "X", "pid": pid,
                    "tid": tid(track(ev, f"dma:{ev.name}")),
                    "ts": us(ev), "dur": ev.dur * 1e6 if use_clock else 0.0,
                    "cat": "move",
                    "name": f"{ev.attrs['cause']} {ev.stream}#{ev.chunk_id}",
                    "args": {"lane": ev.name, "stream": ev.stream,
                             "tenant": ev.tenant, "chunk": ev.chunk_id,
                             "bytes": ev.nbytes, "cause": ev.attrs["cause"],
                             "critical": ev.attrs["critical"]}})
            elif ev.kind == "collective":
                out.append({
                    "ph": "X", "pid": pid,
                    "tid": tid(track(ev, "dma:coll")),
                    "ts": us(ev), "dur": ev.dur * 1e6 if use_clock else 0.0,
                    "cat": "collective", "name": ev.name,
                    "args": {"op": ev.name, "stream": ev.stream,
                             "tenant": ev.tenant, "bytes": ev.nbytes,
                             "hidden": ev.attrs.get("hidden", False)}})
            elif ev.kind == "stall":
                out.append({
                    "ph": "X", "pid": pid, "tid": tid(track(ev, "wall")),
                    "ts": us(ev), "dur": ev.dur * 1e6 if use_clock else 0.0,
                    "cat": "stall", "name": f"stall:{ev.name}",
                    "args": {"lane": ev.name, "stream": ev.stream,
                             "moment": ev.moment, "seconds": ev.dur}})
            elif ev.kind == "compute":
                out.append({
                    "ph": "X", "pid": pid, "tid": tid(track(ev, "wall")),
                    "ts": us(ev), "dur": ev.dur * 1e6 if use_clock else 0.0,
                    "cat": "compute", "name": ev.name,
                    "args": {"moment": ev.moment, "tenant": ev.tenant,
                             "seconds": ev.dur}})
            elif ev.kind == "span":
                rec = {"ph": ev.attrs["ph"], "pid": pid,
                       "tid": tid(track(ev, ev.name)), "ts": us(ev),
                       "cat": "span", "name": ev.attrs["label"]}
                out.append(rec)
            else:  # evict / prefetch / state / oom / snapshot / mark
                args: dict[str, Any] = dict(ev.attrs)
                for field in ("stream", "tenant", "chunk_id", "moment"):
                    v = getattr(ev, field)
                    if v is not None:
                        args[field] = v
                if ev.nbytes:
                    args["bytes"] = ev.nbytes
                out.append({
                    "ph": "i", "pid": pid, "s": "t",
                    "tid": tid(track(ev, ev.kind)),
                    "ts": us(ev), "cat": ev.kind, "name": ev.name,
                    "args": args})
        # Close any spans still open (e.g. a benchmark that probed an
        # OutOfMemory mid-step) so the exported trace is always balanced;
        # assert_balanced_spans stays strict for callers who want that.
        open_spans = {k: v for k, v in self._spans.items() if v}
        if open_spans:
            maxts: dict[int, float] = {}
            for rec in out:
                if rec.get("ph") != "M":
                    maxts[rec["tid"]] = max(
                        maxts.get(rec["tid"], rec["ts"]), rec["ts"])
            for (rank, tr), stack in open_spans.items():
                name = f"rank{rank}/{tr}" if rank is not None else tr
                t = tid(name)
                for begin in reversed(stack):
                    out.append({"ph": "E", "pid": pid, "tid": t,
                                "ts": max(maxts.get(t, 0.0), us(begin)),
                                "cat": "span", "name": begin.attrs["label"]})
        return {"traceEvents": out,
                "otherData": {"clock": "timeline" if use_clock else "seq",
                              "counters": self.counter_totals()}}

    def dump_chrome_trace(self, path: str) -> dict[str, Any]:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace


# Module-level default hub.  ``HeteroMemory`` picks it up at construction
# when no explicit ``telemetry=`` is given, which is how the benchmark
# runner traces every module without per-module wiring; it is None unless
# someone installs one, so tests and library users pay nothing.
_DEFAULT_HUB: Telemetry | None = None


def set_default_hub(hub: Telemetry | None) -> Telemetry | None:
    global _DEFAULT_HUB
    prev = _DEFAULT_HUB
    _DEFAULT_HUB = hub
    return prev


def default_hub() -> Telemetry | None:
    return _DEFAULT_HUB
