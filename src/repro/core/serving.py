"""Chunk-managed serving plane: KV-cache as a managed stream with
continuous batching.

The paper organizes *training* model data as chunks orchestrated across
a CPU+GPU heterogeneous space; this module extends that thesis to the
serving path (the ZeRO-Infinity / Angel-PTM "one manager pages ALL
state" direction).  :class:`ServingEngine` runs eager prefill + greedy
decode with BOTH kinds of serving state inside one
:class:`~repro.core.memory.HeteroMemory` pool:

  * **params** — the familiar chunk stream (read-only here: no grads, no
    optimizer state, the stem stays host-side exactly like training's
    Section 8.2 embedding rule);
  * **kv** — the first *dynamically populated* stream: every admitted
    sequence owns one chunk per (block-group, layer, page) holding that
    layer's decode cache, mapped through
    :class:`~repro.core.chunk.DynamicChunkMap` when the request is
    admitted and unmapped when it completes.  A freshly mapped tensor is
    FREE, so its first access zero-fills — which is precisely an empty
    decode cache.  When the engine fully drains, the kv stream is
    unregistered from the pool and re-registered on the next admission
    (the act stream's rebuild path, now exercised mid-flight).

**Paged KV** (``page_tokens=``): by default one kv chunk holds a
sequence's *entire* decode horizon, so a sequence spills all-or-nothing
and admission reasons in whole horizons.  With a page size the stream's
unit becomes a vLLM-style position-block page — a sequence at position
``p`` holds ``ceil(p / page_tokens)`` chunks per (group, layer), pages
are appended as decode crosses page boundaries, and admission commits a
request's TRUE page footprint at its final position instead of the
whole-horizon template.  Partial spill falls out of the op plan: a
decode visits a sequence's pages one at a time and releases every cold
(non-tail) page HOLD immediately after copying it out, so only the hot
tail page stays COMPUTE-pinned for the write-back — OPT eviction can
keep cold pages on host and the device working set is
pages-at-a-time, never the whole horizon.

Cold sequences spill their KV chunks to host under cross-stream OPT
eviction and are restaged by the :class:`~repro.core.memory.SchedulePrefetcher`
ahead of their turn in the **decode round-robin schedule**: each round
the engine plans the exact (moment, stream, chunk) reference sequence of
this round plus a synthetic next round, registers it as the OPT/prefetch
schedule, and then executes it layer-major (one param fetch per layer
per round, all active sequences' kv chunks visited under it).

**Continuous batching**: ``submit()`` queues a request; each round the
admission loop activates queued requests while the pool can hold the
param working set plus the active KV footprint, and completed sequences
free their chunks immediately — admission capacity returns to the pool
mid-flight, not at batch boundaries.

Correctness is anchored to the compiled path: chunk-managed greedy
decode emits token-for-token identical output to
``driver.build_decode_step`` (tests/test_serving_engine.py), sharing the
same :func:`~repro.models.layers.greedy_token` tie-break.

With ``manage_kv=False`` the engine reproduces the unmanaged baseline
(the seed's ``examples/serve_chunked.py`` behaviour): caches live as raw
device arrays outside every budget decision except a hard reservation
against the device capacity — decode concurrency is whatever fits on the
device.  benchmarks/serving.py measures the managed stream's capacity
win over this baseline at a fixed tight device budget.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunk import (
    TensorSpec,
    build_chunk_map,
    build_kv_chunk_map,
    pages_for,
    search_chunk_size,
)
from repro.core.manager import ChunkManager
from repro.core.memory import (
    HeteroMemory,
    SchedulePrefetcher,
    Tenant,
    acquire_pool,
)
from repro.core.state import TensorState
from repro.core.telemetry import Telemetry
from repro.core.timeline import StepTimeline, TransferTimeline

# shared with the training engine: leaf names MUST be byte-identical
# across planes for chunk placements to line up
from repro.core.engine import _leaves_with_names
from repro.models.api import Model
from repro.models.layers import AxisCtx, greedy_token


def swap_headroom_bytes(*stream_chunk_bytes: int) -> int:
    """Admission swap margin, shared by every admission bound (eager and
    compiled engines inherit the same helper so they can never drift):
    with every tier packed exactly full no eviction can land anywhere
    and paging deadlocks (the cascade-cycle OutOfMemory), so each bound
    leaves room to swap the largest chunk among the streams it
    co-schedules."""
    if not stream_chunk_bytes:
        raise ValueError("at least one stream's chunk size is required")
    return max(int(b) for b in stream_chunk_bytes)


@dataclasses.dataclass
class ServeRequest:
    """One inference request's lifecycle through the admission queue."""

    rid: int
    prompt: np.ndarray  # [S] int32 token ids
    max_new_tokens: int
    state: str = "queued"  # queued -> active -> done
    pos: int = 0  # positions already written into the KV cache
    generated: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeRoundMetrics:
    """One continuous-batching round (admission + prefill + decode)."""

    round_index: int
    admitted: int
    completed: int
    active: int
    queued: int
    prefill_tokens: int
    decode_tokens: int
    h2d_bytes: int
    d2h_bytes: int
    hidden_h2d_bytes: int
    critical_h2d_bytes: int
    prefetch_hits: int
    demand_misses: int
    peak_device_bytes: int  # pool device high-water mark this round
    wall_s: float
    # transfer-timeline decomposition of the round's simulated time
    # (round == compute + h2d_stall + d2h_stall); None without a timeline
    timeline: StepTimeline | None = None

    @property
    def tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens


class ServingEngine:
    """Eager prefill/decode over the chunked heterogeneous memory pool."""

    def __init__(
        self,
        model_cls,
        cfg,
        *,
        device_memory_bytes: int | None = None,
        host_memory_bytes: int | None = None,
        slow_memory_bytes: int | None = None,
        pool: HeteroMemory | None = None,
        tenant: Tenant | None = None,
        policy: str = "opt",
        chunk_size: int | None = None,
        max_seq_len: int = 128,
        manage_kv: bool = True,
        page_tokens: int | None = None,
        prefetch: bool = True,
        prefetch_lookahead: int = 8,
        timeline: TransferTimeline | None = None,
        telemetry: Telemetry | None = None,
        bandwidth_aware_prefetch: bool = True,
        max_decode_batch: int | None = None,
        max_prefill_batch: int | None = None,
        seed: int = 0,
        init_params: Any | None = None,
    ) -> None:
        self.cfg = cfg
        self.ctx = AxisCtx()  # single device, no mesh axes
        self.model: Model = model_cls(cfg, self.ctx)
        self.max_seq_len = max_seq_len
        self.manage_kv = manage_kv
        if page_tokens is not None:
            page_tokens = int(page_tokens)
            if page_tokens < 1:
                raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
            if not manage_kv:
                raise ValueError(
                    "paged KV requires the managed kv stream (manage_kv=True);"
                    " the unmanaged baseline holds whole-horizon raw arrays")
        self._page_tokens = page_tokens
        # owned pool: capacities == tier caps (historical behavior).
        # shared pool (pool= + tenant=): capacities are this tenant's
        # planning SHARES — admission budgets against them while the pool
        # enforces only the physical tier caps.
        self._lease = acquire_pool(
            pool=pool, tenant=tenant,
            device_memory_bytes=device_memory_bytes,
            host_memory_bytes=host_memory_bytes,
            slow_memory_bytes=slow_memory_bytes,
            policy=policy, timeline=timeline)
        self.tenant = self._lease.tenant
        if self._lease.device_bytes is None:
            raise ValueError(
                "serving needs a device budget: pass device_memory_bytes= "
                "or give its tenant a device_budget_bytes soft budget")
        self.device_capacity = self._lease.device_bytes
        self.host_capacity = self._lease.host_bytes
        self.slow_capacity = self._lease.slow_bytes
        if cfg.arch_type in ("audio", "vlm"):
            raise ValueError(
                "ServingEngine serves token prompts; encoder-input archs "
                f"({cfg.arch_type}) need a modality front-end")
        self._decode_groups = [g for g in self.model.groups()
                               if g.decode is not None]
        if len(self._decode_groups) != len(self.model.groups()):
            raise ValueError("every block group must define decode/prefill "
                             "to serve with the chunk-managed engine")
        for g in self._decode_groups:
            if g.prefill is None or g.init_cache is None:
                raise ValueError(f"group {g.name} lacks prefill/init_cache")

        # ---- param chunk stream (read-only; stem stays host-side) -------
        params = init_params if init_params is not None \
            else self.model.init_params(jax.random.key(seed))
        self._stem_np = jax.tree.map(np.asarray, params["stem"])
        named: list[tuple[str, np.ndarray]] = []
        self._group_tensor_names: dict[str, list[list[str]]] = {}
        for g in self.model.groups():
            stacked = params["groups"][g.name]
            per_layer: list[list[str]] = []
            for i in range(g.length):
                layer_tree = jax.tree.map(lambda t: np.asarray(t[i]), stacked)
                pairs = _leaves_with_names(layer_tree, f"{g.name}.{i}")
                per_layer.append([n for n, _ in pairs])
                named.extend(pairs)
            self._group_tensor_names[g.name] = per_layer
        self._layer_trees = {
            g.name: jax.tree_util.tree_structure(
                jax.tree.map(lambda t: t[0], params["groups"][g.name]))
            for g in self.model.groups()
        }
        specs = [TensorSpec(n, tuple(v.shape)) for n, v in named]
        if chunk_size is None:
            chunk_size = search_chunk_size(specs, align=256).chunk_size
        self.cmap = build_chunk_map(specs, chunk_size)
        self.pool = self._lease.pool
        self.timeline = self._lease.timeline
        if telemetry is not None:
            self.pool.set_telemetry(telemetry)
        self.params_mgr = self._lease.stream("param", self.cmap)
        for name, val in named:
            view = self.params_mgr.access_tensor(name, "host")
            view[...] = np.asarray(val, np.float32)
            self.params_mgr.release_tensor(name, TensorState.HOLD)
        self._layer_chunks = {
            (g.name, i): sorted({self.cmap.placement(n).chunk_id
                                 for n in self._group_tensor_names[g.name][i]})
            for g in self.model.groups() for i in range(g.length)
        }
        self._param_stream_bytes = (
            self.cmap.num_payload_chunks * self.params_mgr.chunk_bytes)
        self._param_floor_bytes = max(
            len(c) for c in self._layer_chunks.values()
        ) * self.params_mgr.chunk_bytes

        # ---- KV layout: one (group, layer, page) cache per chunk --------
        # template = init_cache(1, max_seq_len) flattened; a chunk holds
        # the leaves concatenated (k then v for attention; any cache
        # pytree works — SSM states included).  Unpaged, one page spans
        # the horizon; paged, each chunk holds a page_tokens-wide slice
        # of every leaf along its position axis.
        self._cache_tmpl: dict[str, Any] = {}
        self._batchable: dict[str, bool] = {}
        self._page_axes: dict[str, list[int]] = {}
        max_numel = 1
        self._kv_seq_raw_bytes = 0  # actual (unaligned, true-dtype) bytes
        for g in self._decode_groups:
            one = g.init_cache(1, max_seq_len)
            leaves, treedef = jax.tree_util.tree_flatten(one)
            shapes = [tuple(l.shape) for l in leaves]
            dtypes = [l.dtype for l in leaves]
            numels = [int(np.prod(s)) for s in shapes]
            self._cache_tmpl[g.name] = (treedef, shapes, dtypes, numels)
            if page_tokens is None:
                max_numel = max(max_numel, sum(numels))
            else:
                # position axis per leaf: the one axis that grows by
                # exactly 1 when the cache is built for one more position.
                # Caches without such an axis on every leaf (position-
                # independent SSM-style state) cannot page.
                grown = [tuple(l.shape) for l in jax.tree_util.tree_leaves(
                    g.init_cache(1, max_seq_len + 1))]
                axes: list[int] = []
                for sa, sb in zip(shapes, grown):
                    diff = [ax for ax, (a, b) in enumerate(zip(sa, sb))
                            if a != b]
                    if (len(sa) != len(sb) or len(diff) != 1
                            or sb[diff[0]] - sa[diff[0]] != 1):
                        raise ValueError(
                            f"group {g.name} has a cache leaf without a "
                            f"clean position axis ({sa} vs {sb} for one "
                            f"extra position); this arch cannot serve "
                            f"with paged KV")
                    axes.append(diff[0])
                self._page_axes[g.name] = axes
                width = min(page_tokens, max_seq_len)
                page_numel = sum((n // s[ax]) * width
                                 for s, n, ax in zip(shapes, numels, axes))
                max_numel = max(max_numel, page_numel)
            # batched decode packs sequences along the cache's leading
            # axis; only safe when every leaf of the one-sequence template
            # leads with the batch dim (size 1).  Archs that stack other
            # axes first (e.g. zamba's per-unit mamba states) decode
            # sequence-at-a-time.
            self._batchable[g.name] = all(
                len(s) >= 1 and s[0] == 1 for s in shapes)
            self._kv_seq_raw_bytes += g.length * sum(
                n * np.dtype(d).itemsize for n, d in zip(numels, dtypes))
        if getattr(cfg, "n_experts", 0) > 1:
            # GShard expert capacity is f(round token count): packing
            # sequences into one MoE call can push an expert past the
            # capacity a solo pass would have had and drop a token —
            # batching would change tokens, the one thing it must never
            # do.  MoE archs therefore prefill/decode sequence-at-a-time
            # in the eager engine; the compiled round step vmaps
            # independent per-sequence lanes, so it batches *calls*
            # without ever batching routing.
            self._batchable = {k: False for k in self._batchable}
        self._kv_chunk_elems = build_kv_chunk_map(
            max_numel, page_tokens=page_tokens).chunk_size
        self.kv_chunk_bytes = self._kv_chunk_elems * 4  # fp32 payloads
        self._total_layers = sum(g.length for g in self._decode_groups)
        self._flat_layer: dict[tuple[str, int], int] = {}
        for g in self._decode_groups:
            for i in range(g.length):
                self._flat_layer[(g.name, i)] = len(self._flat_layer)
        # one sequence's whole managed KV footprint at the full horizon
        self._pages_per_seq = pages_for(max_seq_len, page_tokens)
        self.kv_seq_bytes = (self._pages_per_seq * self._total_layers
                             * self.kv_chunk_bytes)

        floor = self._param_floor_bytes + (
            self.kv_chunk_bytes + swap_headroom_bytes(self.kv_chunk_bytes)
            if manage_kv else 0)
        if self.device_capacity < floor:
            raise ValueError(
                f"device budget {self.device_capacity} below the serving "
                f"working-set floor {floor} (one layer's param chunks plus "
                f"two kv chunks)")

        self.kv_mgr: ChunkManager | None = None
        self._raw_kv: dict[tuple[int, str, int], Any] = {}
        self._raw_kv_bytes = 0
        if not manage_kv:
            # unmanaged caches are raw device arrays: reserve their bytes
            # out of the pool's chunkable device budget so params and raw
            # KV honestly share the same fixed device capacity.
            self.pool.set_chunkable_memory_fn(
                lambda: self.device_capacity - self._raw_kv_bytes,
                tenant=self.tenant, basis_bytes=self.device_capacity)
        self.prefetcher = self._lease.prefetcher(
            lookahead=prefetch_lookahead,
            bandwidth_aware=bandwidth_aware_prefetch) \
            if prefetch and manage_kv else None

        # batched decode: same-position active sequences pack into ONE
        # g.decode call per layer.  The cap bounds how many kv chunks sit
        # in COMPUTE (unevictable) at once beside the layer's params —
        # sized so the co-resident working set leaves one chunk of swap
        # headroom under the device budget.  The same cap applies to the
        # unmanaged baseline so both modes group (and therefore batch)
        # identically — chunk management must never change a token.
        if max_decode_batch is None:
            fit = (self.device_capacity - self._param_floor_bytes
                   - swap_headroom_bytes(self.kv_chunk_bytes)
                   ) // max(self.kv_chunk_bytes, 1)
            max_decode_batch = max(1, min(8, int(fit)))
        self.max_decode_batch = max(1, int(max_decode_batch))
        # batched prefill: an admission cohort (same prompt length) packs
        # into ONE g.prefill per layer.  Unlike batched decode, prefill
        # stores each sequence's kv chunk one at a time under the layer's
        # params, so the cap mirrors max_decode_batch for symmetry rather
        # than a budget fit.
        if max_prefill_batch is None:
            max_prefill_batch = self.max_decode_batch
        self.max_prefill_batch = max(1, int(max_prefill_batch))
        self._cost_cache: dict[int, Any] = {}

        self._queue: deque[ServeRequest] = deque()
        self._active: list[ServeRequest] = []
        self._req_pages: dict[int, int] = {}  # rid -> mapped pages/(g,layer)
        self._page_layout_cache: dict[tuple[str, int], list] = {}
        self._done: dict[int, ServeRequest] = {}
        self._next_rid = 0
        self._moment = 0
        self._planned: deque[tuple[int, tuple]] = deque()
        self.rounds = 0
        self.total_prefill_tokens = 0
        self.total_decode_tokens = 0
        self.peak_concurrency = 0

    # --------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue a request; returns its id.  The admission loop activates
        it once the pool can hold its KV alongside the current load."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # the last generated token is never fed back, so the cache holds
        # prompt + (max_new_tokens - 1) positions
        if prompt.size + max_new_tokens - 1 > self.max_seq_len:
            raise ValueError(
                f"prompt {prompt.size} + {max_new_tokens} new tokens "
                f"exceeds max_seq_len {self.max_seq_len}")
        probe = ServeRequest(rid=-1, prompt=prompt,
                             max_new_tokens=max_new_tokens)
        if not self._admissible(0, probe):
            raise ValueError(
                "request can never be admitted: one sequence's KV plus the "
                "param working set exceeds the configured budgets")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(ServeRequest(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens))
        return rid

    def _pages_for(self, positions: int) -> int:
        """Pages a sequence holding ``positions`` cache positions needs
        per (group, layer) — 1 always on an unpaged stream."""
        return pages_for(positions, self._page_tokens)

    def _kv_commit_bytes(self, req: ServeRequest) -> int:
        """One request's full-lifetime managed KV footprint: the pages
        that will exist at its final decode position (the last generated
        token is never fed back), per (group, layer).  Unpaged this is
        exactly the whole-horizon template ``kv_seq_bytes``; paged it is
        the request's TRUE page count — the admission win."""
        pages = self._pages_for(int(req.prompt.size) + req.max_new_tokens - 1)
        return pages * self._total_layers * self.kv_chunk_bytes

    def _admissible(self, n_active: int,
                    req: ServeRequest | None = None) -> bool:
        """Can the pool hold the param working set plus the running KV
        commitment and one more sequence's (``req``'s when given, the
        full-horizon template otherwise)?  Managed KV may spill to host
        (and further to the slow tier when one exists), so the bound is
        the total across every pool tier; unmanaged KV is device-resident
        raw arrays, so the device budget alone decides.  Paged streams
        reason in pages: each request commits only the chunks it will
        actually hold at its final position."""
        if self.manage_kv:
            if self.host_capacity is None:
                return True  # unbounded host tier
            headroom = swap_headroom_bytes(
                self.params_mgr.chunk_bytes, self.kv_chunk_bytes)
            active_kv = sum(self._kv_commit_bytes(r)
                            for r in self._active) if n_active else 0
            cand = (self._kv_commit_bytes(req) if req is not None
                    else self.kv_seq_bytes)
            need = self._param_stream_bytes + headroom + active_kv + cand
            total = (self.device_capacity + self.host_capacity
                     + (self.slow_capacity or 0))
            return need <= total
        need = (self._param_floor_bytes
                + (n_active + 1) * self._kv_seq_raw_bytes)
        return need <= self.device_capacity

    def _admit(self) -> list[ServeRequest]:
        newly: list[ServeRequest] = []
        while self._queue and self._admissible(len(self._active),
                                               self._queue[0]):
            req = self._queue.popleft()
            req.state = "active"
            if self.manage_kv:
                self._ensure_kv_stream()
                self._map_request_kv(req)
            else:
                self._raw_kv_bytes += self._kv_seq_raw_bytes
            self._active.append(req)
            newly.append(req)
        self.peak_concurrency = max(self.peak_concurrency, len(self._active))
        return newly

    def _map_request_kv(self, req: ServeRequest) -> None:
        """Map one admitted request's kv pages: enough pages per (group,
        layer) to cover the prompt; decode appends further pages as the
        position crosses page boundaries (:meth:`_ensure_pages`).  The
        compiled engine overrides this to bind the request's pages to its
        padded batch slot's fixed chunk-id range."""
        pages = self._pages_for(int(req.prompt.size))
        self._req_pages[req.rid] = pages
        for g in self._decode_groups:
            for i in range(g.length):
                for p in range(pages):
                    self._map_page(req.rid, g.name, i, p)

    def _map_page(self, rid: int, gname: str, layer: int, page: int) -> None:
        """Map a single kv page chunk (the compiled engine overrides this
        to pin the page into its slot's reserved id range)."""
        self.kv_mgr.add_tensor(
            self._kv_name(rid, gname, layer, page), (self._kv_chunk_elems,))

    def _ensure_pages(self, req: ServeRequest) -> None:
        """Decode writes position ``req.pos`` this round: append page
        chunks (zero-filled on first access, like any fresh cache)
        whenever the write crosses a page boundary.  Unpaged streams
        always hold exactly one page, so this is a no-op for them."""
        if not self.manage_kv:
            return
        need = self._pages_for(req.pos + 1)
        have = self._req_pages[req.rid]
        if need <= have:
            return
        for g in self._decode_groups:
            for i in range(g.length):
                for p in range(have, need):
                    self._map_page(req.rid, g.name, i, p)
        self._req_pages[req.rid] = need

    def _ensure_kv_stream(self) -> None:
        """(Re)register the kv stream — dropped whenever the engine fully
        drains, so admission after a drain exercises the same
        unregister/re-register path as the act stream's batch-shape
        rebuild."""
        if self.kv_mgr is None:
            self.kv_mgr = self._lease.stream(
                "kv", build_kv_chunk_map(self._kv_chunk_elems,
                                         page_tokens=self._page_tokens))

    @staticmethod
    def _kv_name(rid: int, gname: str, layer: int, page: int = 0) -> str:
        return f"kv.{rid}.{gname}.{layer}.{page}"

    # ------------------------------------------------------------- schedule
    def _prefill_batchable(self) -> bool:
        """Whether admission cohorts may pack >1 sequence into one
        ``g.prefill`` call.  The eager engine needs every cache leaf to
        lead with the batch dim so per-sequence caches can be sliced back
        out; the compiled round step prefills lanes under ``vmap`` and
        lifts this restriction."""
        return all(self._batchable.values())

    def _prefill_cohorts(self, newly) -> list[list[ServeRequest]]:
        """Pack newly admitted requests into prefill cohorts: same prompt
        length (one compiled/batched call shape), admission order inside
        a length class (stable sort), capped at ``max_prefill_batch``."""
        cap = self.max_prefill_batch if self._prefill_batchable() else 1
        cohorts: list[list[ServeRequest]] = []
        for req in sorted(newly, key=lambda r: int(r.prompt.size)):
            if (cohorts and cohorts[-1][0].prompt.size == req.prompt.size
                    and len(cohorts[-1]) < cap):
                cohorts[-1].append(req)
            else:
                cohorts.append([req])
        return cohorts

    def _round_ops(self, cohorts, decode_reqs) -> list[tuple[tuple, float]]:
        """The round's exact op order: per admission cohort a layer-major
        prefill pass (one param fetch per layer per cohort, each member's
        kv store under it), then one layer-major decode sweep over the
        running set (params fetched once per layer per round, every
        active sequence's kv chunk visited under that fetch — the decode
        round-robin).

        Returns ``(op, compute_seconds)`` pairs — durations are generated
        alongside the ops so the transfer timeline's per-moment schedule
        can never drift from the execution order.  A prefill param op
        carries the layer's prefill compute over the cohort's prompts;
        decode compute rides each sequence's tail-page kv op (or the
        param op itself when KV is unmanaged).  Paged sequences emit one
        kv op per mapped page — the plan IS the partial-spill policy:
        every page is referenced in visit order, cold pages released as
        soon as they are copied out."""
        ops: list[tuple[tuple, float]] = []
        for cohort in cohorts:
            pre = self._serve_costs(
                int(cohort[0].prompt.size)).prefill_layer_s * len(cohort)
            for g in self._decode_groups:
                for i in range(g.length):
                    ops.append((("param", g.name, i), pre))
                    if self.manage_kv:
                        for req in cohort:
                            for p in range(self._req_pages[req.rid]):
                                ops.append(
                                    (("kv", req.rid, g.name, i, p), 0.0))
        if decode_reqs:
            dec = self._serve_costs(1).decode_layer_s
            for g in self._decode_groups:
                for i in range(g.length):
                    ops.append((("param", g.name, i),
                                0.0 if self.manage_kv
                                else dec * len(decode_reqs)))
                    if self.manage_kv:
                        for req in decode_reqs:
                            pages = self._req_pages[req.rid]
                            for p in range(pages):
                                ops.append((("kv", req.rid, g.name, i, p),
                                            dec if p == pages - 1 else 0.0))
        return ops

    def _serve_costs(self, prompt_tokens: int):
        """Per-layer analytical durations (cached by prompt length)."""
        from repro.analysis.costmodel import serve_operator_costs

        key = int(prompt_tokens)
        c = self._cost_cache.get(key)
        if c is None:
            c = serve_operator_costs(
                self.cfg, prompt_tokens=key, horizon=self.max_seq_len,
                num_layers=self._total_layers)
            self._cost_cache[key] = c
        return c

    def _plan_round(self, cohorts, decode_reqs) -> None:
        """Register this round's reference schedule (plus a synthetic
        next round) as the OPT eviction future and the prefetcher's
        staging queue — the serving analogue of the tracer's warm-up
        schedule, re-derived every round because the active set is
        dynamic."""
        newly = [r for c in cohorts for r in c]
        ops = self._round_ops(cohorts, decode_reqs)
        survivors = [r for r in decode_reqs + newly
                     if len(r.generated) + 1 < r.max_new_tokens]
        future = self._round_ops([], survivors or (decode_reqs + newly))
        param_sched: dict[int, list[int]] = {}
        kv_sched: dict[int, list[int]] = {}
        refs: list[tuple[int, str, int]] = []
        self._planned.clear()
        m = self._moment
        for k, (op, _dur) in enumerate(ops + future):
            if op[0] == "param":
                for cid in self._layer_chunks[(op[1], op[2])]:
                    param_sched.setdefault(cid, []).append(m + k)
                    refs.append((m + k, self.params_mgr.name, cid))
            else:
                cid = self.kv_mgr.cmap.placement(
                    self._kv_name(op[1], op[2], op[3], op[4])).chunk_id
                kv_sched.setdefault(cid, []).append(m + k)
                refs.append((m + k, self.kv_mgr.name, cid))
            if k < len(ops):
                self._planned.append((m + k, op))
        self._moment = m + len(ops) + len(future)
        self.pool.register_moments(self.params_mgr.name, param_sched)
        if self.kv_mgr is not None:
            self.pool.register_moments(self.kv_mgr.name, kv_sched)
        if self.prefetcher is not None:
            self.prefetcher.install(refs)
        if self.pool.timeline is not None:
            # serving moments grow forever: drop already-flushed rounds,
            # then install this round's per-op compute durations (the
            # synthetic future never executes, so it carries none)
            ns = self.tenant.timeline_ns
            self.pool.timeline.prune_durations_before(m, tenant=ns)
            self.pool.timeline.extend_durations(
                {m + k: d for k, (_op, d) in enumerate(ops) if d > 0.0},
                tenant=ns)

    def _begin_op(self, op: tuple) -> None:
        """Advance the moment cursor to the next planned op (asserting the
        executor walks exactly the planned order) and stage upcoming
        references ahead of it."""
        m, planned = self._planned.popleft()
        assert planned == op, (planned, op)
        self.tenant.set_moment(m)
        tel = self.pool.telemetry
        if tel is not None:
            tel.switch_span(self.tenant.qualify("ops"),
                            " ".join(str(x) for x in op),
                            ts=self.pool._now(), moment=m,
                            tenant=self.tenant.name,
                            rank=self.pool.telemetry_rank)
        if self.prefetcher is not None:
            self.prefetcher.advance(m)

    # -------------------------------------------------------- cache chunks
    def _pad_to_tmpl(self, arr: np.ndarray, tshape: tuple[int, ...]) -> np.ndarray:
        if tuple(arr.shape) == tshape:
            return arr
        pads = []
        for a, b in zip(arr.shape, tshape):
            if b < a:
                raise ValueError(f"cache leaf {arr.shape} exceeds template "
                                 f"{tshape}")
            pads.append((0, b - a))
        return np.pad(arr, pads)

    def _page_layout(self, gname: str, page: int):
        """Per-leaf layout of one page chunk: ``(slice_tuple, local_shape,
        offset, numel)`` where ``slice_tuple`` cuts the page's position
        window out of the full-horizon template leaf and ``offset``/
        ``numel`` locate its flattened payload inside the chunk.  Unpaged
        (page 0 spans the horizon) this degenerates to the whole-chunk
        concatenation layout."""
        key = (gname, page)
        out = self._page_layout_cache.get(key)
        if out is not None:
            return out
        _, shapes, _, numels = self._cache_tmpl[gname]
        out = []
        off = 0
        if self._page_tokens is None:
            for s, n in zip(shapes, numels):
                out.append((tuple(slice(None) for _ in s), s, off, n))
                off += n
        else:
            lo = page * self._page_tokens
            hi = min(lo + self._page_tokens, self.max_seq_len)
            for s, ax in zip(shapes, self._page_axes[gname]):
                local = tuple(hi - lo if j == ax else d
                              for j, d in enumerate(s))
                sl = tuple(slice(lo, hi) if j == ax else slice(None)
                           for j in range(len(s)))
                n = int(np.prod(local))
                out.append((sl, local, off, n))
                off += n
        self._page_layout_cache[key] = out
        return out

    def _store_prefill_cache(self, rid: int, gname: str, layer: int,
                             cache) -> None:
        """Write a freshly prefilled layer cache into the request's page
        chunks — one planned op per page; the FREE access zero-fills,
        then prefill leaves are padded to the decode-horizon template so
        every page slices cleanly, matching the layout decode expects."""
        _, shapes, _, _ = self._cache_tmpl[gname]
        leaves = [self._pad_to_tmpl(np.asarray(l, np.float32), ts)
                  for l, ts in zip(jax.tree_util.tree_leaves(cache), shapes)]
        for p in range(self._req_pages[rid]):
            self._begin_op(("kv", rid, gname, layer, p))
            name = self._kv_name(rid, gname, layer, p)
            view = self.kv_mgr.access_tensor(name, "device")
            for leaf, (sl, _local, off, n) in zip(
                    leaves, self._page_layout(gname, p)):
                view[off:off + n] = leaf[sl].reshape(-1)
            self.kv_mgr.release_tensor(name, TensorState.HOLD)

    def _store_decode_cache(self, rid: int, gname: str, layer: int,
                            cache) -> None:
        """Write back after a decode step.  Decode writes exactly one new
        position, which by construction lives on the tail page — so only
        the tail (still COMPUTE from the load) is rewritten; cold pages
        were already released and may have spilled meanwhile."""
        tail = self._req_pages[rid] - 1
        name = self._kv_name(rid, gname, layer, tail)
        if self.kv_mgr.tensor_state(name) is TensorState.COMPUTE:
            view = self.kv_mgr.tensor_view(name)
        else:
            view = self.kv_mgr.access_tensor(name, "device")
        _, shapes, _, _ = self._cache_tmpl[gname]
        leaves = jax.tree_util.tree_leaves(cache)
        for leaf, tshape, (sl, _local, off, n) in zip(
                leaves, shapes, self._page_layout(gname, tail)):
            arr = self._pad_to_tmpl(np.asarray(leaf, np.float32), tshape)
            view[off:off + n] = arr[sl].reshape(-1)
        self.kv_mgr.release_tensor(name, TensorState.HOLD)

    def _load_cache(self, rid: int, gname: str, layer: int):
        """Visit the request's page chunks in order and rebuild the
        full-horizon layer cache pytree.  Cold (non-tail) pages are
        COPIED out and released HOLD immediately — evictable again before
        the decode op even runs — while the hot tail page stays COMPUTE
        for the in-place write-back.  This is the partial-spill policy:
        the device-pinned working set is one page per (sequence, layer),
        never the whole horizon."""
        treedef, shapes, dtypes, numels = self._cache_tmpl[gname]
        pages = self._req_pages[rid]
        if pages == 1:
            # single page spans the horizon: the historical whole-chunk
            # path (no intermediate full-buffer assembly)
            self._begin_op(("kv", rid, gname, layer, 0))
            view = self.kv_mgr.access_tensor(
                self._kv_name(rid, gname, layer, 0), "device")
            leaves = []
            off = 0
            for shape, dtype, n in zip(shapes, dtypes, numels):
                leaves.append(jnp.asarray(
                    np.array(view[off:off + n], copy=True).reshape(shape)
                ).astype(dtype))
                off += n
            return jax.tree_util.tree_unflatten(treedef, leaves)
        fulls = [np.zeros(s, np.float32) for s in shapes]
        for p in range(pages):
            self._begin_op(("kv", rid, gname, layer, p))
            name = self._kv_name(rid, gname, layer, p)
            view = self.kv_mgr.access_tensor(name, "device")
            for full, (sl, local, off, n) in zip(
                    fulls, self._page_layout(gname, p)):
                full[sl] = view[off:off + n].reshape(local)
            if p < pages - 1:
                self.kv_mgr.release_tensor(name, TensorState.HOLD)
        # positions beyond the mapped pages stay zero — exactly the
        # zero-filled bytes an unpaged chunk would hold there
        leaves = [jnp.asarray(f).astype(dt)
                  for f, dt in zip(fulls, dtypes)]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def _raw_cache(self, rid: int, gname: str, layer: int):
        key = (rid, gname, layer)
        if self._raw_kv.get(key) is None:
            g = next(g for g in self._decode_groups if g.name == gname)
            self._raw_kv[key] = g.init_cache(1, self.max_seq_len)
        return self._raw_kv[key]

    def _raw_store(self, rid: int, gname: str, layer: int, cache) -> None:
        _, shapes, dtypes, _ = self._cache_tmpl[gname]
        treedef = self._cache_tmpl[gname][0]
        leaves = [
            jnp.asarray(self._pad_to_tmpl(np.asarray(l), ts)).astype(dt)
            for l, ts, dt in zip(jax.tree_util.tree_leaves(cache), shapes,
                                 dtypes)]
        self._raw_kv[(rid, gname, layer)] = jax.tree_util.tree_unflatten(
            treedef, leaves)

    # ------------------------------------------------------------ layer ops
    def _access_layer(self, gname: str, layer: int):
        names = self._group_tensor_names[gname][layer]
        # COPY at the numpy->jax boundary: the payload may be evicted (and
        # its buffer reused by a later admission) while lazy jax values
        # still reference it.
        arrs = [jnp.array(self.params_mgr.access_tensor(n, "device"),
                          copy=True) for n in names]
        tree = jax.tree_util.tree_unflatten(self._layer_trees[gname], arrs)
        return names, tree

    def _release_layer(self, names) -> None:
        for n in names:
            self.params_mgr.release_tensor(n, TensorState.HOLD)

    # ------------------------------------------------------------- phases
    def _prefill_cohort(self, cohort: list[ServeRequest], stem) -> None:
        """Prefill one admission cohort in a single layer-major pass:
        the cohort's prompts run as ONE batch through ``g.prefill`` (one
        param fetch per layer per cohort), then each member's cache rows
        are sliced back out and stored into its kv chunks.  A cohort of
        one is byte-identical to the old per-request prefill pass."""
        k = len(cohort)
        batch = {"tokens": jnp.asarray(
            np.stack([r.prompt for r in cohort], axis=0))}
        x, extras = self.model.embed(stem, batch)
        for g in self._decode_groups:
            x, extras = self.model.between_groups(
                g.name, x, extras, stem, batch)
            for i in range(g.length):
                self._begin_op(("param", g.name, i))
                names, ptree = self._access_layer(g.name, i)
                x, cache = g.prefill(ptree, x, extras, self.ctx)
                self._release_layer(names)
                for j, req in enumerate(cohort):
                    cj = cache if k == 1 else jax.tree.map(
                        lambda t, _j=j: t[_j:_j + 1], cache)
                    if self.manage_kv:
                        self._store_prefill_cache(req.rid, g.name, i, cj)
                    else:
                        self._raw_store(req.rid, g.name, i, cj)
        logits = self.model.head_logits(stem, x[:, -1:, :])
        toks = greedy_token(logits, self.cfg.vocab_size, self.ctx)
        for j, req in enumerate(cohort):
            req.pos = int(req.prompt.size)
            req.generated.append(int(toks[j]))
            self.total_prefill_tokens += int(req.prompt.size)

    def _decode_batches(self, decode_reqs) -> list[list[ServeRequest]]:
        """Pack the running set into decode batches: consecutive
        same-position sequences (one shared cache position per ``decode``
        call) in admission order, capped at ``max_decode_batch`` so the
        batch's COMPUTE-pinned kv chunks plus the layer's params stay
        within the device budget."""
        batches: list[list[ServeRequest]] = []
        # stable sort brings every same-position sequence together while
        # keeping admission order inside a position cohort (deterministic,
        # and identical between managed and unmanaged KV)
        for req in sorted(decode_reqs, key=lambda r: r.pos):
            if (batches and batches[-1][0].pos == req.pos
                    and len(batches[-1]) < self.max_decode_batch):
                batches[-1].append(req)
            else:
                batches.append([req])
        return batches

    def _decode_round(self, batches, stem) -> None:
        """One layer-major decode sweep: params fetched once per layer
        per round; same-position sequences decode as ONE batched
        ``g.decode`` call (their kv chunks co-resident for its duration),
        token-for-token identical to the sequence-at-a-time path."""
        decode_reqs = [r for b in batches for r in b]
        xs: dict[int, list] = {}
        for req in decode_reqs:
            tok = jnp.asarray([[req.generated[-1]]], jnp.int32)
            x = self.model.embed_decode(stem, tok, jnp.int32(req.pos), None)
            xs[req.rid] = [x, self.model.decode_extras(stem, x)]
        for g in self._decode_groups:
            for i in range(g.length):
                self._begin_op(("param", g.name, i))
                names, ptree = self._access_layer(g.name, i)
                for batch in batches:
                    # batched execution requires every leaf of x/cache to
                    # lead with the batch dim AND per-request extras to be
                    # None: a non-None extras tree can mix shared weights
                    # with batch-dependent leaves (zamba's {shared_attn,
                    # x0}), and both concatenating and recomputing it
                    # diverge from the compiled path's embed-time extras.
                    batched = (len(batch) > 1 and self._batchable[g.name]
                               and all(xs[r.rid][1] is None for r in batch))
                    if not batched:
                        # sequence-at-a-time: load/decode/store per
                        # request, one kv chunk COMPUTE-pinned at a time
                        for req in batch:
                            if self.manage_kv:
                                cache = self._load_cache(req.rid, g.name, i)
                            else:
                                cache = self._raw_cache(req.rid, g.name, i)
                            st = xs[req.rid]
                            y, c2 = g.decode(ptree, st[0], cache,
                                             jnp.int32(req.pos), st[1],
                                             self.ctx)
                            if self.manage_kv:
                                self._store_decode_cache(
                                    req.rid, g.name, i, c2)
                            else:
                                self._raw_kv[(req.rid, g.name, i)] = c2
                            st[0] = y
                        continue
                    caches = []
                    for req in batch:
                        if self.manage_kv:
                            caches.append(self._load_cache(req.rid, g.name, i))
                        else:
                            caches.append(self._raw_cache(req.rid, g.name, i))
                    xcat = jnp.concatenate(
                        [xs[r.rid][0] for r in batch], axis=0)
                    ccat = jax.tree.map(
                        lambda *ls: jnp.concatenate(ls, axis=0), *caches)
                    y, c2 = g.decode(ptree, xcat, ccat,
                                     jnp.int32(batch[0].pos), None, self.ctx)
                    for j, req in enumerate(batch):
                        cj = jax.tree.map(lambda t, _j=j: t[_j:_j + 1], c2)
                        if self.manage_kv:
                            self._store_decode_cache(req.rid, g.name, i, cj)
                        else:
                            self._raw_kv[(req.rid, g.name, i)] = cj
                        xs[req.rid][0] = y[j:j + 1]
                self._release_layer(names)
        for req in decode_reqs:
            logits = self.model.head_logits(stem, xs[req.rid][0])
            tok = int(greedy_token(logits, self.cfg.vocab_size, self.ctx)[0])
            req.pos += 1
            req.generated.append(tok)
            self.total_decode_tokens += 1

    def _retire_finished(self) -> int:
        done = [r for r in self._active
                if len(r.generated) >= r.max_new_tokens]
        for req in done:
            req.state = "done"
            self._active.remove(req)
            self._done[req.rid] = req
            if self.manage_kv:
                pages = self._req_pages.pop(req.rid)
                for g in self._decode_groups:
                    for i in range(g.length):
                        for p in range(pages):
                            self.kv_mgr.remove_tensor(
                                self._kv_name(req.rid, g.name, i, p))
            else:
                for g in self._decode_groups:
                    for i in range(g.length):
                        self._raw_kv.pop((req.rid, g.name, i), None)
                self._raw_kv_bytes -= self._kv_seq_raw_bytes
        if not self._active and not self._queue and self.kv_mgr is not None:
            # full drain: drop the kv stream; the next admission
            # re-registers it from scratch
            self.pool.unregister_stream(self.kv_mgr.name)
            self.kv_mgr = None
        return len(done)

    # ------------------------------------------------------------------ run
    def step_round(self) -> ServeRoundMetrics | None:
        """One continuous-batching round: admit, prefill the newly
        admitted, decode one token for everyone else, retire finished
        sequences.  Returns None when there is nothing to do."""
        if not self._queue and not self._active:
            return None
        t0 = time.perf_counter()
        tel = self.pool.telemetry
        if tel is not None:
            tel.begin_span(self.tenant.qualify("round"),
                           f"round{self.rounds}", ts=self.pool._now(),
                           tenant=self.tenant.name,
                           rank=self.pool.telemetry_rank)
        st0, pf0 = self.tenant.snapshot()
        prefill0 = self.total_prefill_tokens
        decode0 = self.total_decode_tokens
        newly = self._admit()
        newly_ids = {r.rid for r in newly}
        # group admissions into prefill cohorts and the running set into
        # decode batches FIRST: the plan's reference order must equal the
        # execution (load) order
        cohorts = self._prefill_cohorts(newly)
        batches = self._decode_batches(
            [r for r in self._active if r.rid not in newly_ids])
        decode_reqs = [r for b in batches for r in b]
        # page append happens BEFORE planning: the plan references every
        # page the round will touch, including ones decode creates by
        # writing across a page boundary this round
        for req in decode_reqs:
            self._ensure_pages(req)
        self._plan_round(cohorts, decode_reqs)
        self._execute_round(cohorts, batches)
        completed = self._retire_finished()
        self.rounds += 1
        pf = self.tenant.prefetch
        # close the round on the timeline FIRST: the drain stalls booked
        # inside take_step belong before the round span's end timestamp
        tl_step = (self.pool.timeline.take_step()
                   if self.pool.timeline is not None else None)
        met = ServeRoundMetrics(
            round_index=self.rounds - 1,
            admitted=len(newly),
            completed=completed,
            active=len(self._active),
            queued=len(self._queue),
            prefill_tokens=self.total_prefill_tokens - prefill0,
            decode_tokens=self.total_decode_tokens - decode0,
            h2d_bytes=self.tenant.stats.h2d_bytes - st0.h2d_bytes,
            d2h_bytes=self.tenant.stats.d2h_bytes - st0.d2h_bytes,
            hidden_h2d_bytes=pf.hidden_h2d_bytes - pf0.hidden_h2d_bytes,
            critical_h2d_bytes=pf.critical_h2d_bytes - pf0.critical_h2d_bytes,
            prefetch_hits=pf.hits - pf0.hits,
            demand_misses=pf.demand_misses - pf0.demand_misses,
            peak_device_bytes=self.tenant.take_step_peak_device_bytes(),
            wall_s=time.perf_counter() - t0,
            timeline=tl_step,
        )
        tel = self.pool.telemetry
        if tel is not None:
            ts = self.pool._now()
            rank = self.pool.telemetry_rank
            tel.close_span(self.tenant.qualify("ops"), ts=ts, rank=rank)
            tel.close_span(self.tenant.qualify("round"), ts=ts, rank=rank)
            tel.snapshot(
                f"{self.tenant.name}:round{met.round_index}", ts=ts,
                rank=rank, admitted=met.admitted, completed=met.completed,
                active=met.active, queued=met.queued,
                prefill_tokens=met.prefill_tokens,
                decode_tokens=met.decode_tokens,
                h2d_bytes=met.h2d_bytes, d2h_bytes=met.d2h_bytes,
                hidden_h2d_bytes=met.hidden_h2d_bytes,
                critical_h2d_bytes=met.critical_h2d_bytes,
                prefetch_hits=met.prefetch_hits,
                demand_misses=met.demand_misses,
                peak_device_bytes=met.peak_device_bytes)
        return met

    def _execute_round(self, cohorts, batches) -> None:
        """Run one planned round eagerly: per-cohort prefill passes, then
        the layer-major decode sweep.  The compiled engine overrides this
        with jitted round steps over padded slots (same plan, same pool
        accounting, compiled compute)."""
        stem = jax.tree.map(jnp.asarray, self._stem_np)
        for cohort in cohorts:
            self._prefill_cohort(cohort, stem)
        if batches:
            self._decode_round(batches, stem)

    def run(self, max_rounds: int = 10_000) -> list[ServeRoundMetrics]:
        """Round until every submitted request has completed."""
        out: list[ServeRoundMetrics] = []
        while self._queue or self._active:
            if len(out) >= max_rounds:
                raise RuntimeError(
                    f"serving did not drain within {max_rounds} rounds "
                    f"({len(self._active)} active, {len(self._queue)} queued)")
            m = self.step_round()
            assert m is not None
            out.append(m)
        return out

    # ------------------------------------------------------------- results
    def result(self, rid: int) -> list[int]:
        """Generated token ids of a completed request."""
        return list(self._done[rid].generated)

    @property
    def active_count(self) -> int:
        return len(self._active)

    @property
    def queued_count(self) -> int:
        return len(self._queue)

    def device_bytes_in_use(self) -> int:
        """This tenant's device bytes plus (unmanaged) raw KV
        reservations — the quantity that must stay within the fixed
        device capacity (identical to the pool total on an owned
        pool)."""
        return self.tenant.device_bytes_used() + self._raw_kv_bytes

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        if self.kv_mgr is not None:
            expect = sum(self._req_pages[r.rid]
                         for r in self._active) * self._total_layers
            assert self.kv_mgr.cmap.num_payload_chunks == expect, (
                self.kv_mgr.cmap.num_payload_chunks, expect)
        if self.tenant.is_default:
            # on a shared pool the device share is a SOFT budget (the
            # overflow region may absorb transients); the pool's own
            # check bounds the physical tiers
            assert self.device_bytes_in_use() <= self.device_capacity, (
                self.device_bytes_in_use(), self.device_capacity)
