"""Rank-parallel eager memory plane — chunked ZeRO in the PatrickStar
runtime (paper Section 7, Figs. 8/9, Algorithms 1-2).

:class:`DistributedPatrickStarEngine` simulates ``nproc`` ranks
in-process.  Each rank is a full :class:`~repro.core.engine.PatrickStarEngine`
(its own :class:`~repro.core.memory.HeteroMemory` device/host budget, its
own tracer/prefetcher/placement) that owns chunk ``g*p + r`` of every
communication group:

  * **init**: a rank materializes param fp16 + the three optimizer-state
    streams only for its owned chunks; every non-owned chunk starts in
    the RELEASED remote lifecycle (no local payload).
  * **FWD/BWD fetch** (Algorithm 1): the first COMPUTE access to a
    RELEASED chunk all-gathers its whole communication group — every
    rank pins its own chunk on-device and materializes the other p-1
    replicas, booking ``(p-1) * chunk_bytes`` received per rank in the
    pool's :class:`~repro.core.memory.CollectiveStats`.  After the
    group's post-FWD transition the remote replicas are dropped back to
    RELEASED (local bookkeeping inside the rank core).
  * **grad reduce-scatter** (Algorithm 2 + Fig. 6): grads overwrite the
    param-fp16 replicas on every rank; when a group reaches
    HOLD_AFTER_BWD everywhere, the driver sums the p replicas onto the
    owner's payload, releases the others, and books
    ``(p-1) * chunk_bytes`` sent per rank.
  * **ADAM** runs purely on local shards (each rank updates only its
    owned chunks; the stem stays replicated and its grads all-reduce —
    counted separately, outside the chunked plane).
  * **activations are rank-local**: each rank core owns its own act
    stream (the fifth managed stream) over its batch shard's
    checkpointed layer inputs — act chunks never appear in communication
    groups, are never gathered or reduced, and spill/restage purely
    through the rank's own H2D/D2H plane.
  * **gather prefetch**: after warm-up, rank 0's tracer schedule drives a
    :class:`~repro.core.memory.GatherPrefetcher` that issues upcoming
    FWD/BWD group gathers ahead of their operator, classifying those
    collective bytes hidden instead of critical-path — the collective
    analogue of the H2D staging queue.

Ranks advance in lock-step at layer granularity (the driver interleaves
the engine's phase methods), which is what makes the simulated
collectives well-defined: when a gather or reduce-scatter fires, every
rank is at the same point of the same schedule.  Per-rank measured
volume is exactly the paper's analytic ``3 (p-1)/p`` of the chunk-store
bytes per step — two all-gather passes plus one reduce-scatter, padding
chunks included, matching a tiled ``lax.all_gather`` over the
``[G, p, S]`` store of the compiled path (asserted in
tests/test_distributed_engine.py and benchmarks/comm_volume.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.core.engine import EngineMetrics, PatrickStarEngine
from repro.core.memory import CollectiveStats, GatherPrefetcher
from repro.core.state import ChunkState


@dataclasses.dataclass
class DistributedStepMetrics:
    """One lock-step iteration across all ranks.  Collective byte counts
    are PER RANK (they are symmetric by construction — every rank sends
    and receives the same chunk count per group)."""

    loss: float  # global loss: sum of per-shard losses (1/global_tokens)
    rank_metrics: list[EngineMetrics]
    allgather_bytes: int = 0
    reduce_scatter_bytes: int = 0
    allreduce_bytes: int = 0
    hidden_allgather_bytes: int = 0
    critical_allgather_bytes: int = 0

    @property
    def chunk_collective_bytes(self) -> int:
        """The quantity the paper's 6(p-1)/p*M model predicts."""
        return self.allgather_bytes + self.reduce_scatter_bytes

    @property
    def moved_bytes(self) -> int:
        """Per-step H2D+D2H over all ranks (the offload plane)."""
        return sum(m.moved_bytes for m in self.rank_metrics)


class DistributedPatrickStarEngine:
    """nproc-rank chunked-ZeRO driver over per-rank PatrickStar cores."""

    def __init__(
        self,
        model_cls,
        cfg,
        *,
        nproc: int,
        device_memory_bytes: int,  # PER-RANK device budget
        host_memory_bytes: int | None = None,
        slow_memory_bytes: int | None = None,
        policy: str = "opt",
        chunk_size: int | None = None,
        lr: float = 1e-3,
        betas: tuple[float, float] = (0.9, 0.95),
        eps: float = 1e-8,
        seed: int = 0,
        device_aware_placement: bool = True,
        prefetch: bool = True,
        prefetch_lookahead: int = 6,
        gather_lookahead: int = 2,
        timeline_factory: "Callable[[], Any] | None" = None,
        telemetry: "Any | None" = None,
        bandwidth_aware_prefetch: bool = True,
        manage_activations: bool = True,
        strict_device_budget: bool = False,
        pools: "list | None" = None,
        tenants: "list | None" = None,
    ) -> None:
        if nproc < 2:
            raise ValueError("nproc must be >= 2 (use PatrickStarEngine)")
        # co-tenancy: one shared pool (+ tenant handle) PER RANK — each
        # simulated rank owns its own device, so a co-resident serving
        # fleet shares memory rank-to-rank, never across ranks
        for arg, label in ((pools, "pools"), (tenants, "tenants")):
            if arg is not None and len(arg) != nproc:
                raise ValueError(f"{label}= needs one entry per rank "
                                 f"({len(arg)} != nproc {nproc})")
        self.nproc = nproc
        # ONE init for all ranks (the paper's replicated init — every rank
        # derives the same values, so initializing nproc times would only
        # burn time and transient memory; each core copies what it owns
        # into its chunk payloads).  Rank 0 also runs the chunk-size
        # search once; the others reuse its layout.
        from repro.models.layers import AxisCtx

        init_params = model_cls(cfg, AxisCtx()).init_params(
            jax.random.key(seed))

        def make_core(r, csize):
            return PatrickStarEngine(
                model_cls, cfg,
                device_memory_bytes=device_memory_bytes,
                host_memory_bytes=host_memory_bytes,
                slow_memory_bytes=slow_memory_bytes,
                pool=pools[r] if pools is not None else None,
                tenant=tenants[r] if tenants is not None else None,
                policy=policy, chunk_size=csize,
                lr=lr, betas=betas, eps=eps, seed=seed,
                device_aware_placement=device_aware_placement,
                prefetch=prefetch, prefetch_lookahead=prefetch_lookahead,
                timeline=timeline_factory() if timeline_factory else None,
                bandwidth_aware_prefetch=bandwidth_aware_prefetch,
                manage_activations=manage_activations,
                strict_device_budget=strict_device_budget,
                nproc=nproc, rank=r, collective=self,
                init_params=init_params)

        rank0 = make_core(0, chunk_size)
        self.ranks = [rank0] + [
            make_core(r, rank0.cmap.chunk_size) for r in range(1, nproc)]
        # rank-tag each core's telemetry (explicit hub or the default one
        # its pool picked up) so every event and trace track names its
        # rank; a shared hub merges all ranks into one trace.
        for r, core in enumerate(self.ranks):
            tel = telemetry if telemetry is not None else core.pool.telemetry
            if tel is not None:
                core.pool.set_telemetry(tel, rank=r)
        self.cmap = rank0.cmap
        if any(c.cmap != self.cmap for c in self.ranks[1:]):
            raise AssertionError("rank cores disagree on the chunk layout")
        # the gather prefetcher projects against rank 0's timeline (lock-
        # step execution keeps every rank's clock identical); a staged
        # gather moves (p-1) chunks onto every rank's collective lane.
        gp_timeline = rank0.timeline if bandwidth_aware_prefetch else None
        self.gather_prefetcher = GatherPrefetcher(
            lambda grp: self.fetch_group(grp, hidden=True),
            lookahead=gather_lookahead,
            timeline=gp_timeline,
            group_bytes=(nproc - 1) * rank0.params_mgr.chunk_bytes,
        ) if gather_lookahead > 0 else None
        self.step_count = 0

    # ----------------------------------------------------------- collectives
    def fetch_group(self, group: int, *, hidden: bool = False) -> bool:
        """Chunk-granular all-gather of one communication group
        (Algorithm 1 ``FetchRemoteChunks`` / Fig. 9).

        Every rank brings its OWN chunk of the group on-device and pins it
        for the duration (line 11-12); every rank then materializes the
        p-1 non-owned replicas and copies the owners' bytes in.  Received
        bytes — ``(p-1) * chunk_bytes`` per rank, padding chunks included,
        exactly what a tiled ``lax.all_gather`` of the [G, p, S] store
        moves — land in the pool's collective ledger, classified hidden
        (prefetched) or critical-path (demand).  Returns True iff a
        gather actually ran (resident groups are a no-op, so the gather
        prefetcher can probe freely)."""
        cmap = self.cmap
        payload_ids = [c for c in cmap.comm_group_chunk_ids(group)
                       if cmap.chunk_tensors(c)]
        # all-or-nothing: a collective is only well-defined when EVERY
        # rank's non-owned replicas of the group are released.  A mixed
        # state means some rank is still mid-phase on the group (e.g. a
        # prefetch probing across the FWD->BWD boundary before the last
        # rank's post-FWD release) — refuse, the demand fetch will run
        # once the phase transition completes everywhere.  This guard is
        # also what keeps the per-rank accounting exact: a gather that ran
        # would otherwise book (p-1) chunks on a rank that materialized
        # fewer.
        released = [
            core.params_mgr.chunk_state(c) is ChunkState.RELEASED
            for r, core in enumerate(self.ranks)
            for c in payload_ids if cmap.chunk_owner(c) != r]
        if not (released and all(released)):
            return False
        chunk_bytes = self.ranks[0].params_mgr.chunk_bytes
        pinned: list[tuple[int, int]] = []
        try:
            # owners first: the collective reads their payloads
            for c in payload_ids:
                o = cmap.chunk_owner(c)
                self.ranks[o].params_mgr.prepare_payload(c, "device")
                self.ranks[o].params_mgr.pin(c)
                pinned.append((o, c))
            for r, core in enumerate(self.ranks):
                for c in payload_ids:
                    o = cmap.chunk_owner(c)
                    if o == r:
                        continue
                    dst = core.params_mgr.materialize_chunk(c, "device",
                                                            pin=True)
                    pinned.append((r, c))
                    src = self.ranks[o].params_mgr._records[c].payload
                    dst[...] = src
                core.pool.account_allgather(
                    (self.nproc - 1) * chunk_bytes, hidden=hidden,
                    group=group)
        finally:
            for r, c in pinned:
                self.ranks[r].params_mgr.unpin(c)
        return True

    def reduce_scatter_group(self, group: int) -> None:
        """Algorithm 2 gradient path: the p grad replicas of every chunk
        in the group SUM onto the owner's payload (the per-shard losses
        already carry 1/global_tokens, so summing is the correct global
        reduction); non-owned replicas then drop back to RELEASED.  Sent
        bytes per rank: ``(p-1) * chunk_bytes``."""
        cmap = self.cmap
        chunk_bytes = self.ranks[0].params_mgr.chunk_bytes
        for c in cmap.comm_group_chunk_ids(group):
            if not cmap.chunk_tensors(c):
                continue
            o = cmap.chunk_owner(c)
            acc = self.ranks[o].params_mgr._records[c].payload
            for r, core in enumerate(self.ranks):
                if r == o:
                    continue
                acc += core.params_mgr._records[c].payload
        for r, core in enumerate(self.ranks):
            for c in cmap.comm_group_chunk_ids(group):
                if cmap.chunk_owner(c) != r and cmap.chunk_tensors(c):
                    core.params_mgr.mark_released(c)
            core.pool.account_reduce_scatter((self.nproc - 1) * chunk_bytes)
        self.retire_group(group)

    def retire_group(self, group: int) -> None:
        """A rank dropped its replicas of ``group`` (post-FWD release or
        the reduce-scatter above): once EVERY rank's non-owned replicas
        are back in RELEASED, the group's staged-gather slot is retired —
        the gather prefetcher's in-flight cap bounds replicas actually
        held, so the slot must not free while any rank still holds
        (p-1)/p of the group."""
        if self.gather_prefetcher is None:
            return
        cmap = self.cmap
        ids = [c for c in cmap.comm_group_chunk_ids(group)
               if cmap.chunk_tensors(c)]
        if all(core.params_mgr.chunk_state(c) is ChunkState.RELEASED
               for r, core in enumerate(self.ranks)
               for c in ids if cmap.chunk_owner(c) != r):
            self.gather_prefetcher.retire(group)

    def advance_prefetch(self, moment: int) -> None:
        """Called by rank 0's moment cursor: stage upcoming group gathers."""
        if self.gather_prefetcher is not None:
            self.gather_prefetcher.advance(moment)

    # ------------------------------------------------------------------ step
    def _split_batch(self, batch: dict) -> list[dict]:
        b = int(batch["tokens"].shape[0])
        if b % self.nproc:
            raise ValueError(
                f"batch dim {b} must divide evenly over nproc={self.nproc}")
        per = b // self.nproc

        def shard(x, r):
            if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == b:
                return x[r * per:(r + 1) * per]
            return x  # scalars (global_tokens) replicate

        return [{k: shard(v, r) for k, v in batch.items()}
                for r in range(self.nproc)]

    def step(self, batch: dict) -> DistributedStepMetrics:
        """One lock-step data-parallel iteration: equivalent math to the
        single-rank engine on the full batch (grads sum across shards,
        losses carry 1/global_tokens)."""
        cores = self.ranks
        shards = self._split_batch(batch)
        # per-rank ledgers are symmetric by construction; rank 0's delta
        # is the step's per-rank figure
        col0 = dataclasses.replace(cores[0].pool.collectives)
        warmup = cores[0].tracer.warmup

        sts = [core.begin_step(sh) for core, sh in zip(cores, shards)]

        # per-rank phase spans (fwd/bwd/adam), each stamped on its own
        # core's simulated clock
        def _phase(label: str) -> None:
            for core in cores:
                tel = core.pool.telemetry
                if tel is not None:
                    tel.switch_span("phase", label, ts=core.pool._now(),
                                    rank=core.pool.telemetry_rank)

        def _phase_end() -> None:
            for core in cores:
                tel = core.pool.telemetry
                if tel is not None:
                    tel.close_span("phase", ts=core.pool._now(),
                                   rank=core.pool.telemetry_rank)

        # ------------------------------------------------------------ forward
        _phase("fwd")
        for core, st in zip(cores, sts):
            core.forward_embed(st)
        for g in cores[0].model.groups():
            for core, st in zip(cores, sts):
                core.forward_group_start(st, g.name)
            for i in range(g.length):
                for core, st in zip(cores, sts):
                    core.forward_layer(st, g, i)
        for core, st in zip(cores, sts):
            core.end_forward(st)

        # ----------------------------------------------------------- backward
        _phase("bwd")
        for core, st in zip(cores, sts):
            core.begin_backward(st)
        for idx in range(len(sts[0].saved) - 1, -1, -1):
            done = [core.backward_layer(st, idx)
                    for core, st in zip(cores, sts)]
            # symmetric model + lock-step => identical completion sets
            assert all(d == done[0] for d in done[1:]), done
            for grp in done[0]:
                self.reduce_scatter_group(grp)
        for core, st in zip(cores, sts):
            core.backward_embed(st)
            core.end_backward(st)

        # -------------------------------- stem grad all-reduce (off-plane)
        total_stem = sts[0].stem_grad
        for st in sts[1:]:
            total_stem = jax.tree.map(lambda a, b: a + b, total_stem,
                                      st.stem_grad)
        stem_bytes = sum(
            int(np.prod(x.shape)) * 4 for x in jax.tree.leaves(total_stem))
        ar_bytes = 2 * (self.nproc - 1) * stem_bytes // self.nproc  # ring
        for core in cores:
            core.pool.account_allreduce(ar_bytes)

        # --------------------------------------------------------------- ADAM
        _phase("adam")
        for core, st in zip(cores, sts):
            core.adam_chunks(st)
        cores[0].update_stem(total_stem)
        for core in cores[1:]:
            core._stem_np = cores[0]._stem_np  # replicated stem

        _phase_end()
        mets = [core.end_step(st) for core, st in zip(cores, sts)]
        if warmup and self.gather_prefetcher is not None:
            self.gather_prefetcher.install(
                cores[0].tracer.gather_reference_sequence(self.cmap))

        d0 = self._collective_delta(cores[0].pool.collectives, col0)
        self.step_count += 1
        return DistributedStepMetrics(
            loss=float(sum(m.loss for m in mets)),
            rank_metrics=mets,
            allgather_bytes=d0.allgather_bytes,
            reduce_scatter_bytes=d0.reduce_scatter_bytes,
            allreduce_bytes=d0.allreduce_bytes,
            hidden_allgather_bytes=d0.hidden_allgather_bytes,
            critical_allgather_bytes=d0.critical_allgather_bytes,
        )

    @staticmethod
    def _collective_delta(now: CollectiveStats,
                          before: CollectiveStats) -> CollectiveStats:
        return CollectiveStats(
            allgather_bytes=now.allgather_bytes - before.allgather_bytes,
            reduce_scatter_bytes=(now.reduce_scatter_bytes
                                  - before.reduce_scatter_bytes),
            allreduce_bytes=now.allreduce_bytes - before.allreduce_bytes,
            allgather_count=now.allgather_count - before.allgather_count,
            reduce_scatter_count=(now.reduce_scatter_count
                                  - before.reduce_scatter_count),
            hidden_allgather_bytes=(now.hidden_allgather_bytes
                                    - before.hidden_allgather_bytes),
            critical_allgather_bytes=(now.critical_allgather_bytes
                                      - before.critical_allgather_bytes),
        )

    # ------------------------------------------------------------- inspection
    @property
    def collectives(self) -> list[CollectiveStats]:
        """Cumulative per-rank collective ledgers."""
        return [core.pool.collectives for core in self.ranks]

    def check_invariants(self) -> None:
        for core in self.ranks:
            core.pool.check_invariants()
        # exactly one authoritative (owner) replica per payload chunk
        for c in range(self.cmap.num_chunks):
            if not self.cmap.chunk_tensors(c):
                continue
            o = self.cmap.chunk_owner(c)
            assert self.ranks[o].params_mgr._records[c].payload is not None, (
                f"owner rank {o} of chunk {c} has no payload")


# ---------------------------------------------------------------------------
# Rank-sharded serving fleet
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetRoundMetrics:
    """One lock-step serving round across all ranks (``None`` entries are
    ranks that had nothing to do this round)."""

    round_index: int
    rank_metrics: list  # ServeRoundMetrics | None, indexed by rank

    def _sum(self, field: str) -> int:
        return sum(getattr(m, field) for m in self.rank_metrics
                   if m is not None)

    @property
    def admitted(self) -> int:
        return self._sum("admitted")

    @property
    def completed(self) -> int:
        return self._sum("completed")

    @property
    def active(self) -> int:
        return self._sum("active")

    @property
    def queued(self) -> int:
        return self._sum("queued")

    @property
    def prefill_tokens(self) -> int:
        return self._sum("prefill_tokens")

    @property
    def decode_tokens(self) -> int:
        return self._sum("decode_tokens")

    @property
    def tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def peak_device_bytes(self) -> int:
        """Worst per-rank pool device high-water mark this round — the
        per-rank budget every rank must individually respect."""
        return max((m.peak_device_bytes for m in self.rank_metrics
                    if m is not None), default=0)


class DistributedServingEngine:
    """Rank-sharded serving: ``nproc`` independent serving cores advanced
    in lock-step rounds, sequences placed round-robin at submit time.

    This reuses :class:`DistributedPatrickStarEngine`'s driver shape —
    one shared parameter init, rank 0's chunk layout reused by every
    rank, per-rank pools, lock-step stepping — but where the trainer
    shards *chunks* across ranks and gathers them on demand, the serving
    fleet shards *sequences*: every rank holds a full read-only param
    replica and its own sequences' KV pages, so scaling out multiplies
    concurrent-sequence capacity at a fixed per-rank budget with ZERO
    new collectives (asserted in :meth:`check_invariants` against each
    rank's :class:`~repro.core.memory.CollectiveStats` ledger).  This is
    the data-parallel production serving stack shape: paged admission +
    continuous batching per rank, a stateless router in front.
    """

    def __init__(
        self,
        model_cls,
        cfg,
        *,
        nproc: int,
        device_memory_bytes: int,  # PER-RANK device budget
        host_memory_bytes: int | None = None,
        compiled: bool = False,
        seed: int = 0,
        pools: "list | None" = None,
        tenants: "list | None" = None,
        **engine_kw,
    ) -> None:
        if nproc < 1:
            raise ValueError(f"nproc must be >= 1, got {nproc}")
        for arg, label in ((pools, "pools"), (tenants, "tenants")):
            if arg is not None and len(arg) != nproc:
                raise ValueError(f"{label}= needs one entry per rank "
                                 f"({len(arg)} != nproc {nproc})")
        self.nproc = nproc
        from repro.core.serving import ServingEngine
        from repro.models.layers import AxisCtx

        if compiled:
            from repro.runtime.serve import CompiledServingEngine
            engine_cls = CompiledServingEngine
        else:
            engine_cls = ServingEngine
        # ONE init for all ranks: the fleet replicates parameters, so
        # initializing nproc times would only burn time and transient
        # memory (and rank 0's searched chunk size is reused so every
        # rank's pool sees the identical layout).
        init_params = model_cls(cfg, AxisCtx()).init_params(
            jax.random.key(seed))

        def make_core(r, csize):
            return engine_cls(
                model_cls, cfg,
                device_memory_bytes=device_memory_bytes,
                host_memory_bytes=host_memory_bytes,
                pool=pools[r] if pools is not None else None,
                tenant=tenants[r] if tenants is not None else None,
                chunk_size=csize, seed=seed, init_params=init_params,
                **engine_kw)

        rank0 = make_core(0, engine_kw.pop("chunk_size", None))
        self.ranks = [rank0] + [make_core(r, rank0.cmap.chunk_size)
                                for r in range(1, nproc)]
        # rank-tag each core's hub (passed through **engine_kw or picked
        # up from the module default) so fleet traces separate per rank
        for r, core in enumerate(self.ranks):
            tel = core.pool.telemetry
            if tel is not None:
                core.pool.set_telemetry(tel, rank=r)
        self._placement: dict[int, tuple[int, int]] = {}  # gid -> (rank, rid)
        self._next_gid = 0
        self._rr = 0
        self.rounds = 0

    # --------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue a request on the next rank round-robin; returns a fleet-
        global id.  KV for the sequence lives only on that rank."""
        rank = self._rr
        self._rr = (self._rr + 1) % self.nproc
        local = self.ranks[rank].submit(prompt, max_new_tokens)
        gid = self._next_gid
        self._next_gid += 1
        self._placement[gid] = (rank, local)
        return gid

    # ------------------------------------------------------------------ run
    def step_round(self) -> FleetRoundMetrics | None:
        """Advance every rank one continuous-batching round in lock-step.
        Returns ``None`` when the whole fleet is drained."""
        ms = [core.step_round() for core in self.ranks]
        if all(m is None for m in ms):
            return None
        self.rounds += 1
        return FleetRoundMetrics(round_index=self.rounds - 1,
                                 rank_metrics=ms)

    def run(self, max_rounds: int = 10_000) -> list[FleetRoundMetrics]:
        """Round until every submitted request has completed."""
        out: list[FleetRoundMetrics] = []
        while any(c.queued_count or c.active_count for c in self.ranks):
            if len(out) >= max_rounds:
                raise RuntimeError(
                    f"fleet did not drain within {max_rounds} rounds")
            m = self.step_round()
            assert m is not None
            out.append(m)
        return out

    # ------------------------------------------------------------- results
    def result(self, gid: int) -> list[int]:
        rank, rid = self._placement[gid]
        return self.ranks[rank].result(rid)

    @property
    def active_count(self) -> int:
        return sum(c.active_count for c in self.ranks)

    @property
    def queued_count(self) -> int:
        return sum(c.queued_count for c in self.ranks)

    @property
    def peak_concurrency(self) -> int:
        """Fleet-wide concurrent-sequence capacity actually reached: the
        sum of per-rank high-water marks (ranks admit independently)."""
        return sum(c.peak_concurrency for c in self.ranks)

    @property
    def total_decode_tokens(self) -> int:
        return sum(c.total_decode_tokens for c in self.ranks)

    @property
    def total_prefill_tokens(self) -> int:
        return sum(c.total_prefill_tokens for c in self.ranks)

    def check_invariants(self) -> None:
        for r, core in enumerate(self.ranks):
            core.check_invariants()
            col = core.pool.collectives
            moved = (col.allgather_bytes + col.reduce_scatter_bytes
                     + col.allreduce_bytes)
            assert moved == 0, (
                f"rank {r} booked {moved} collective bytes — serving KV "
                f"and params must stay rank-local")
