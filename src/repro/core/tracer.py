"""Runtime memory tracer (PatrickStar Section 8.1).

During a *warm-up* iteration the tracer records, at every operator
begin/end ("**moment**"), the real memory consumption R of the computing
device and the bytes C the chunk manager holds there; non-model footprint
is R - C.  Since PTM iterations repeat the same compute pattern, the
warm-up profile predicts every later iteration, giving:

  * ``chunkable_memory(moment)`` — device bytes available for chunks at a
    moment (total - non-model[moment]);
  * per-chunk *reference moments*, the future-knowledge schedule consumed
    by the OPT eviction policy (Section 8.3);
  * ``peak_nonmodel`` / GPU **margin space** for device-aware operator
    placement (Section 8.2).

During warm-up the chunk budget is capped at ``warmup_chunk_fraction``
(default 20%, the paper's choice) of device memory, and eviction falls
back to chunk-list order because no schedule exists yet.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class Moment:
    index: int
    op_name: str
    phase: str  # "FWD" | "BWD" | "ADAM"
    nonmodel_bytes: int


class RuntimeMemoryTracer:
    def __init__(
        self,
        device_total_bytes: int,
        *,
        warmup_chunk_fraction: float = 0.2,
        overhead_bytes: int = 0,
    ) -> None:
        self.device_total_bytes = device_total_bytes
        self.warmup_chunk_fraction = warmup_chunk_fraction
        # constant runtime overhead (CUDA context in the paper; compiled
        # program + runtime buffers on TPU).
        self.overhead_bytes = overhead_bytes
        self.warmup = True
        self.moments: list[Moment] = []
        self.chunk_moments: dict[int, list[int]] = defaultdict(list)
        self._moment_idx = -1

    # ------------------------------------------------------------- recording
    def begin_iteration(self) -> None:
        self._moment_idx = -1
        if self.warmup:
            self.moments.clear()
            self.chunk_moments.clear()

    def record_moment(self, op_name: str, phase: str, nonmodel_bytes: int) -> int:
        """Called at operator start and finish.  Returns the moment index."""
        self._moment_idx += 1
        if self.warmup:
            self.moments.append(
                Moment(self._moment_idx, op_name, phase, int(nonmodel_bytes))
            )
        return self._moment_idx

    def record_chunk_use(self, chunk_id: int) -> None:
        if self.warmup:
            self.chunk_moments[chunk_id].append(max(self._moment_idx, 0))

    def end_warmup(self) -> None:
        self.warmup = False

    @property
    def current_moment(self) -> int:
        return max(self._moment_idx, 0)

    # --------------------------------------------------------------- queries
    def nonmodel_at(self, moment: int) -> int:
        if not self.moments:
            return 0
        moment = min(max(moment, 0), len(self.moments) - 1)
        return self.moments[moment].nonmodel_bytes

    def chunkable_memory(self, moment: int | None = None) -> int:
        """Device bytes available for chunks (Section 8.1)."""
        if self.warmup:
            return int(self.device_total_bytes * self.warmup_chunk_fraction)
        m = self.current_moment if moment is None else moment
        avail = self.device_total_bytes - self.overhead_bytes - self.nonmodel_at(m)
        return max(avail, 0)

    @property
    def peak_nonmodel_bytes(self) -> int:
        return max((m.nonmodel_bytes for m in self.moments), default=0)

    def margin_space(self, param_working_set_bytes: int) -> int:
        """GPU margin space for OS chunks (Section 8.2):
        total - peak non-model - the param fp16 working set."""
        return max(
            self.device_total_bytes
            - self.overhead_bytes
            - self.peak_nonmodel_bytes
            - param_working_set_bytes,
            0,
        )

    def schedule(self) -> dict[int, list[int]]:
        """The per-chunk future-reference schedule for OPT eviction."""
        return {c: list(ms) for c, ms in self.chunk_moments.items()}
