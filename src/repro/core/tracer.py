"""Runtime memory tracer (PatrickStar Section 8.1).

During a *warm-up* iteration the tracer records, at every operator
begin/end ("**moment**"), the real memory consumption R of the computing
device and the bytes C the chunk manager holds there; non-model footprint
is R - C.  Since PTM iterations repeat the same compute pattern, the
warm-up profile predicts every later iteration, giving:

  * ``chunkable_memory(moment)`` — device bytes available for chunks at a
    moment (total - non-model[moment]);
  * per-chunk *reference moments*, the future-knowledge schedule consumed
    by the OPT eviction policy (Section 8.3) — recorded per stream (param
    chunks are referenced in FWD/BWD/ADAM, optimizer-state chunks only in
    ADAM, activation chunks exactly twice: their FWD write and their
    mirrored BWD read — the FWD->BWD reuse distance is what lets OPT
    spill cold act chunks to host mid-step and the prefetcher stage them
    back ahead of ``backward_layer``), which also yields the total
    reference order the schedule-driven prefetcher stages chunks from;
  * ``peak_nonmodel`` / GPU **margin space** for device-aware operator
    placement (Section 8.2).

During warm-up the chunk budget is capped at ``warmup_chunk_fraction``
(default 20%, the paper's choice) of device memory, and eviction falls
back to chunk-list order because no schedule exists yet.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class Moment:
    index: int
    op_name: str
    phase: str  # "FWD" | "BWD" | "ADAM"
    nonmodel_bytes: int


class RuntimeMemoryTracer:
    def __init__(
        self,
        device_total_bytes: int,
        *,
        warmup_chunk_fraction: float = 0.2,
        overhead_bytes: int = 0,
    ) -> None:
        self.device_total_bytes = device_total_bytes
        self.warmup_chunk_fraction = warmup_chunk_fraction
        # constant runtime overhead (CUDA context in the paper; compiled
        # program + runtime buffers on TPU).
        self.overhead_bytes = overhead_bytes
        self.warmup = True
        self.moments: list[Moment] = []
        # stream -> chunk_id -> *device* reference moments (the schedule
        # OPT eviction and the prefetcher consume: both reason about the
        # device tier, so a use that computes host-side is not a reason to
        # keep — or stage — a chunk on the device)
        self.stream_chunk_moments: dict[str, dict[int, list[int]]] = defaultdict(
            lambda: defaultdict(list)
        )
        # stream -> chunk_id -> host-side reference moments (ADAM on host);
        # promoted to device refs for OS groups later placed in GPU margin.
        self.host_chunk_moments: dict[str, dict[int, list[int]]] = defaultdict(
            lambda: defaultdict(list)
        )
        self._moment_idx = -1

    # ------------------------------------------------------------- recording
    def begin_iteration(self) -> None:
        self._moment_idx = -1
        if self.warmup:
            self.moments.clear()
            self.stream_chunk_moments.clear()
            self.host_chunk_moments.clear()

    def record_moment(self, op_name: str, phase: str, nonmodel_bytes: int) -> int:
        """Called at operator start and finish.  Returns the moment index."""
        self._moment_idx += 1
        if self.warmup:
            self.moments.append(
                Moment(self._moment_idx, op_name, phase, int(nonmodel_bytes))
            )
        return self._moment_idx

    def record_chunk_use(
        self, chunk_id: int, stream: str = "param", dev: str = "device"
    ) -> None:
        if not self.warmup:
            return
        m = max(self._moment_idx, 0)
        if dev == "device":
            self.stream_chunk_moments[stream][chunk_id].append(m)
        else:
            self.host_chunk_moments[stream][chunk_id].append(m)

    def end_warmup(self) -> None:
        self.warmup = False

    @property
    def current_moment(self) -> int:
        return max(self._moment_idx, 0)

    # --------------------------------------------------------------- queries
    def nonmodel_at(self, moment: int) -> int:
        if not self.moments:
            return 0
        moment = min(max(moment, 0), len(self.moments) - 1)
        return self.moments[moment].nonmodel_bytes

    def chunkable_memory(self, moment: int | None = None) -> int:
        """Device bytes available for chunks (Section 8.1)."""
        if self.warmup:
            return int(self.device_total_bytes * self.warmup_chunk_fraction)
        m = self.current_moment if moment is None else moment
        avail = self.device_total_bytes - self.overhead_bytes - self.nonmodel_at(m)
        return max(avail, 0)

    @property
    def peak_nonmodel_bytes(self) -> int:
        return max((m.nonmodel_bytes for m in self.moments), default=0)

    def margin_space(self, param_working_set_bytes: int) -> int:
        """GPU margin space for OS chunks (Section 8.2):
        total - peak non-model - the param fp16 working set."""
        return max(
            self.device_total_bytes
            - self.overhead_bytes
            - self.peak_nonmodel_bytes
            - param_working_set_bytes,
            0,
        )

    def schedule(self, stream: str | None = None) -> dict[int, list[int]]:
        """The per-chunk future-reference schedule for OPT eviction.

        Without ``stream`` the merged (all-stream) schedule is returned,
        which is what a standalone single-stream manager consumes."""
        if stream is not None:
            per = self.stream_chunk_moments.get(stream, {})
            return {c: list(ms) for c, ms in per.items()}
        merged: dict[int, list[int]] = defaultdict(list)
        for per in self.stream_chunk_moments.values():
            for c, ms in per.items():
                merged[c].extend(ms)
        return {c: sorted(ms) for c, ms in merged.items()}

    def schedule_by_stream(
        self, promote_chunks: "dict[str, set[int]] | None" = None
    ) -> dict[str, dict[int, list[int]]]:
        """Per-stream device schedules.  ``promote_chunks`` (stream ->
        chunk ids) additionally merges in host-side reference moments for
        chunks the placement plan later keeps on the device (OS groups in
        GPU margin space: their ADAM runs device-side after warm-up)."""
        out = {
            s: {c: list(ms) for c, ms in per.items()}
            for s, per in self.stream_chunk_moments.items()
        }
        for s, chunks in (promote_chunks or {}).items():
            per = out.setdefault(s, {})
            hosted = self.host_chunk_moments.get(s, {})
            for c in chunks:
                if c in hosted:
                    per[c] = sorted(per.get(c, []) + list(hosted[c]))
        return out

    def duration_schedule(self, cost_of) -> dict[int, float]:
        """Per-moment compute durations for the transfer timeline
        (:class:`repro.core.timeline.TransferTimeline`): maps each
        warm-up moment through ``cost_of(op_name, phase) -> seconds``
        (e.g. :meth:`repro.analysis.costmodel.TrainOperatorCosts.of_moment`).
        Zero-duration moments are omitted — the timeline treats missing
        moments as instantaneous."""
        out: dict[int, float] = {}
        for m in self.moments:
            dur = cost_of(m.op_name, m.phase)
            if dur > 0.0:
                out[m.index] = dur
        return out

    def gather_reference_sequence(
        self, cmap, stream: str = "param",
        phases: tuple[str, ...] = ("FWD", "BWD"),
    ) -> list[tuple[int, int]]:
        """Deduplicated (moment, comm_group) pairs of one iteration — the
        schedule the distributed driver's gather prefetcher walks: at
        every lock-step moment, the next upcoming *remote-group
        all-gathers* can be issued ahead of the operator that reads them.

        ADAM moments are excluded by default on purpose: the ADAM stage is
        local to chunk owners (Section 7), so a post-reduce-scatter
        reference must never re-gather a group that was just released."""
        phase_of = {m.index: m.phase for m in self.moments}
        per = self.stream_chunk_moments.get(stream, {})
        refs = {
            (mm, cmap.comm_group(c))
            for c, ms in per.items()
            for mm in ms
            if phase_of.get(mm) in phases
        }
        return sorted(refs)

    def reference_sequence(
        self, schedules: "dict[str, dict[int, list[int]]] | None" = None
    ) -> list[tuple[int, str, int]]:
        """All device-side (moment, stream, chunk_id) references of one
        iteration in moment order — the staging queue the prefetcher
        walks.  Pass the (possibly promotion-amended) ``schedules`` to
        keep prefetch and OPT consuming the same future."""
        if schedules is None:
            schedules = self.schedule_by_stream()
        refs = [
            (m, s, c)
            for s, per in schedules.items()
            for c, ms in per.items()
            for m in ms
        ]
        return sorted(refs)
