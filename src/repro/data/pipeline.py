"""Data pipeline: synthetic corpus -> packed token batches, per-host sharded.

The paper pretrains GPT-2-like models on internet text; for the repro we
ship a deterministic synthetic corpus (a mixture of Zipfian unigrams and
repeated n-gram motifs, so models have real structure to learn and loss
curves are meaningful), a byte-level tokenizer stub for real text, and a
packing loader that emits fixed-length ``{tokens, labels, mask}`` batches
with next-token labels.

For multi-host launches each host reads a disjoint shard
(``shard=(host_id, n_hosts)``); within a host, the global batch is laid
out so that jax's device placement along the (pod, data) axes matches the
batch sharding in ``runtime.driver``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic pseudo-text: Zipf unigrams + injected n-gram motifs."""

    vocab_size: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64
    motif_prob: float = 0.3

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            0, self.vocab_size, size=(self.n_motifs, self.motif_len))
        ranks = np.arange(1, self.vocab_size + 1)
        p = 1.0 / ranks**1.1
        self._p = p / p.sum()

    def tokens(self, n: int, *, stream: int = 0) -> np.ndarray:
        rng = np.random.default_rng((self.seed + 1) * 7919 + stream)
        out = np.empty(n, dtype=np.int32)
        i = 0
        while i < n:
            if rng.random() < self.motif_prob:
                m = self._motifs[rng.integers(self.n_motifs)]
                take = min(len(m), n - i)
                out[i : i + take] = m[:take]
                i += take
            else:
                take = min(int(rng.integers(4, 32)), n - i)
                out[i : i + take] = rng.choice(
                    self.vocab_size, size=take, p=self._p)
                i += take
        return out


def byte_tokenize(text: str, vocab_size: int) -> np.ndarray:
    """Byte-level tokenizer stub for real text files (mod-folded)."""
    b = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
    return b % vocab_size


@dataclasses.dataclass
class PackedLMLoader:
    """Packs a token stream into [batch, seq+1] windows -> tokens/labels."""

    corpus: SyntheticCorpus
    batch_size: int
    seq_len: int
    shard: tuple[int, int] = (0, 1)  # (host_id, n_hosts)

    def __iter__(self) -> Iterator[dict]:
        host, n_hosts = self.shard
        step = 0
        while True:
            stream = step * n_hosts + host
            flat = self.corpus.tokens(
                self.batch_size * (self.seq_len + 1), stream=stream)
            window = flat.reshape(self.batch_size, self.seq_len + 1)
            yield {
                "tokens": window[:, :-1].copy(),
                "labels": window[:, 1:].copy(),
                "mask": np.ones((self.batch_size, self.seq_len), np.float32),
            }
            step += 1


def make_batch_fn(cfg, batch_size: int, seq_len: int, *, seed: int = 0,
                  shard: tuple[int, int] = (0, 1)):
    """Arch-aware batch iterator (adds stub modality inputs for vlm/audio)."""
    rng = np.random.default_rng(seed + 1000 * shard[0])
    if cfg.arch_type == "vlm":
        text_len = seq_len - cfg.num_patches
        corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
        loader = iter(PackedLMLoader(corpus, batch_size, text_len, shard=shard))

        def nxt():
            b = next(loader)
            b["patch_embeds"] = rng.standard_normal(
                (batch_size, cfg.num_patches, cfg.vision_dim)).astype(np.float32)
            b["global_tokens"] = np.float32(batch_size * text_len)
            return b
        return nxt
    if cfg.arch_type == "audio":
        frames = min(cfg.encoder_frames, seq_len)
        corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
        loader = iter(PackedLMLoader(corpus, batch_size, seq_len, shard=shard))

        def nxt():
            b = next(loader)
            b["frames"] = rng.standard_normal(
                (batch_size, frames, cfg.frontend_dim)).astype(np.float32)
            b["global_tokens"] = np.float32(batch_size * seq_len)
            return b
        return nxt
    corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
    loader = iter(PackedLMLoader(corpus, batch_size, seq_len, shard=shard))

    def nxt():
        b = next(loader)
        b["global_tokens"] = np.float32(batch_size * seq_len)
        return b
    return nxt
