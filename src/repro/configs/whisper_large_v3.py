"""whisper-large-v3 [audio] — 32L (decoder) d_model=1280 20H
d_ff=5120 vocab=51866; enc-dec, conv frontend stubbed. [arXiv:2212.04356]

20 heads do not divide the 16-way model axis: attention is replicated
over TP and the MLP shards (see DESIGN.md).  32 encoder layers match the
release.
"""

from repro.configs.base import EncDecConfig

CONFIG = EncDecConfig(
    name="whisper-large-v3", arch_type="audio",
    num_layers=32, num_encoder_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    encoder_frames=1500, frontend_dim=128,
    activation="gelu", gated_mlp=False, norm="ln", use_rope=True,
    source="arXiv:2212.04356",
)

SMOKE_CONFIG = CONFIG.replace(
    name="whisper-smoke", num_layers=2, num_encoder_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    encoder_frames=32, frontend_dim=16)
