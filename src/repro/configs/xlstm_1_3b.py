"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304;
sLSTM + mLSTM blocks at ratio 7:1 (xLSTM[7:1]). [arXiv:2405.04517]"""

from repro.configs.base import XLSTMConfig

CONFIG = XLSTMConfig(
    name="xlstm-1.3b", arch_type="ssm",
    num_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50304,
    proj_factor=2.0, conv_kernel=4, mlstm_per_unit=7, slstm_per_unit=1,
    chunk_len=64,
    source="arXiv:2405.04517",
)

SMOKE_CONFIG = CONFIG.replace(
    name="xlstm-smoke", num_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, vocab_size=512, mlstm_per_unit=1, slstm_per_unit=1,
    chunk_len=16)
