"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400; llama-arch. [arXiv:2401.02954]"""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="deepseek-7b", arch_type="dense",
    num_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    activation="silu", gated_mlp=True,
    source="arXiv:2401.02954",
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-7b-smoke", num_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
