"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""

from repro.configs.base import MoEConfig

CONFIG = MoEConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, d_ff_expert=14336, vocab_size=32000,
    n_experts=8, top_k=2, n_shared_experts=0,
    sliding_window=4096,
    activation="silu", gated_mlp=True,
    moe_impl="tp",  # 8 experts on a 16-way model axis -> ffn-sharded layout
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, d_ff_expert=256, vocab_size=512, n_experts=4,
    top_k=2, sliding_window=32)
