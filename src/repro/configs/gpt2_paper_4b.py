"""The paper's GPT-2-like 4B config (Table 2): 64 layers, hidden 2304."""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="gpt2-paper-4b", arch_type="dense",
    num_layers=64, d_model=2304, n_heads=16, n_kv_heads=16, head_dim=144,
    d_ff=9216, vocab_size=50304,
    activation="gelu", gated_mlp=False, norm="ln",
    source="PatrickStar Table 2",
)

SMOKE_CONFIG = CONFIG.replace(
    name="gpt2-paper-4b-smoke", num_layers=2, d_model=144, n_heads=4,
    n_kv_heads=4, head_dim=36, d_ff=576, vocab_size=512)
