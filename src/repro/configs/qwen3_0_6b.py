"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="qwen3-0.6b", arch_type="dense",
    num_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, activation="silu", gated_mlp=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-smoke", num_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
