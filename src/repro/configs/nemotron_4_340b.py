"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8)
d_ff=73728 vocab=256000; GQA, squared-ReLU un-gated MLP. [arXiv:2402.16819]"""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="nemotron-4-340b", arch_type="dense",
    num_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    activation="relu2", gated_mlp=False, tie_embeddings=False,
    source="arXiv:2402.16819",
)

SMOKE_CONFIG = CONFIG.replace(
    name="nemotron-smoke", num_layers=2, d_model=192, n_heads=4, n_kv_heads=2,
    head_dim=48, d_ff=768, vocab_size=512)
