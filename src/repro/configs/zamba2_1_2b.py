"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192,
ssm_state=64; Mamba2 backbone + shared attention block. [arXiv:2411.15242]

38 Mamba2 layers = 6 units of (6 mamba + shared attention) plus a
2-layer mamba tail group (38 % 6), so the assigned layer count is exact.
"""

from repro.configs.base import HybridConfig

CONFIG = HybridConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    num_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, mamba_headdim=64, mamba_expand=2, conv_kernel=4,
    shared_interval=6, chunk_len=64,
    activation="gelu", gated_mlp=True,
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.replace(
    name="zamba2-smoke", num_layers=5, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=64, d_ff=256, vocab_size=512, ssm_state=16, mamba_headdim=32,
    shared_interval=2, chunk_len=16)
