"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064; phi3-mini decoder + CLIP stub frontend.
[hf:microsoft/Phi-3-vision-128k-instruct]"""

from repro.configs.base import VLMConfig

CONFIG = VLMConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    num_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    num_patches=576, vision_dim=1024,
    activation="silu", gated_mlp=True,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE_CONFIG = CONFIG.replace(
    name="phi3v-smoke", num_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512, num_patches=16, vision_dim=64)
