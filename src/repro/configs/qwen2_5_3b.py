"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936; GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="qwen2.5-3b", arch_type="dense",
    num_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab_size=151936,
    qkv_bias=True, activation="silu", gated_mlp=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2.5-smoke", num_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
