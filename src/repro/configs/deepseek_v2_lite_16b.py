"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408
vocab=102400; MLA kv_lora=512, shared + routed experts top-6.
[arXiv:2405.04434]

The assignment line lists both "MoE 64e top-6" and "2 shared+160 routed";
64 routed experts matches the published V2-Lite card (160 belongs to the
full V2-236B), so the structured "64e" field wins; the first layer is
dense, as in the release.
"""

from repro.configs.base import MoEConfig

CONFIG = MoEConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    num_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,            # dense first-layer ffn
    d_ff_expert=1408, vocab_size=102400,
    n_experts=64, top_k=6, n_shared_experts=2, first_dense_layers=1,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    activation="silu", gated_mlp=True,
    moe_impl="ep",  # 64 experts over a 16-way model axis -> expert parallel
    source="arXiv:2405.04434",
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-v2-lite-smoke", num_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=256, d_ff_expert=64, vocab_size=512,
    n_experts=4, top_k=2, n_shared_experts=1, first_dense_layers=1,
    kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
    moe_impl="tp")
