"""Architecture config registry (``--arch <id>``)."""

from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, BaseConfig, InputShape

ARCH_IDS = [
    "deepseek-v2-lite-16b",
    "qwen3-0.6b",
    "deepseek-7b",
    "zamba2-1.2b",
    "xlstm-1.3b",
    "nemotron-4-340b",
    "phi-3-vision-4.2b",
    "qwen2.5-3b",
    "whisper-large-v3",
    "mixtral-8x7b",
    # the paper's own workload family (GPT-2-like ladder, Table 2)
    "gpt2-paper-1b",
    "gpt2-paper-4b",
]


def _module(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, *, smoke: bool = False) -> BaseConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_module(arch_id))
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def model_class(cfg: BaseConfig):
    """Map a config to its Model class."""
    if cfg.arch_type == "dense":
        from repro.models.transformer import TransformerLM
        return TransformerLM
    if cfg.arch_type == "moe":
        from repro.models.moe_lm import MoELM
        return MoELM
    if cfg.arch_type == "ssm":
        from repro.models.xlstm_lm import XLSTMLM
        return XLSTMLM
    if cfg.arch_type == "hybrid":
        from repro.models.zamba import ZambaLM
        return ZambaLM
    if cfg.arch_type == "vlm":
        from repro.models.vlm import VLMBackbone
        return VLMBackbone
    if cfg.arch_type == "audio":
        from repro.models.whisper import WhisperBackbone
        return WhisperBackbone
    raise KeyError(cfg.arch_type)
