"""The paper's own GPT-2-like workload (Table 2): 1B = 20 layers,
hidden 2048, 16 heads, seq 1024, vocab 50257."""

from repro.configs.base import BaseConfig

CONFIG = BaseConfig(
    name="gpt2-paper-1b", arch_type="dense",
    num_layers=20, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304,
    activation="gelu", gated_mlp=False, norm="ln",
    source="PatrickStar Table 2",
)

SMOKE_CONFIG = CONFIG.replace(
    name="gpt2-paper-smoke", num_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512)
