"""Config schema for all supported architectures + the input-shape suite.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` file
exporting ``CONFIG`` (the exact assigned full-scale config, used only via
the dry-run) and ``SMOKE_CONFIG`` (a reduced same-family variant: <=2
layers, d_model<=512, <=4 experts — runnable on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class BaseConfig:
    name: str = "unnamed"
    arch_type: str = "dense"  # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10_000.0
    sliding_window: int | None = None
    # mlp flavour
    activation: str = "silu"
    gated_mlp: bool = True
    # norm flavour
    norm: str = "rms"  # "rms" | "ln"
    tie_embeddings: bool = True
    # numerics
    param_dtype: str = "bfloat16"  # chunk-store dtype (paper's "param fp16")
    compute_dtype: str = "bfloat16"
    # provenance
    source: str = ""

    # which input shapes this arch runs; long_500k only for sub-quadratic
    # families (see DESIGN.md §Arch-applicability)
    def supported_shapes(self) -> list[str]:
        shapes = ["train_4k", "prefill_32k", "decode_32k"]
        if self.subquadratic_decode:
            shapes.append("long_500k")
        return shapes

    @property
    def subquadratic_decode(self) -> bool:
        return self.sliding_window is not None

    def replace(self, **kw) -> "BaseConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class MoEConfig(BaseConfig):
    arch_type: str = "moe"
    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0
    d_ff_expert: int = 512  # per-expert ffn width
    first_dense_layers: int = 0  # leading dense layers (deepseek-v2 style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "tp"  # "tp": experts ffn-sharded | "ep": experts sharded over model
    # MLA (deepseek-v2) attention — enabled when kv_lora_rank > 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    @property
    def use_mla(self) -> bool:
        return self.kv_lora_rank > 0


@dataclasses.dataclass(frozen=True)
class XLSTMConfig(BaseConfig):
    """xLSTM: blocks of mLSTM with interleaved sLSTM (ratio a:b)."""

    arch_type: str = "ssm"
    proj_factor: float = 2.0  # d_inner = proj_factor * d_model
    conv_kernel: int = 4
    mlstm_per_unit: int = 7  # xLSTM[7:1]
    slstm_per_unit: int = 1
    chunk_len: int = 64  # chunkwise-parallel mLSTM block length

    @property
    def subquadratic_decode(self) -> bool:
        return True  # recurrent state decode

    @property
    def num_units(self) -> int:
        per = self.mlstm_per_unit + self.slstm_per_unit
        assert self.num_layers % per == 0, (self.num_layers, per)
        return self.num_layers // per

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)


@dataclasses.dataclass(frozen=True)
class HybridConfig(BaseConfig):
    """Zamba2-style: Mamba2 backbone + one shared attention block."""

    arch_type: str = "hybrid"
    ssm_state: int = 64
    mamba_headdim: int = 64
    mamba_expand: int = 2
    conv_kernel: int = 4
    shared_interval: int = 6  # shared attn applied every N mamba layers
    chunk_len: int = 64

    @property
    def subquadratic_decode(self) -> bool:
        return True  # SSM state + a handful of attention caches

    @property
    def num_units(self) -> int:
        return self.num_layers // self.shared_interval

    @property
    def tail_layers(self) -> int:
        """Mamba layers left over after the last shared-attention unit."""
        return self.num_layers % self.shared_interval

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // self.mamba_headdim


@dataclasses.dataclass(frozen=True)
class EncDecConfig(BaseConfig):
    """Whisper-style encoder-decoder; conv/mel frontend is a stub that
    provides precomputed frame embeddings."""

    arch_type: str = "audio"
    num_encoder_layers: int = 2
    encoder_frames: int = 1500  # encoder positions fed by the stub frontend
    frontend_dim: int = 128  # stub frame-embedding dim

    @property
    def subquadratic_decode(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class VLMConfig(BaseConfig):
    """Phi-3-vision-style: language decoder consuming stub patch embeds."""

    arch_type: str = "vlm"
    num_patches: int = 576
    vision_dim: int = 1024  # stub patch-embedding dim (pre-projector)


def dtype_of(name: str):
    import jax.numpy as jnp

    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
