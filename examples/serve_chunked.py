"""Serving on the chunked runtime, both planes:

1. **Compiled**: prefill a prompt batch, then greedy-decode continuation
   tokens, with params living in ZeRO chunk stores gathered per layer
   (weight-offloaded inference).
2. **Chunk-managed (eager)**: the same decoding through
   :class:`~repro.core.serving.ServingEngine`, where the KV caches are a
   managed chunk stream in the heterogeneous pool — requests arrive
   staggered, queue when the budget is full, spill cold KV to host, and
   free their chunks the moment they complete (continuous batching).
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.core.serving import ServingEngine
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def compiled_demo(cfg):
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, _ = driver.init_state(rt, jax.random.key(0))

    B, S, new_tokens = 4, 16, 8
    horizon = S + new_tokens
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    # decode path sized to the horizon; replay the prompt then continue
    shape = InputShape("serve", horizon, B, "decode")
    dec, _ = driver.build_decode_step(rt, shape)
    caches = driver.init_caches(rt, shape)
    tok = prompts[:, :1]
    seqs = [np.asarray(prompts)]
    for i in range(horizon - 1):
        nxt, caches = dec(ps, caches,
                          prompts[:, i:i + 1] if i < S else tok,
                          jnp.int32(i))
        if i >= S - 1:
            tok = nxt[:, None].astype(jnp.int32)
            seqs.append(np.asarray(tok))
    out = np.concatenate(seqs, axis=1)
    print("compiled prompt + continuation token ids:")
    for row in out:
        print(" ", row.tolist())
    assert out.shape == (B, S + new_tokens)


def chunk_managed_demo(cfg):
    horizon, new_tokens = 40, 8
    eng = ServingEngine(model_class(cfg), cfg,
                        device_memory_bytes=1_200_000,  # < param stream!
                        host_memory_bytes=8_000_000,
                        max_seq_len=horizon, seed=0)
    print(f"\nchunk-managed serving: device budget "
          f"{eng.device_capacity/1e6:.1f}MB vs param stream "
          f"{eng._param_stream_bytes/1e6:.1f}MB "
          f"+ {eng.kv_seq_bytes/1e3:.0f}KB KV per sequence")
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (6, 12), 0, cfg.vocab_size))
    # staggered arrivals: two requests join mid-flight (continuous
    # batching admits them while earlier sequences keep decoding)
    rids = [eng.submit(p, new_tokens) for p in prompts[:4]]
    for _ in range(2):
        m = eng.step_round()
        print(f"  round {m.round_index}: active={m.active} "
              f"queued={m.queued} tokens={m.tokens} "
              f"spill d2h={m.d2h_bytes/1e3:.0f}KB "
              f"prefetch hits={m.prefetch_hits}")
    rids += [eng.submit(p, new_tokens) for p in prompts[4:]]
    for m in eng.run():
        print(f"  round {m.round_index}: active={m.active} "
              f"queued={m.queued} tokens={m.tokens} "
              f"spill d2h={m.d2h_bytes/1e3:.0f}KB "
              f"prefetch hits={m.prefetch_hits}")
    print("generated token ids:")
    for rid in rids:
        print(f"  req {rid}: {eng.result(rid)}")
    eng.check_invariants()
    st = eng.pool.stats
    print(f"pool: h2d {st.h2d_bytes/1e6:.1f}MB, d2h {st.d2h_bytes/1e6:.1f}MB, "
          f"peak device {eng.pool.peak_device_bytes/1e6:.2f}MB "
          f"(budget {eng.device_capacity/1e6:.1f}MB), "
          f"prefetch hit-rate {eng.pool.prefetch.hit_rate:.0%}")


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    compiled_demo(cfg)
    chunk_managed_demo(cfg.replace(param_dtype="float32",
                                   compute_dtype="float32"))


if __name__ == "__main__":
    main()
