"""Batched serving on the chunked runtime: prefill a prompt batch, then
greedy-decode continuation tokens, with params living in ZeRO chunk
stores gathered per layer (weight-offloaded inference)."""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, _ = driver.init_state(rt, jax.random.key(0))

    B, S, new_tokens = 4, 16, 8
    horizon = S + new_tokens
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    # decode path sized to the horizon; replay the prompt then continue
    shape = InputShape("serve", horizon, B, "decode")
    dec, _ = driver.build_decode_step(rt, shape)
    caches = driver.init_caches(rt, shape)
    tok = prompts[:, :1]
    seqs = [np.asarray(prompts)]
    for i in range(horizon - 1):
        nxt, caches = dec(ps, caches,
                          prompts[:, i:i + 1] if i < S else tok,
                          jnp.int32(i))
        if i >= S - 1:
            tok = nxt[:, None].astype(jnp.int32)
            seqs.append(np.asarray(tok))
    out = np.concatenate(seqs, axis=1)
    print("prompt + continuation token ids:")
    for row in out:
        print(" ", row.tolist())
    assert out.shape == (B, S + new_tokens)


if __name__ == "__main__":
    main()
