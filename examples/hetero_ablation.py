"""Paper Fig. 10 — situations where a static partition fails but
PatrickStar's dynamic chunk management trains anyway.

Left case: params + activations exceed the device; PatrickStar spills
chunks mid-iteration.  Right case: host is too small for all OS; margin
space on the device absorbs the overflow (device-aware placement)."""

import jax.numpy as jnp

from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.manager import OutOfMemory
from repro.data.pipeline import make_batch_fn


def batch(cfg):
    nxt = make_batch_fn(cfg, 4, 64)
    return {k: jnp.asarray(v) for k, v in nxt().items() if k != "mask"}


def main():
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=6, param_dtype="float32", compute_dtype="float32")

    # ---- GPU-too-small case ----------------------------------------------
    tight_dev = 2_600_000  # < param stream (so a static layout cannot fit)
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=tight_dev)
    # size from the stream's real chunk bytes (cmap capacity x the
    # manager dtype), not a hardcoded fp32 itemsize
    need = eng.cmap.num_chunks * eng.params_mgr.chunk_bytes
    print(f"param stream {need/1e6:.1f}MB vs device {tight_dev/1e6:.1f}MB "
          f"-> static partition would OOM")
    m = eng.step(batch(cfg))
    print(f"PatrickStar trains anyway: loss={m.loss:.3f}, "
          f"moved {m.moved_bytes/1e6:.1f}MB across tiers")

    # ---- CPU-too-small case ----------------------------------------------
    dev = 24_000_000
    host = int(need * 2.0)  # host can't hold all 3 OS streams
    eng2 = PatrickStarEngine(model_class(cfg), cfg,
                             device_memory_bytes=dev,
                             host_memory_bytes=host)
    m2 = eng2.step(batch(cfg))
    m2 = eng2.step(batch(cfg))
    print(f"host-constrained case: loss={m2.loss:.3f}; "
          f"OS groups on device (margin space): "
          f"{eng2.placement.os_device_groups}/{eng2.placement.num_local_groups}")


if __name__ == "__main__":
    main()
