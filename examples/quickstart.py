"""Quickstart — the paper's Listing 1 on the eager PatrickStar engine.

    PYTHONPATH=src python examples/quickstart.py

Trains a small GPT on a simulated 4 MB "GPU" next to a host tier,
exercising the full chunk machinery: warm-up tracing, OPT eviction,
device-aware OS placement, grad-fp16 chunk reuse.
"""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, model_class
from repro.core.engine import initialize_engine
from repro.data.pipeline import make_batch_fn


def main():
    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")

    # ----- paper Listing 1 -------------------------------------------------
    model, optimizer = initialize_engine(
        model_func=lambda: (model_class(cfg), cfg),
        config={"device_memory_bytes": 4_000_000, "policy": "opt",
                "lr": 1e-2})

    next_batch = make_batch_fn(cfg, 4, 64)
    for step in range(8):
        batch = {k: jnp.asarray(v) for k, v in next_batch().items()
                 if k != "mask"}
        optimizer.zero_grad()
        loss = model(batch)
        model.backward(loss)
        optimizer.step()
        m = model._metrics
        print(f"step {step}: loss={model.loss:.4f} "
              f"moved={m.moved_bytes/1e6:.2f}MB "
              f"(fwd {m.fwd_s*1e3:.0f}ms bwd {m.bwd_s*1e3:.0f}ms "
              f"adam {m.adam_s*1e3:.0f}ms)")
    eng = model._eng
    print("\nchunk map:", eng.cmap.num_chunks, "chunks x",
          eng.cmap.chunk_size, "elems, utilization",
          f"{eng.cmap.utilization:.2%}")
    print("placement plan:", eng.placement)
    assert np.isfinite(model.loss)


if __name__ == "__main__":
    main()
