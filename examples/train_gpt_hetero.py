"""End-to-end driver: chunked-ZeRO training of a GPT on the compiled
runtime (deliverable b): synthetic data pipeline -> shard_map train step
-> chunked Adam -> checkpoint.

Default is a CPU-sized run; the full assignment-scale command is

    PYTHONPATH=src python examples/train_gpt_hetero.py \
        --layers 12 --d-model 768 --steps 300 --batch 8 --seq 512 \
        --dp 2 --tp 2            # ~100M params, a few hundred steps
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--checkpoint", default="/tmp/repro_gpt_ck")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.dp * args.tp} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import make_batch_fn
    from repro.launch.mesh import make_smoke_mesh
    from repro.models.transformer import TransformerLM
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    heads = max(args.d_model // 64, 4)
    cfg = get_config("gpt2-paper-1b").replace(
        name="gpt-example", num_layers=args.layers, d_model=args.d_model,
        n_heads=heads, n_kv_heads=heads, head_dim=64, d_ff=4 * args.d_model,
        vocab_size=50304)
    mesh = make_smoke_mesh(args.dp, args.tp)
    rt = ChunkedRuntime(TransformerLM, cfg, mesh,
                        RuntimeOptions(lr=3e-4, xent_block=1024))
    n = sum(int(jnp.prod(jnp.asarray(s.shape)))
            for s in jax.tree.leaves(rt.model.param_specs())) * args.tp
    print(f"params ~{n/1e6:.1f}M  mesh={dict(mesh.shape)}  "
          f"chunk layouts: "
          f"{[(k, v.store_shape) for k, v in rt.layouts.items()]}")

    shape = InputShape("train", args.seq, args.batch, "train")
    step_fn, _, _ = driver.build_train_step(rt, shape)
    ps, oss = driver.init_state(rt, jax.random.key(0))
    next_batch = make_batch_fn(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next_batch().items()
                 if k != "mask"}
        ps, oss, m = step_fn(ps, oss, batch, jnp.int32(step))
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.perf_counter()-t0)/(step+1)*1e3:.0f} ms/step avg)")
    ckpt.save(rt, ps, oss, args.checkpoint, step=args.steps)
    print("checkpoint saved to", args.checkpoint)


if __name__ == "__main__":
    main()
