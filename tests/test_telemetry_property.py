"""Hypothesis property tests for the telemetry plane: under random
multi-stream / multi-tenant chunk traffic with a (bandwidth-aware)
prefetcher and a transfer timeline attached, the event log conserves
everything it mirrors — per-lane byte totals equal the ``TransferStats``
counters (globally AND per tenant), event-derived stall seconds equal
the timeline's whole-run ledger and the ``StepTimeline`` lanes
bit-for-bit, prefetch lifecycle counts match, and span events always
nest (every begin has a matching end, no interleaving within a track)."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.analysis import tracereport
from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, OutOfMemory, SchedulePrefetcher
from repro.core.state import TensorState
from repro.core.telemetry import MOVE_LANES, Telemetry
from repro.core.timeline import TransferTimeline

SIZE = 8  # elements per tensor == per chunk (one tensor per chunk)
CB = SIZE * 4  # chunk bytes (fp32)


@st.composite
def telemetry_traffic(draw):
    n = draw(st.integers(2, 6))
    n_streams = draw(st.integers(1, 3))
    ops = draw(st.lists(
        st.tuples(st.integers(0, n_streams - 1), st.integers(0, n - 1),
                  st.sampled_from(["hold", "free"])),
        min_size=5, max_size=60))
    policy = draw(st.sampled_from(["opt", "lru", "fifo"]))
    device_chunks = draw(st.integers(1, n * n_streams))
    bw = lambda: draw(st.one_of(
        st.none(), st.floats(1.0, 1e6, allow_nan=False, allow_infinity=False)))
    h2d_bw, d2h_bw = bw(), bw()
    durations = draw(st.lists(
        st.floats(0.0, 2.0, allow_nan=False, allow_infinity=False),
        min_size=len(ops), max_size=len(ops)))
    aware = draw(st.booleans())
    two_tenants = draw(st.booleans())
    return n, n_streams, ops, policy, device_chunks, h2d_bw, d2h_bw, \
        durations, aware, two_tenants


def _run(n, n_streams, ops, policy, device_chunks, h2d_bw, d2h_bw,
         durations, aware, two_tenants):
    """Replay one traffic sequence through a hub-attached pool; odd
    streams belong to a second (higher-priority) tenant when drawn."""
    hub = Telemetry()
    streams = [f"s{i}" for i in range(n_streams)]
    specs = [TensorSpec(f"t{i}", (SIZE,)) for i in range(n)]
    cmap = build_chunk_map(specs, SIZE)
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * CB,
        host_capacity_bytes=(n * n_streams + 2) * CB, policy=policy)
    pool.set_telemetry(hub)
    tl = TransferTimeline(h2d_bandwidth=h2d_bw, d2h_bandwidth=d2h_bw)
    pool.set_timeline(tl)
    serve = (pool.create_tenant("serve", priority=5)
             if two_tenants and n_streams > 1 else None)
    mgrs = {}
    for i, s in enumerate(streams):
        if serve is not None and i % 2 == 1:
            mgrs[s] = ChunkManager(cmap, name=s, pool=pool, tenant=serve)
        else:
            mgrs[s] = ChunkManager(cmap, name=s, pool=pool)
    per_stream: dict[str, dict[int, list[int]]] = {}
    refs = []
    for m, (s_idx, t_idx, _rel) in enumerate(ops):
        name = mgrs[streams[s_idx]].name
        per_stream.setdefault(name, {}).setdefault(t_idx, []).append(m)
        refs.append((m, name, t_idx))
    for s, sched in per_stream.items():
        pool.register_moments(s, sched)
    tl.install_durations({m: d for m, d in enumerate(durations) if d > 0})
    pf = SchedulePrefetcher(pool, lookahead=4, max_inflight=2,
                            timeline=tl if aware else None)
    pf.install(refs)
    hub.begin_span("traffic", "run", ts=tl.now)
    for m, (s_idx, t_idx, rel) in enumerate(ops):
        mgr = mgrs[streams[s_idx]]
        pool.set_moment(m)
        pf.advance(m)
        hub.switch_span("ops", f"m{m}", ts=tl.now, moment=m)
        try:
            mgr.access_tensor(f"t{t_idx}")
        except OutOfMemory:
            break
        mgr.release_tensor(
            f"t{t_idx}",
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
    pool.check_invariants()
    rep = tl.take_step()
    hub.close_span("ops", ts=tl.now)
    hub.end_span("traffic", ts=tl.now)
    return pool, tl, hub, rep


@given(telemetry_traffic())
@settings(max_examples=40, deadline=None)
def test_event_bytes_equal_counters_globally_and_per_tenant(t):
    """Per-lane event byte/count totals == TransferStats, exactly —
    for the pool and for every tenant's accounting mirror."""
    pool, _tl, hub, _rep = _run(*t)
    hub.assert_conservation()
    lane = hub.lane_bytes()
    assert lane["h2d"] == pool.stats.h2d_bytes
    assert lane["d2h"] == pool.stats.d2h_bytes
    for name, tenant in pool.tenants.items():
        for ln in MOVE_LANES:
            got = sum(ev.nbytes for ev in hub.events
                      if ev.kind == "move" and ev.name == ln
                      and ev.tenant == name)
            assert got == getattr(tenant.stats, f"{ln}_bytes"), (name, ln)


@given(telemetry_traffic())
@settings(max_examples=40, deadline=None)
def test_event_stalls_equal_timeline_ledgers_exactly(t):
    """Event-derived stall seconds == the timeline's whole-run ledger ==
    the StepTimeline lanes, bit-for-bit (identical left-folds of the
    same float sequence — no tolerance)."""
    pool, tl, hub, rep = _run(*t)
    stalls = hub.stall_totals()
    assert stalls == tl.total_stalls
    # one step taken => whole-run totals ARE the step's lanes
    assert stalls["h2d"] == rep.h2d_stall_s
    assert stalls["d2h"] == rep.d2h_stall_s
    assert stalls["coll"] == rep.gather_stall_s
    if tl.h2d.bandwidth is None and tl.d2h.bandwidth is None:
        assert sum(stalls.values()) == 0.0


@given(telemetry_traffic())
@settings(max_examples=40, deadline=None)
def test_spans_nest_and_trace_validates(t):
    """Every span begin has a matching end with no interleaving, and the
    exported Chrome trace passes structural validation (monotone
    timestamps per track, balanced B/E, byte conservation)."""
    _pool, _tl, hub, _rep = _run(*t)
    hub.assert_balanced_spans()
    assert not hub.open_spans()
    tracereport.validate(hub.chrome_trace())
    counts = hub.prefetch_counts()
    assert counts["issue"] == _pool.prefetch.staged_transfers
    assert counts["hit"] == _pool.prefetch.hits
    assert counts["miss"] == _pool.prefetch.demand_misses
    assert counts["stale"] == _pool.prefetch.wasted_stages
