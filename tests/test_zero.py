"""Chunked ZeRO store: flatten/unflatten, gather, grad reduce-scatter."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import zero
from repro.core.tracer import RuntimeMemoryTracer
from repro.models.layers import shard_map_compat


@st.composite
def trees(draw):
    n = draw(st.integers(1, 8))
    tree = {}
    for i in range(n):
        shape = tuple(draw(st.lists(st.integers(1, 6), min_size=1, max_size=3)))
        tree[f"w{i}"] = np.arange(int(np.prod(shape)), dtype=np.float32).reshape(shape) + i
    return tree


@given(trees(), st.sampled_from([1, 2, 4]))
@settings(max_examples=60, deadline=None)
def test_flatten_roundtrip(tree, nproc):
    largest = max(v.size for v in tree.values())
    layout = zero.make_layout(tree, nproc=nproc, dtype=jnp.float32,
                              chunk_size=max(largest, 8))
    store = zero.flatten_to_store(layout, tree)
    assert store.shape == layout.store_shape
    back = zero.unflatten_from_store(layout, store)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), tree[k])


def test_gather_and_grad_reduce_scatter():
    """all_gather fetch + autodiff reduce-scatter = paper Section 7."""
    from repro.launch.mesh import _mesh

    mesh = _mesh((4,), ("data",))
    tree = {"a": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
            "b": jnp.ones((5,), jnp.float32)}
    layout = zero.make_layout(tree, nproc=4, dtype=jnp.float32, chunk_size=32)
    store = zero.flatten_to_store(layout, tree)

    def step(local):
        def loss(l):
            params = zero.gather_params(layout, l, "data")
            return sum(jnp.sum(x**2) for x in jax.tree.leaves(params))
        val, g = jax.value_and_grad(loss)(local)
        return jax.lax.psum(val, "data") / 4.0, g

    f = jax.jit(shard_map_compat(
        step, mesh=mesh, in_specs=(P(None, "data", None),),
        out_specs=(P(), P(None, "data", None)), check_vma=True))
    val, g = f(store)
    # every rank computes the same loss; grads reduce-scatter to 4x2x (4
    # identical rank contributions summed onto the owner's shard)
    assert np.allclose(float(val), sum(float(jnp.sum(x**2)) for x in tree.values()))
    np.testing.assert_allclose(np.asarray(g), 4 * 2 * np.asarray(store), rtol=1e-6)
    txt = jax.jit(f).lower(store).compile().as_text()
    assert txt.count("all-gather") >= 1
    assert txt.count("reduce-scatter") >= 1


def test_comm_volume_model():
    tree = {"w": jnp.zeros((64, 64))}
    layout = zero.make_layout(tree, nproc=8, dtype=jnp.bfloat16, chunk_size=4096)
    vol = zero.comm_volume_bytes(layout)
    m = 64 * 64 * 2
    assert vol["params_bytes"] == m
    assert abs(vol["chunked_allgather_bytes"] - 3 * (7 / 8) * m) < 1e-6
    # paper: broadcast-based baseline moves 10/6x more
    assert vol["broadcast_baseline_bytes"] > vol["chunked_allgather_bytes"] * 1.6


def test_split_merge_groups():
    store = jnp.arange(2 * 3 * 4 * 8, dtype=jnp.float32).reshape(2, 3, 4, 8)
    # [L=2, G=3, p=4, S=8]
    dev, host = zero.split_groups(store, 2)
    assert dev.shape == (2, 2, 4, 8) and host.shape == (2, 1, 4, 8)
    np.testing.assert_array_equal(np.asarray(zero.merge_groups(dev, host)),
                                  np.asarray(store))


def test_tracer_and_margin():
    tr = RuntimeMemoryTracer(1000, warmup_chunk_fraction=0.2)
    tr.begin_iteration()
    assert tr.chunkable_memory() == 200  # warm-up cap
    for i, nm in enumerate([100, 300, 250]):
        tr.record_moment(f"op{i}", "FWD", nm)
        tr.record_chunk_use(i % 2)
    tr.end_warmup()
    assert tr.peak_nonmodel_bytes == 300
    assert tr.chunkable_memory(0) == 900
    assert tr.chunkable_memory(1) == 700
    assert tr.margin_space(100) == 1000 - 300 - 100
    sched = tr.schedule()
    assert sched[0] == [0, 2] and sched[1] == [1]
