"""Analytical cost model sanity: scaling laws and option effects."""

import pytest

from repro.analysis import costmodel
from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, InputShape


def _terms(arch, shape_name, **kw):
    cfg = get_config(arch)
    return costmodel.analyze_pair(cfg, INPUT_SHAPES[shape_name],
                                  dp=16, tp=16, pods=1, **kw)


def test_flops_scale_with_tokens():
    a = _terms("deepseek-7b", "train_4k")
    small = costmodel.analyze_pair(get_config("deepseek-7b"),
                                   InputShape("half", 2048, 256, "train"),
                                   dp=16, tp=16)
    assert 1.7 < a.flops / small.flops < 2.4  # ~linear + attention superlinear


def test_train_costs_more_than_prefill():
    t = _terms("deepseek-7b", "train_4k")
    p = _terms("deepseek-7b", "prefill_32k")
    # per token: train = fwd+bwd+refwd = ~4x prefill's fwd
    tok_t = 256 * 4096
    tok_p = 32 * 32768
    assert (t.flops / tok_t) > 2.5 * (p.flops / tok_p)


def test_decode_is_tiny():
    d = _terms("deepseek-7b", "decode_32k")
    t = _terms("deepseek-7b", "train_4k")
    assert d.flops < t.flops / 100


def test_combine_first_cuts_moe_collective():
    base = _terms("deepseek-v2-lite-16b", "train_4k")
    opt = _terms("deepseek-v2-lite-16b", "train_4k", ep_combine_first=True)
    assert opt.collective_bytes < base.collective_bytes * 0.5
    assert opt.flops == base.flops  # math unchanged


def test_dots_remat_cuts_compute():
    base = _terms("deepseek-7b", "train_4k")
    dots = _terms("deepseek-7b", "train_4k", remat="dots")
    assert abs(dots.flops / base.flops - 0.75) < 0.02  # 3x vs 4x fwd-units


def test_pod_axis_adds_grad_psum():
    one = _terms("qwen3-0.6b", "train_4k")
    two = costmodel.analyze_pair(get_config("qwen3-0.6b"),
                                 INPUT_SHAPES["train_4k"], dp=16, tp=16,
                                 pods=2)
    assert two.pod_bytes > 0 and one.pod_bytes == 0


def test_param_bytes_match_layouts():
    """The cost model's parameter count agrees with the real chunk layouts
    (payload bytes per model-rank) within packing tolerance."""
    import jax

    from repro.configs import model_class
    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = get_config("qwen3-0.6b")
    est = costmodel._param_bytes_local(cfg, 16)
    # build the real tp=16-shaped layout cheaply via eval_shape specs:
    # per-rank payload elems x2 bytes
    from repro.models.layers import AxisCtx
    model = model_class(cfg)(cfg, AxisCtx(model_axis="model", tp=16,
                                          data_axis="data", dp=16))
    specs = model.param_specs()
    import numpy as np
    real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(specs)) * 2
    assert abs(est - real) / real < 0.05, (est, real)
