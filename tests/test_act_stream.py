"""Activation chunk stream (the fifth managed stream): differential
parity (placement-only change), lifecycle, mid-step spill/restage, honest
margin accounting, strict-budget batch headroom, and p=2 distributed
parity with the stream enabled."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.distributed import DistributedPatrickStarEngine
from repro.core.engine import PatrickStarEngine
from repro.core.memory import OutOfMemory
from repro.core.state import ChunkState, TensorState


def _cfg(**over):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32", **over)


def _batch(cfg, b=4, s=32, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def _engine(cfg, budget, act, **kw):
    return PatrickStarEngine(model_class(cfg), cfg,
                             device_memory_bytes=budget,
                             manage_activations=act, **kw)


# ---------------------------------------------------------------------------
# differential: the act stream never changes the math
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["opt", "lru", "fifo"])
@pytest.mark.parametrize("budget", [2_500_000, 16_000_000])
def test_act_on_off_loss_parity(policy, budget):
    """Eager losses with activation offload on vs off agree to <= 1e-6 on
    every step, on tight and loose budgets, under all three eviction
    policies (the stream changes where checkpointed inputs LIVE, never
    what is computed)."""
    cfg = _cfg()
    batch = _batch(cfg)
    losses = {}
    for act in (True, False):
        eng = _engine(cfg, budget, act, policy=policy, lr=1e-2)
        losses[act] = [eng.step(batch).loss for _ in range(3)]
        eng.pool.check_invariants()
    for a, b in zip(losses[True], losses[False]):
        assert abs(a - b) <= 1e-6, (losses[True], losses[False])


def test_act_lifecycle_written_read_freed():
    """Every act chunk is written once in FWD, read once at the mirrored
    BWD layer, then freed: after a step the act stream holds zero bytes
    and every act tensor is FREE; the stream never grows ADAM/fp32
    companions."""
    cfg = _cfg()
    eng = _engine(cfg, 8_000_000, True)
    eng.step(_batch(cfg))
    assert eng.act_mgr is not None
    # one chunk per checkpointed layer input
    n_layers = sum(g.length for g in eng.model.groups())
    assert eng.act_cmap.num_payload_chunks == n_layers
    # all consumed and released...
    assert eng.act_mgr.device_bytes_used() == 0
    assert eng.act_mgr.host_bytes_used() == 0
    # ...but the stream really was device-resident during the step: the
    # per-stream high-water mark proves the footprint the margin
    # accounting reserves for is real
    assert eng.act_mgr.peak_device_bytes() >= eng.act_mgr.chunk_bytes
    assert eng.act_mgr.peak_device_bytes() <= eng.pool.peak_device_bytes
    for c in range(eng.act_cmap.num_chunks):
        assert eng.act_mgr.chunk_state(c) is ChunkState.FREE
    # rank-local layout: no communication groups beyond the trivial ones
    assert eng.act_cmap.nproc == 1
    # the fifth stream registers alongside the four model-data streams
    assert set(eng.pool.streams) == {"param", "p32", "m", "v", "act"}
    # no optimizer state exists for activations (nothing to check beyond
    # the stream set: os_mgrs is exactly the three OS streams)
    assert set(eng.os_mgrs) == {"p32", "m", "v"}


def test_act_chunks_spill_and_restage_mid_step():
    """On a tight budget, cold activation chunks must spill to host
    between their FWD write and BWD read (the FWD->BWD reuse distance the
    tracer exposes), and the post-warm-up prefetcher must stage act
    chunks back ahead of backward_layer (hidden H2D on the act stream)."""
    cfg = _cfg(num_layers=4)
    eng = _engine(cfg, 2_500_000, True, policy="opt")
    batch = _batch(cfg, b=8)
    eng.step(batch)  # warm-up
    d2h0, h2d0 = eng.act_mgr.stats.d2h_bytes, eng.act_mgr.stats.h2d_bytes
    eng.step(batch)
    d2h = eng.act_mgr.stats.d2h_bytes - d2h0
    h2d = eng.act_mgr.stats.h2d_bytes - h2d0
    assert d2h > 0, "no act chunk ever spilled despite the tight budget"
    assert h2d > 0, "spilled act chunks never came back for their BWD read"
    # per-stream peaks stay within the shared budget's high-water mark
    assert 0 < eng.act_mgr.peak_device_bytes() <= eng.pool.peak_device_bytes
    # losses still match the unmanaged baseline exactly
    base = _engine(cfg, 2_500_000, False, policy="opt")
    base.step(batch)
    m_base = base.step(batch)
    eng2 = _engine(cfg, 2_500_000, True, policy="opt")
    eng2.step(batch)
    m_act = eng2.step(batch)
    assert abs(m_base.loss - m_act.loss) <= 1e-6


def test_act_schedule_reaches_opt_and_prefetcher():
    """The warm-up must record act-chunk reference moments (FWD write +
    mirrored BWD read) and install them for OPT eviction and staging."""
    cfg = _cfg()
    eng = _engine(cfg, 8_000_000, True)
    eng.step(_batch(cfg))
    sched = eng.tracer.schedule_by_stream().get("act", {})
    n_layers = sum(g.length for g in eng.model.groups())
    assert len(sched) == n_layers
    for chunk_id, moments in sched.items():
        assert len(moments) == 2, (chunk_id, moments)  # write + read
        assert moments[0] < moments[1]
    # reverse order: the first-written act chunk is read LAST (the
    # longest reuse distance — the best eviction victim mid-FWD)
    writes = sorted((ms[0], c) for c, ms in sched.items())
    reads = sorted((ms[1], c) for c, ms in sched.items())
    assert [c for _, c in writes] == [c for _, c in reads][::-1]
    # and the pool's OPT view consumes them
    assert eng.pool._moments.get("act")


def test_placement_reserves_act_working_set():
    """plan_placement carves the act working set out of the margin before
    OS groups claim it: with the stream on, never MORE margin-placed OS
    groups than with it off."""
    cfg = _cfg()
    plans = {}
    for act in (True, False):
        eng = _engine(cfg, 16_000_000, act)
        eng.step(_batch(cfg))
        plans[act] = eng.placement
    assert plans[True].act_reserved_bytes > 0
    assert plans[False].act_reserved_bytes == 0
    assert plans[True].os_device_groups <= plans[False].os_device_groups


def test_strict_budget_act_stream_buys_batch_headroom():
    """Under strict_device_budget a batch whose unmanaged activation
    footprint exceeds the device budget OOMs with the stream off but
    trains with it on — the max_batch.py acceptance in miniature."""
    cfg = _cfg(num_layers=4)
    budget = 6_000_000
    big = _batch(cfg, b=28, s=64)

    eng_off = _engine(cfg, budget, False, strict_device_budget=True)
    with pytest.raises(OutOfMemory):
        for _ in range(2):
            eng_off.step(big)

    eng_on = _engine(cfg, budget, True, strict_device_budget=True)
    mets = [eng_on.step(big) for _ in range(2)]
    assert all(np.isfinite(m.loss) for m in mets)
    assert eng_on.pool.peak_device_bytes <= budget


def test_batch_shape_change_retraces_and_rebuilds_act_stream():
    """A batch-shape change invalidates the warm-up profile and the act
    chunk layout: the engine must re-trace (fresh OPT/prefetch schedules,
    fresh act layout sized to the new batch) instead of running the new
    shape against the old batch's statistics."""
    cfg = _cfg()
    eng = _engine(cfg, 8_000_000, True)
    small = _batch(cfg, b=2)
    big = _batch(cfg, b=8)
    eng.step(small)
    assert not eng.tracer.warmup
    numel_small = eng._act_numel
    m = eng.step(big)  # re-warm-up: retrace + act rebuild
    assert np.isfinite(m.loss)
    assert not eng.tracer.warmup
    assert eng._act_numel == 4 * numel_small
    sched = eng.tracer.schedule_by_stream().get("act", {})
    n_layers = sum(g.length for g in eng.model.groups())
    assert len(sched) == n_layers  # act schedule re-formed for the new shape
    assert all(len(ms) == 2 for ms in sched.values())
    m2 = eng.step(big)  # and the re-traced profile drives the next step
    assert np.isfinite(m2.loss)
    eng.pool.check_invariants()


def test_dual_tight_budgets_degrade_gracefully():
    """Fig. 10's dual-constrained corner (host too small for all OS, so
    init spills push the device over its dynamic budget): the act stream
    must refuse management up-front and hold inputs live — the engine
    trains anyway, exactly like the unmanaged baseline."""
    cfg = _cfg(num_layers=6)
    probe = _engine(cfg, 24_000_000, False)
    host = probe.cmap.capacity * 4 * 2  # host holds only 2 of 4 streams
    losses = {}
    for act in (True, False):
        eng = PatrickStarEngine(
            model_class(cfg), cfg, device_memory_bytes=24_000_000,
            host_memory_bytes=host, manage_activations=act)
        batch = _batch(cfg, b=4, s=64)
        losses[act] = [eng.step(batch).loss for _ in range(2)]
        eng.pool.check_invariants()
    for a, b in zip(losses[True], losses[False]):
        assert abs(a - b) <= 1e-6, (losses[True], losses[False])


# ---------------------------------------------------------------------------
# distributed: act stream is rank-local and parity still holds
# ---------------------------------------------------------------------------


def test_p2_parity_with_act_stream():
    """p=2 lock-step parity (test_distributed_engine's acceptance) holds
    with the act stream enabled, collective volume stays EXACTLY the
    analytic figure (act chunks never enter the collective plane), and
    each rank owns a private act stream."""
    from repro.core import zero

    cfg = _cfg()
    batch = _batch(cfg)
    single = PatrickStarEngine(model_class(cfg), cfg,
                               device_memory_bytes=4_000_000, lr=1e-2,
                               manage_activations=True)
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000,
                                        lr=1e-2, manage_activations=True)
    g = dist.cmap.num_comm_groups
    cb = dist.ranks[0].params_mgr.chunk_bytes
    exact = 3 * (dist.nproc - 1) * g * cb
    for step in range(3):
        ms = single.step(batch)
        md = dist.step(batch)
        assert abs(ms.loss - md.loss) < 1e-4, (step, ms.loss, md.loss)
        assert md.chunk_collective_bytes == exact
    dist.check_invariants()
    for core in dist.ranks:
        assert core.act_mgr is not None
        # rank-local: the act layout has no multi-rank comm groups and
        # holds nothing between steps
        assert core.act_cmap.nproc == 1
        assert core.act_mgr.device_bytes_used() == 0
        assert core.act_mgr.host_bytes_used() == 0


def test_p2_act_on_off_loss_parity():
    cfg = _cfg()
    batch = _batch(cfg)
    losses = {}
    for act in (True, False):
        dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                            device_memory_bytes=4_000_000,
                                            manage_activations=act)
        losses[act] = [dist.step(batch).loss for _ in range(3)]
    for a, b in zip(losses[True], losses[False]):
        assert abs(a - b) <= 1e-6, (losses[True], losses[False])
