"""Paged ``DynamicChunkMap``: page math, slot-range id reservation, and
free-list recycling under admission/retirement churn.

The property test models the compiled serving plane's traffic shape:
"slots" reserve fixed page-id ranges and pin their page tensors into
them with explicit ids, while "background" tensors use default
allocation — the invariant under any interleaving is that default
allocation and recycling NEVER hand out an id inside a live (or even
retired) slot's reserved range.  Runs under hypothesis when installed
(CI), and always as a seeded-random driver.
"""

import itertools
import random

import pytest

from repro.core.chunk import (
    ChunkMapError,
    DynamicChunkMap,
    TensorSpec,
    build_kv_chunk_map,
    pages_for,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without dev extras: seeded driver only
    HAVE_HYPOTHESIS = False


LAYERS = 2
PAGES_PER_SLOT = 3
SLOT_W = LAYERS * PAGES_PER_SLOT


def test_pages_for_math():
    dm = DynamicChunkMap(64, page_tokens=8)
    assert dm.pages_for(0) == 1  # a sequence always holds >= 1 page
    assert dm.pages_for(1) == 1
    assert dm.pages_for(8) == 1
    assert dm.pages_for(9) == 2
    assert dm.pages_for(64) == 8
    unpaged = DynamicChunkMap(64)
    assert unpaged.pages_for(10_000) == 1
    assert pages_for(17, 8) == 3
    assert pages_for(17, None) == 1
    assert build_kv_chunk_map(100, page_tokens=4).page_tokens == 4
    with pytest.raises(ChunkMapError):
        DynamicChunkMap(64, page_tokens=0)


def test_reserved_ids_interop_with_default_allocation():
    dm = DynamicChunkMap(16, page_tokens=4)
    dm.reserve_ids(range(0, 6))
    dm.reserve_ids([2, 3])  # idempotent
    # default allocation skips the reserved range entirely
    assert dm.add_tensor(TensorSpec("a", (8,))).chunk_id == 6
    # explicit pin binds into it
    assert dm.add_tensor(TensorSpec("s0.p0", (8,)), chunk_id=0).chunk_id == 0
    # a freed reserved id is NOT recycled to default allocation ...
    dm.remove_tensor("s0.p0")
    assert dm.add_tensor(TensorSpec("b", (8,))).chunk_id == 7
    # ... but an explicit re-pin reuses it
    assert dm.add_tensor(TensorSpec("s0.p0b", (8,)), chunk_id=0).chunk_id == 0
    # a live chunk cannot be reserved
    with pytest.raises(ChunkMapError):
        dm.reserve_ids([6])
    with pytest.raises(ChunkMapError):
        dm.reserve_ids([-1])
    # freed unreserved ids still recycle LIFO as before
    dm.remove_tensor("b")
    assert dm.add_tensor(TensorSpec("c", (8,))).chunk_id == 7


def test_explicit_pin_above_reserved_high_water():
    dm = DynamicChunkMap(16)
    dm.reserve_ids([1, 3])
    # explicit bind past the high-water mark frees the gap EXCEPT the
    # reserved ids inside it
    dm.add_tensor(TensorSpec("x", (4,)), chunk_id=4)
    assert dm.num_chunks == 5
    got = {dm.add_tensor(TensorSpec(f"d{i}", (4,))).chunk_id
           for i in range(3)}
    assert got == {0, 2, 5}  # 1 and 3 stayed reserved


def _run_trace(choices):
    """Deterministic churn driven by a list of ints: admit slots (reserve
    range + pin prompt pages), append pages, retire slots, and allocate/
    free background tensors, checking invariants after every step."""
    dm = DynamicChunkMap(32, page_tokens=4)
    live = {}  # slot -> (list[name], pages)
    background = []  # names with default-allocated ids
    reserved_slots = set()  # every slot that EVER reserved its range
    serial = itertools.count()

    def check():
        for slot, (names, _pages) in live.items():
            lo, hi = slot * SLOT_W, (slot + 1) * SLOT_W
            for nm in names:
                cid = dm.placement(nm).chunk_id
                assert lo <= cid < hi, (nm, cid, slot)
        for nm in background:
            cid = dm.placement(nm).chunk_id
            for slot in reserved_slots:
                assert not (slot * SLOT_W <= cid < (slot + 1) * SLOT_W), (
                    f"default allocation handed out {cid} inside slot "
                    f"{slot}'s reserved range")
        expect = (sum(len(ns) for ns, _ in live.values()) + len(background))
        assert dm.num_payload_chunks == expect

    for c in choices:
        kind = c % 4
        if kind == 0 or not (live or background):
            # admit: lowest free slot whose range holds no live chunk
            # (reserving over a live default-allocated chunk is a
            # ChunkMapError by design — the engine reserves a slot's
            # range before anything else can squat on it, so the trace
            # models the same discipline)
            bg_ids = {dm.placement(nm).chunk_id for nm in background}
            slot = next(
                s for s in itertools.count()
                if s not in live and not any(
                    s * SLOT_W <= cid < (s + 1) * SLOT_W for cid in bg_ids))
            dm.reserve_ids(range(slot * SLOT_W, (slot + 1) * SLOT_W))
            reserved_slots.add(slot)
            pages = 1 + (c // 4) % PAGES_PER_SLOT
            names = []
            for j in range(LAYERS):
                for p in range(pages):
                    nm = f"kv.{next(serial)}.{slot}.{j}.{p}"
                    pl = dm.add_tensor(
                        TensorSpec(nm, (16,)),
                        chunk_id=slot * SLOT_W + j * PAGES_PER_SLOT + p)
                    assert pl.chunk_id in range(slot * SLOT_W,
                                                (slot + 1) * SLOT_W)
                    names.append(nm)
            live[slot] = (names, pages)
        elif kind == 1 and live:
            # append one page to a slot that has room
            grow = [s for s, (_, p) in live.items() if p < PAGES_PER_SLOT]
            if grow:
                slot = grow[c // 4 % len(grow)]
                names, pages = live[slot]
                for j in range(LAYERS):
                    nm = f"kv.{next(serial)}.{slot}.{j}.{pages}"
                    dm.add_tensor(
                        TensorSpec(nm, (16,)),
                        chunk_id=slot * SLOT_W + j * PAGES_PER_SLOT + pages)
                    names.append(nm)
                live[slot] = (names, pages + 1)
        elif kind == 2 and live:
            # retire a slot: remove every page (ids stay reserved)
            slot = sorted(live)[c // 4 % len(live)]
            names, _ = live.pop(slot)
            for nm in names:
                dm.remove_tensor(nm)
        else:
            # background churn through the default allocator
            if background and (c // 4) % 2:
                dm.remove_tensor(background.pop(c // 8 % len(background)))
            else:
                nm = f"bg.{next(serial)}"
                dm.add_tensor(TensorSpec(nm, (16,)))
                background.append(nm)
        check()


def test_paged_map_seeded_churn():
    for seed in range(20):
        rng = random.Random(seed)
        _run_trace([rng.randrange(1 << 16) for _ in range(120)])


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, (1 << 16) - 1), max_size=150))
    def test_paged_map_property_churn(choices):
        _run_trace(choices)
