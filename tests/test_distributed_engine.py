"""Rank-parallel eager memory plane (paper Section 7, Algorithms 1-2):
chunk ownership, RELEASED remote lifecycle, all-gather fetch /
reduce-scatter grads, collective-volume parity with the analytic model,
and loss parity with both the single-rank engine and the compiled
ChunkedRuntime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core import zero
from repro.core.distributed import DistributedPatrickStarEngine
from repro.core.engine import PatrickStarEngine
from repro.core.state import ChunkState, TensorState, derive_chunk_state


def _cfg(**over):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32", **over)


def _batch(cfg, b=4, s=32, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def _exact_chunked_volume(dist):
    """3(p-1)/p of the chunk-store capacity, as exact integer bytes."""
    g = dist.cmap.num_comm_groups
    cb = dist.ranks[0].params_mgr.chunk_bytes
    return 3 * (dist.nproc - 1) * g * cb


# ---------------------------------------------------------------------------
# acceptance: p=2 matches single-rank losses AND the analytic volume model
# ---------------------------------------------------------------------------


def test_p2_matches_single_rank_and_analytic_volume():
    cfg = _cfg()
    batch = _batch(cfg)
    single = PatrickStarEngine(model_class(cfg), cfg,
                               device_memory_bytes=4_000_000, lr=1e-2)
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000,
                                        lr=1e-2)
    exact = _exact_chunked_volume(dist)
    vol = zero.comm_volume_bytes(dist.cmap, itemsize=4)
    # the capacity-based analytic figure is the measured quantity; the
    # payload-based one differs from it by exactly the fragmentation
    assert exact == int(vol["chunked_capacity_bytes"])
    assert vol["chunked_allgather_bytes"] <= vol["chunked_capacity_bytes"]

    for step in range(4):
        ms = single.step(batch)
        md = dist.step(batch)
        # loss trajectory: same math (grads reduce-scatter-summed, shard
        # losses carry 1/global_tokens), only float association differs
        assert abs(ms.loss - md.loss) < 1e-4, (step, ms.loss, md.loss)
        # measured all-gather + reduce-scatter bytes == analytic chunked
        # volume, exactly, on every step (warm-up included)
        assert md.chunk_collective_bytes == exact, (
            step, md.chunk_collective_bytes, exact)
        # 2 gather passes : 1 reduce-scatter
        assert md.allgather_bytes == 2 * md.reduce_scatter_bytes
    assert md.loss < 0.7 * 6.8  # and it actually learns
    dist.check_invariants()


def test_p4_volume_and_loss_under_eviction_pressure():
    cfg = _cfg(num_layers=4)
    batch = _batch(cfg)
    single = PatrickStarEngine(model_class(cfg), cfg,
                               device_memory_bytes=8_000_000, lr=1e-2)
    # per-rank budget far below the full model: remote fetch + cross-stream
    # eviction must cooperate
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=4,
                                        device_memory_bytes=2_000_000,
                                        lr=1e-2)
    exact = _exact_chunked_volume(dist)
    for step in range(3):
        ms = single.step(batch)
        md = dist.step(batch)
        assert abs(ms.loss - md.loss) < 1e-3, (step, ms.loss, md.loss)
        assert md.chunk_collective_bytes == exact
    dist.check_invariants()


# ---------------------------------------------------------------------------
# compiled-runtime parity (the paper's two planes agree step-for-step)
# ---------------------------------------------------------------------------


def test_p2_matches_compiled_chunked_runtime():
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = _cfg()
    lr, betas, eps, seed = 1e-2, (0.9, 0.95), 1e-8, 0
    mesh = make_smoke_mesh(2, 1)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh,
                        RuntimeOptions(lr=lr, betas=betas, eps=eps))
    # identical init values packed into the [G, p, S] stores
    params = rt.model.init_params(jax.random.key(seed))
    pstores, osstores = {}, {}
    for name, lay in rt.layouts.items():
        if name == "stem":
            store = zero.flatten_to_store(lay, params["stem"])[None]
            gax = 1
        else:
            stacked = params["groups"][name]
            store = jax.vmap(
                lambda t, _l=lay: zero.flatten_to_store(_l, t))(stacked)[None]
            gax = 2
        pstores[name] = store
        dev_g, host_g = rt.os_split(name)
        p32 = store.astype(jnp.float32)
        zeros = jnp.zeros_like(p32)
        sl = lambda x, a, b: jax.lax.slice_in_dim(x, a, b, axis=gax)
        osstores[name] = {
            k: {"dev": sl(src, 0, dev_g), "host": sl(src, dev_g, dev_g + host_g)}
            for k, src in (("p32", p32), ("m", zeros), ("v", zeros))}
    jf, _, _ = driver.build_train_step(rt, InputShape("parity", 32, 4, "train"))

    batch = _batch(cfg)
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000,
                                        lr=lr, betas=betas, eps=eps, seed=seed)
    for step in range(4):
        pstores, osstores, metrics = jf(pstores, osstores, batch,
                                        jnp.int32(step))
        md = dist.step(batch)
        cl = float(metrics["loss"])
        assert np.isfinite(cl)
        assert abs(cl - md.loss) < 1e-4, (step, cl, md.loss)


# ---------------------------------------------------------------------------
# remote lifecycle mechanics
# ---------------------------------------------------------------------------


def test_remote_lifecycle_and_ownership():
    cfg = _cfg()
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000)
    cmap = dist.cmap
    # at init and between steps: every non-owned payload chunk is RELEASED
    # (no local payload), every owned chunk has an authoritative payload
    def assert_shard_invariant():
        for r, core in enumerate(dist.ranks):
            for c in range(cmap.num_chunks):
                if not cmap.chunk_tensors(c):
                    continue
                if cmap.chunk_owner(c) == r:
                    assert core.params_mgr._records[c].payload is not None
                    assert core.params_mgr.chunk_state(c) is not ChunkState.RELEASED
                else:
                    assert core.params_mgr.chunk_state(c) is ChunkState.RELEASED
                    assert core.params_mgr._records[c].payload is None

    assert_shard_invariant()
    dist.step(_batch(cfg))
    assert_shard_invariant()  # post-RS the replicas are dropped again

    # OS streams exist only for owned chunks (ADAM is local, Section 7)
    for r, core in enumerate(dist.ranks):
        for c in range(cmap.num_chunks):
            if not cmap.chunk_tensors(c) or cmap.chunk_owner(c) == r:
                continue
            for m in core.os_mgrs.values():
                assert m._records[c].payload is None

    # accessing a RELEASED tensor without the collective is an error, not
    # a silent zero-fill
    core = dist.ranks[0]
    remote = next(p.name for p in cmap.placements
                  if cmap.chunk_owner(p.chunk_id) != 0)
    with pytest.raises(RuntimeError, match="RELEASED"):
        core.params_mgr.access_tensor(remote)


def test_gather_prefetch_hides_collective_bytes():
    """Post-warm-up the gather prefetcher must convert critical-path
    all-gather bytes into hidden ones WITHOUT changing total collective
    volume (the H2D staging property, lifted to the collective plane)."""
    cfg = _cfg()
    batch = _batch(cfg)
    mets = {}
    for look in (0, 2):
        dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                            device_memory_bytes=4_000_000,
                                            gather_lookahead=look)
        dist.step(batch)  # warm-up
        mets[look] = dist.step(batch)
    demand, staged = mets[0], mets[2]
    assert demand.hidden_allgather_bytes == 0
    assert staged.allgather_bytes == demand.allgather_bytes > 0
    assert staged.hidden_allgather_bytes > 0
    assert staged.critical_allgather_bytes < demand.critical_allgather_bytes
    assert (staged.hidden_allgather_bytes + staged.critical_allgather_bytes
            == staged.allgather_bytes)


def test_stem_allreduce_counted_separately():
    cfg = _cfg()
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000)
    m = dist.step(_batch(cfg))
    assert m.allreduce_bytes > 0
    # the chunked-plane parity quantity excludes it
    assert m.chunk_collective_bytes == _exact_chunked_volume(dist)


# ---------------------------------------------------------------------------
# state machine: RELEASED
# ---------------------------------------------------------------------------


def test_released_state_machine():
    assert derive_chunk_state([TensorState.RELEASED]) is ChunkState.RELEASED
    assert derive_chunk_state(
        [TensorState.RELEASED, TensorState.HOLD]) is ChunkState.HOLD
    assert derive_chunk_state(
        [TensorState.RELEASED, TensorState.COMPUTE]) is ChunkState.COMPUTE
    assert derive_chunk_state([TensorState.FREE]) is ChunkState.FREE

    from repro.core.state import IllegalTransition, check_transition
    check_transition(TensorState.HOLD_AFTER_FWD, TensorState.RELEASED)
    check_transition(TensorState.HOLD_AFTER_BWD, TensorState.RELEASED)
    check_transition(TensorState.RELEASED, TensorState.HOLD)
    check_transition(TensorState.RELEASED, TensorState.COMPUTE)
    with pytest.raises(IllegalTransition):
        check_transition(TensorState.RELEASED, TensorState.FREE)
    with pytest.raises(IllegalTransition):
        check_transition(TensorState.COMPUTE, TensorState.RELEASED)
