"""TP gradient parity: loss and every gradient at tp=2 must match the
tp=1 oracle (sharded grads concatenate; replicated grads psum-sync)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, model_class
from repro.core import zero
from repro.launch.mesh import make_smoke_mesh
from repro.models.layers import AxisCtx, shard_map_compat
from repro.runtime.step import ChunkedRuntime, RuntimeOptions

TP = 2

pytestmark = pytest.mark.slow  # per-arch grad sweeps: the sweeps CI job


def _split_tree(tree, ax_tree, rank, tp, shift=0):
    def split(p, ax):
        if ax is None:
            return p
        n = p.shape[ax + shift] // tp
        return jax.lax.slice_in_dim(p, rank * n, (rank + 1) * n, axis=ax + shift)
    return jax.tree.map(split, tree, ax_tree, is_leaf=lambda x: x is None)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b"])
def test_loss_and_grad_parity(arch):
    cfg = get_config(arch, smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    B, S = 4, 32
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
             "global_tokens": jnp.float32(B * S)}

    # ---- tp=1 oracle: bare model, direct params --------------------------
    ctx1 = AxisCtx()
    model1 = model_class(cfg)(cfg, ctx1)
    params1 = model1.init_params(jax.random.key(7))

    def loss1(params):
        x, extras = model1.embed(params["stem"], batch)
        aux = jnp.float32(0.0)
        for g in model1.groups():
            x, extras = model1.between_groups(g.name, x, extras,
                                              params["stem"], batch)
            def body(c, lp, _g=g):
                cx, ca = c
                y, a = _g.apply(lp, cx, extras, ctx1)
                return (y, ca + jnp.float32(a)), None
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"][g.name])
        return model1.head_loss(params["stem"], x, batch) + aux

    l1, g1 = jax.value_and_grad(loss1)(params1)

    # ---- tp=2 through the chunked runtime --------------------------------
    mesh = make_smoke_mesh(1, TP)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    axes = rt.tp_axes

    def build_stores(rank):
        stem_l = _split_tree(params1["stem"], axes["stem"], rank, TP)
        st = {"stem": zero.flatten_to_store(rt.layouts["stem"], stem_l)[None]}
        for g in rt.model.groups():
            loc = _split_tree(params1["groups"][g.name],
                              axes["groups"][g.name], rank, TP, shift=1)
            arr = jax.vmap(lambda t, _l=rt.layouts[g.name]:
                           zero.flatten_to_store(_l, t))(loc)
            st[g.name] = arr[None]
        return st

    pstores = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                           *[build_stores(r) for r in range(TP)])

    def loss2(ps, batch):
        from repro.models.layers import vary_to
        tot = rt._loss_local(ps, batch)[0]
        # sum over data shards; model copies identical -> mean over model
        return jax.lax.psum(vary_to(tot, ("data", "model")),
                            ("data", "model")) / TP

    f = jax.jit(shard_map_compat(
        jax.value_and_grad(loss2), mesh=mesh,
        in_specs=(rt.store_pspecs(),
                  {"tokens": P(), "labels": P(), "global_tokens": P()}),
        out_specs=(P(), rt.store_pspecs()), check_vma=True))
    l2, g2 = f(pstores, batch)
    assert abs(float(l1) - float(l2)) < 5e-5 * max(1.0, abs(float(l1)))

    # ---- compare every gradient leaf --------------------------------------
    for g in rt.model.groups():
        lay = rt.layouts[g.name]
        parts = []
        for r in range(TP):
            flat = g2[g.name][r].reshape(g2[g.name][r].shape[0], -1)
            parts.append(jax.vmap(
                lambda f_, _l=lay: zero.unflatten_from_flat(_l, f_))(flat))
        ref = g1["groups"][g.name]
        ga = axes["groups"][g.name]
        flat_ref = jax.tree_util.tree_flatten_with_path(ref)[0]
        flat_ax = jax.tree.leaves(
            ga, is_leaf=lambda x: x is None or isinstance(x, int))
        flat_parts = [jax.tree_util.tree_flatten_with_path(t)[0] for t in parts]
        for i, ((path, a1), ax) in enumerate(zip(flat_ref, flat_ax)):
            ps = [fp[i][1] for fp in flat_parts]
            scale = float(jnp.max(jnp.abs(a1))) + 1e-9
            if ax is None:
                err = max(float(jnp.max(jnp.abs(p - a1))) for p in ps)
            else:
                cat = jnp.concatenate(ps, axis=ax + 1)
                err = float(jnp.max(jnp.abs(cat - a1)))
            assert err / scale < 2e-4, (
                f"{g.name}{jax.tree_util.keystr(path)}: relerr {err/scale:.2e}")
