"""Tier-stack tests (core/memory.py): the heterogeneous-chunk-size
eviction-cascade overflow regression, the three-tier (device/host/slow)
unlock, demand promotion and two-hop staging from the slow tier, the
improved OutOfMemory diagnostics, and stale-prefetcher-reference cleanup
on unregister_stream."""

import pytest

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, OutOfMemory, SchedulePrefetcher
from repro.core.state import TensorState
from repro.core.timeline import TransferTimeline

A_SIZE = 8  # elements per A tensor == per A chunk (32 B fp32)
B_SIZE = 2  # elements per B tensor == per B chunk (8 B fp32)
A_CB = A_SIZE * 4
B_CB = B_SIZE * 4
DEV_CAP = 2 * A_CB  # 64 B
HOST_CAP = 5 * B_CB  # 40 B: holds 5 B chunks but NOT host-load + one A chunk


def _two_stream_pool(slow_bytes=None, policy="fifo"):
    a_map = build_chunk_map(
        [TensorSpec(f"t{i}", (A_SIZE,)) for i in range(4)], A_SIZE)
    b_map = build_chunk_map(
        [TensorSpec(f"t{i}", (B_SIZE,)) for i in range(8)], B_SIZE)
    pool = HeteroMemory(
        device_capacity_bytes=DEV_CAP, host_capacity_bytes=HOST_CAP,
        slow_capacity_bytes=slow_bytes, policy=policy)
    A = ChunkManager(a_map, name="A", pool=pool)
    B = ChunkManager(b_map, name="B", pool=pool)
    return pool, A, B


def _cascade_setup(slow_bytes=None):
    """Both tiers near-full with heterogeneous chunk sizes, FIFO order
    arranged so the next device admission evicts the large A chunk:

      host:   b0..b4 (5 x 8 B, full)          arrivals 1..5
      device: a0 (32 B) + b5 (8 B) = 40 B      arrivals 6, 7

    Accessing a1 (32 B) on the device overflows it (40+32 > 64); FIFO
    picks a0 (oldest arrival) as victim, whose spill to the full host
    must cascade 32 B worth of B chunks out of the way — four of them.
    A single-victim cascade frees only 8 B and overflows the host tier.
    """
    pool, A, B = _two_stream_pool(slow_bytes=slow_bytes)
    for i in range(5):
        B.access_tensor(f"t{i}", "host")
        B.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
    A.access_tensor("t0", "device")
    A.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    B.access_tensor("t5", "device")
    B.release_tensor("t5", TensorState.HOLD_AFTER_FWD)
    assert pool.host_bytes_used() == HOST_CAP
    assert pool.device_bytes_used() == A_CB + B_CB
    return pool, A, B


def test_heterogeneous_cascade_never_overflows_budgets():
    """Regression: with different per-stream chunk_bytes a one-victim
    destination cascade frees less than the incoming chunk needs, and the
    spill silently overflowed the host budget.  The cascade must evict
    size-aware until the chunk fits — or raise — but never overflow."""
    pool, A, B = _cascade_setup()
    try:
        A.access_tensor("t1", "device")
        A.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    except OutOfMemory:
        pass  # an honest refusal is acceptable; an overflow never is
    assert pool.device_bytes_used() <= DEV_CAP
    assert pool.host_bytes_used() <= HOST_CAP
    pool.check_invariants()


def test_slow_tier_absorbs_cascade():
    """The same pressure with a slow tier behind the host trains through:
    host victims demote DOWN to the slow tier (no device bounce), the A
    chunk spills to the host, and the admission succeeds with every tier
    inside budget."""
    slow_cap = 25 * B_CB
    pool, A, B = _cascade_setup(slow_bytes=slow_cap)
    A.access_tensor("t1", "device")
    A.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    assert A.location(1) == "device"
    assert pool.device_bytes_used() <= DEV_CAP
    assert pool.host_bytes_used() <= HOST_CAP
    assert 0 < pool.slow_bytes_used() <= slow_cap
    # the cascade crossed the host->slow lane, not the host->device bounce
    assert pool.stats.h2s_count >= 4
    assert pool.stats.h2s_bytes == pool.stats.h2s_count * B_CB
    assert pool.stats.total_bytes >= pool.stats.h2s_bytes
    pool.check_invariants()


def test_demand_promotion_from_slow():
    """A slow-resident chunk promotes on demand via the two-hop
    slow->host->device route (s2h then h2d, both booked)."""
    pool, A, B = _cascade_setup(slow_bytes=25 * B_CB)
    A.access_tensor("t1", "device")
    A.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    assert B.location(0) == "slow"  # FIFO demoted the oldest B chunks
    h2d_before = pool.stats.h2d_count
    B.access_tensor("t0", "device")
    B.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    assert B.location(0) == "device"
    assert pool.stats.s2h_count >= 1
    assert pool.stats.h2d_count > h2d_before
    assert pool.stats.total_bytes == (
        pool.stats.h2d_bytes + pool.stats.d2h_bytes
        + pool.stats.h2s_bytes + pool.stats.s2h_bytes)
    pool.check_invariants()


def test_two_tier_pool_has_no_slow_tier():
    """slow_capacity=None keeps the two-tier stack: host evictions bounce
    to the device (margin-overflow), the slow lanes stay untouched."""
    pool, A, B = _two_stream_pool()
    assert pool.tiers == ("device", "host")
    assert pool._evict_target("host") == "device"
    assert pool.slow_bytes_used() == 0
    pool3, _, _ = _two_stream_pool(slow_bytes=100)
    assert pool3.tiers == ("device", "host", "slow")
    assert pool3._evict_target("host") == "slow"
    assert pool3._evict_target("slow") == "host"


def _one_stream_pool(n=4, device_chunks=1, host_bytes=None, slow_bytes=None,
                     policy="opt"):
    cmap = build_chunk_map(
        [TensorSpec(f"t{i}", (A_SIZE,)) for i in range(n)], A_SIZE)
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * A_CB,
        host_capacity_bytes=host_bytes, slow_capacity_bytes=slow_bytes,
        policy=policy)
    return pool, ChunkManager(cmap, name="param", pool=pool)


def test_oom_message_empty_candidate_set():
    """A genuinely empty victim set (every resident in COMPUTE) says so,
    with a per-tier/per-stream usage breakdown."""
    pool, mgr = _one_stream_pool(device_chunks=1)
    mgr.access_tensor("t0", "device")  # stays in COMPUTE: unevictable
    with pytest.raises(OutOfMemory) as ei:
        mgr.access_tensor("t1", "device")
    msg = str(ei.value)
    assert "no evictable chunk" in msg
    assert "tier usage by stream" in msg
    assert "param=" in msg


def test_oom_message_cascade_no_progress():
    """Evictable chunks exist but cascades ping-pong between full tiers:
    the message must NOT claim there was no evictable chunk."""
    pool, mgr = _one_stream_pool(device_chunks=1, host_bytes=A_CB)
    mgr.access_tensor("t0", "device")
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    mgr.access_tensor("t1", "host")
    mgr.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    with pytest.raises(OutOfMemory) as ei:
        mgr.access_tensor("t2", "device")
    msg = str(ei.value)
    assert "no evictable chunk" not in msg
    assert "tier usage by stream" in msg
    pool.check_invariants()


def test_two_hop_stage_from_slow():
    """Staging a slow-resident chunk runs s2h + h2d, books both legs
    hidden on the H2D side (hidden+critical==h2d stays conserved), and on
    the timeline the h2d leg starts only after the s2h leg lands."""
    tl = TransferTimeline(h2d_bandwidth=1e3, d2h_bandwidth=1e3,
                          h2s_bandwidth=500.0, s2h_bandwidth=500.0)
    pool, mgr = _one_stream_pool(device_chunks=2, host_bytes=A_CB,
                                 slow_bytes=4 * A_CB, policy="opt")
    pool.set_timeline(tl)
    # t0 -> host, then t1 -> host evicts t0 down to the slow tier
    mgr.access_tensor("t0", "host")
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    mgr.access_tensor("t1", "host")
    mgr.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    assert mgr.location(0) == "slow"
    mgr.register_moments({0: [5]})
    pool.set_moment(0)
    assert pool.stage("param", 0)
    assert mgr.location(0) == "device"
    assert pool.prefetch.staged_transfers == 1
    assert pool.stats.s2h_count == 1
    assert (pool.prefetch.hidden_h2d_bytes + pool.prefetch.critical_h2d_bytes
            == pool.stats.h2d_bytes)
    # chained legs: the h2d wire starts after the s2h completion
    assert tl.h2d.busy_until >= tl.s2h.busy_until
    # the consumer's arrival resolves the rendezvous as a hit
    pool.set_moment(5)
    mgr.access_tensor("t0", "device")
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    assert pool.prefetch.hits == 1
    pool.check_invariants()


def test_three_tier_timeline_conservation():
    """wall == compute + stalls holds with the slow lanes in play, the
    slow lanes actually see traffic, and infinite bandwidth stalls 0."""

    def drive(tl):
        pool, A, B = _cascade_setup(slow_bytes=25 * B_CB)
        pool.set_timeline(tl)
        tl.install_durations({m: 1e-3 for m in range(4)})
        pool.set_moment(0)
        A.access_tensor("t1", "device")  # cascade: d2h + 4x h2s
        A.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
        pool.set_moment(1)
        B.access_tensor("t0", "device")  # two-hop promotion: s2h + h2d
        B.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
        pool.set_moment(2)
        rep = tl.take_step()
        pool.check_invariants()
        return pool, rep

    pool, rep = drive(TransferTimeline(
        h2d_bandwidth=1e6, d2h_bandwidth=1e6,
        h2s_bandwidth=2e5, s2h_bandwidth=2e5))
    assert abs(rep.wall_s - rep.step_s) <= 1e-9 * max(rep.wall_s, 1e-30)
    assert rep.h2s_stall_s > 0.0 and rep.s2h_stall_s > 0.0
    assert (pool.prefetch.hidden_h2d_bytes + pool.prefetch.critical_h2d_bytes
            == pool.stats.h2d_bytes)

    _, rep_inf = drive(TransferTimeline())
    assert rep_inf.stall_s == 0.0
    assert abs(rep_inf.wall_s - rep_inf.compute_s) <= 1e-12


def test_unregister_stream_drops_prefetcher_refs():
    """unregister_stream purges the stream from installed prefetcher
    queues; a rebuilt stream reusing the name (with recycled, possibly
    fewer chunk ids) is never staged off the stale schedule."""
    pool, mgr = _one_stream_pool(n=4, device_chunks=4, policy="opt")
    kv_map = build_chunk_map(
        [TensorSpec(f"t{i}", (A_SIZE,)) for i in range(6)], A_SIZE)
    kv = ChunkManager(kv_map, name="kv", pool=pool)
    pf = SchedulePrefetcher(pool, lookahead=4)
    pf.install([(0, "param", 0), (1, "kv", 5), (2, "kv", 1), (3, "param", 1)])
    pool.unregister_stream("kv")
    assert all(stream != "kv" for _, stream, _ in pf._refs)
    assert len(pf._refs) == 2
    # a rebuilt, smaller "kv" stream: the stale id 5 is out of range and
    # stage() must tolerate it (no IndexError), not stage a wrong chunk
    small_map = build_chunk_map([TensorSpec("t0", (A_SIZE,))], A_SIZE)
    ChunkManager(small_map, name="kv", pool=pool)
    assert pool.stage("kv", 5) is False
    assert pf.advance(0) >= 0  # queue still consistent after the drop
    pool.check_invariants()


def test_unregister_unknown_stream_raises():
    pool, _ = _one_stream_pool()
    with pytest.raises(KeyError, match="not registered"):
        pool.unregister_stream("nope")


# ---------------------------------------------------------------------------
# satellite (PR 9): kv pages ride the cascade to the slow tier
# ---------------------------------------------------------------------------


def test_serving_kv_pages_demote_to_slow_and_survive_long_burst():
    """Admission counts the slow tier, so a long-horizon burst that does
    NOT fit device+host must actually be able to park its cold kv pages
    there: cold HOLD pages demote host->slow like model-data streams, and
    the whole burst decodes to completion without OOM — token-for-token
    equal to a roomy-host reference."""
    import jax
    import numpy as np

    from repro.configs import get_config, model_class
    from repro.core.serving import ServingEngine

    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    prompts = np.asarray(jax.random.randint(
        jax.random.key(3), (12, 8), 0, cfg.vocab_size))

    def run(**kw):
        eng = ServingEngine(model_class(cfg), cfg, max_seq_len=48,
                            page_tokens=8, seed=0, **kw)
        rids = [eng.submit(p, 32) for p in prompts]
        kv_h2s = kv_slow_peak = 0
        while True:
            m = eng.step_round()
            if m is None:
                break
            if eng.kv_mgr is not None:
                kv_h2s = max(kv_h2s, eng.kv_mgr.stats.h2s_bytes)
                kv_slow_peak = max(kv_slow_peak,
                                   eng.kv_mgr.slow_bytes_used())
            eng.check_invariants()
        return eng, [eng.result(r) for r in rids], kv_h2s, kv_slow_peak

    # host tier deliberately tight: it holds the device's param overflow
    # with almost no room left for cold kv, so kv residency must lean on
    # the slow tier
    eng, toks, kv_h2s, kv_slow_peak = run(
        device_memory_bytes=1_200_000, host_memory_bytes=600_000,
        slow_memory_bytes=2_000_000)
    assert all(len(t) == 32 for t in toks)
    # the burst was admitted AGAINST slow capacity: at peak concurrency
    # the admission bound exceeds what device+host alone could cover
    assert (eng._param_stream_bytes + eng.peak_concurrency * eng.kv_seq_bytes
            > eng.device_capacity + eng.host_capacity)
    # and kv pages genuinely rode the cascade there
    assert kv_h2s > 0
    assert kv_slow_peak > 0
    # chunk residency must never change tokens
    _, ref, _, _ = run(device_memory_bytes=1_200_000,
                       host_memory_bytes=16_000_000)
    assert toks == ref
