"""Paged KV serving (page_tokens): token parity with the unpaged
oracle across the eager and compiled engines, the page append/retire
lifecycle, page-granular admission, and the compiled plane's
slot page-range binding."""

import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.serving import ServeRequest, ServingEngine, \
    swap_headroom_bytes


def _cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _prompts(cfg, n, plen, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
            for _ in range(n)]


def _serve(engine_cls, cfg, prompts, news, *, horizon=40,
           device=1_300_000, host=8_000_000, **kw):
    eng = engine_cls(model_class(cfg), cfg, device_memory_bytes=device,
                     host_memory_bytes=host, max_seq_len=horizon, **kw)
    rids = [eng.submit(p, n) for p, n in zip(prompts, news)]
    for m in eng.run():
        assert m.peak_device_bytes <= eng.device_capacity, (
            m.round_index, m.peak_device_bytes)
        eng.check_invariants()
    return eng, [eng.result(r) for r in rids]


def test_paged_eager_matches_unpaged_oracle():
    """Paging changes the memory-management unit, never a token — and
    the page chunk is a fraction of the whole-horizon chunk, which is
    the admission-granularity win."""
    cfg = _cfg()
    prompts = _prompts(cfg, 4, 8)
    news = [10, 4, 10, 6]  # staggered retirement churns the free list
    e0, oracle = _serve(ServingEngine, cfg, prompts, news)
    e1, paged = _serve(ServingEngine, cfg, prompts, news, page_tokens=8)
    assert paged == oracle
    assert e1.kv_chunk_bytes < e0.kv_chunk_bytes
    assert e1._pages_per_seq == 5  # ceil(40 / 8)
    assert e1.kv_seq_bytes == (e1._pages_per_seq * e1._total_layers
                               * e1.kv_chunk_bytes)
    # partial spill really happened: cold pages moved to host mid-flight
    assert e1.pool.stats.d2h_bytes > 0


def test_page_append_tracks_decode_position():
    """Pages are appended exactly when decode crosses a page boundary,
    and admission commits the request's true page footprint."""
    cfg = _cfg()
    T = 4
    eng = ServingEngine(model_class(cfg), cfg,
                        device_memory_bytes=1_300_000,
                        host_memory_bytes=8_000_000, max_seq_len=24,
                        page_tokens=T)
    [prompt] = _prompts(cfg, 1, 6)
    rid = eng.submit(prompt, 10)
    # commit = pages at the final written position (prompt + new - 1)
    req = ServeRequest(rid=-1, prompt=prompt, max_new_tokens=10)
    assert eng._kv_commit_bytes(req) == (
        -(-(6 + 10 - 1) // T) * eng._total_layers * eng.kv_chunk_bytes)
    while eng.step_round() is not None:
        active = [r for r in eng._active if r.rid == rid]
        if not active:
            break
        r = active[0]
        # pos positions are written; decode will extend to pos+1 next
        want = max(1, -(-r.pos // T))
        assert eng._req_pages[rid] == want, (r.pos, eng._req_pages[rid])
        assert eng.kv_mgr.cmap.num_payload_chunks == (
            want * eng._total_layers)
    assert len(eng.result(rid)) == 10
    assert eng.kv_mgr is None  # full drain dropped the stream


def test_paged_compiled_matches_oracle_and_pins_page_ranges():
    cfg = _cfg()
    from repro.runtime import driver
    from repro.runtime.serve import CompiledServingEngine

    prompts = _prompts(cfg, 4, 8, seed=13)
    news = [9, 4, 9, 6]
    _, oracle = _serve(ServingEngine, cfg, prompts, news)
    comp = CompiledServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_300_000,
        host_memory_bytes=8_000_000, max_seq_len=40, page_tokens=8)
    rids = [comp.submit(p, n) for p, n in zip(prompts, news)]
    stepped = False
    while comp.step_round() is not None:
        stepped = True
        # every live kv page sits inside its slot's reserved id range
        if comp.kv_mgr is not None:
            for pl in comp.kv_mgr.cmap.placements:
                rid = int(pl.name.split(".")[1])
                r = driver.slot_page_range(
                    comp._slot_of[rid], comp._total_layers,
                    comp._pages_per_seq)
                assert pl.chunk_id in r, (pl.name, pl.chunk_id, r)
        comp.check_invariants()
    assert stepped
    assert [comp.result(r) for r in rids] == oracle


def test_unpageable_cache_arch_rejected():
    """An arch whose cache leaves have no clean position axis (xLSTM
    recurrent state) must refuse page_tokens up front."""
    cfg = get_config("xlstm-1.3b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    with pytest.raises(ValueError, match="position axis"):
        ServingEngine(model_class(cfg), cfg,
                      device_memory_bytes=8_000_000,
                      host_memory_bytes=32_000_000, max_seq_len=24,
                      page_tokens=8)


def test_paged_requires_managed_stream():
    cfg = _cfg()
    with pytest.raises(ValueError, match="manage_kv"):
        ServingEngine(model_class(cfg), cfg,
                      device_memory_bytes=4_000_000, max_seq_len=24,
                      manage_kv=False, page_tokens=8)


def test_swap_headroom_helper_is_the_admission_margin():
    """The shared helper IS the margin at each admission site: the
    floor check, the decode-batch fit and `_admissible` all route
    through it with their site's co-scheduled streams."""
    assert swap_headroom_bytes(3, 7) == 7
    assert swap_headroom_bytes(5) == 5
    with pytest.raises(ValueError):
        swap_headroom_bytes()
    cfg = _cfg()
    eng = ServingEngine(model_class(cfg), cfg,
                        device_memory_bytes=1_300_000,
                        host_memory_bytes=8_000_000, max_seq_len=24)
    fit = (eng.device_capacity - eng._param_floor_bytes
           - swap_headroom_bytes(eng.kv_chunk_bytes)) // eng.kv_chunk_bytes
    assert eng.max_decode_batch == max(1, min(8, fit))
