"""Chunk manager: state machine, eviction policies, transfer accounting."""

import numpy as np
import pytest

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager, OutOfMemory
from repro.core.state import (
    ChunkState,
    IllegalTransition,
    TensorState,
    check_transition,
    derive_chunk_state,
)


def _mgr(n_tensors=8, chunk_size=16, device_chunks=2, policy="opt", **kw):
    specs = [TensorSpec(f"t{i}", (chunk_size,)) for i in range(n_tensors)]
    cmap = build_chunk_map(specs, chunk_size)  # one tensor per chunk
    return ChunkManager(
        cmap, device_capacity_bytes=device_chunks * chunk_size * 4,
        policy=policy, **kw), cmap


def test_state_transitions():
    check_transition(TensorState.FREE, TensorState.HOLD)
    check_transition(TensorState.HOLD, TensorState.COMPUTE)
    check_transition(TensorState.COMPUTE, TensorState.HOLD_AFTER_FWD)
    check_transition(TensorState.HOLD_AFTER_FWD, TensorState.COMPUTE)
    with pytest.raises(IllegalTransition):
        check_transition(TensorState.FREE, TensorState.HOLD_AFTER_BWD)
    with pytest.raises(IllegalTransition):
        check_transition(TensorState.HOLD, TensorState.HOLD_AFTER_FWD)


def test_chunk_state_derivation():
    T = TensorState
    assert derive_chunk_state([T.FREE, T.FREE]) is ChunkState.FREE
    assert derive_chunk_state([T.HOLD, T.FREE]) is ChunkState.HOLD
    assert derive_chunk_state([T.HOLD, T.COMPUTE]) is ChunkState.COMPUTE
    assert derive_chunk_state([T.HOLD_AFTER_FWD]) is ChunkState.HOLD


def test_payload_survives_eviction_roundtrip():
    mgr, cmap = _mgr(n_tensors=4, device_chunks=1, policy="lru")
    v = mgr.access_tensor("t0")
    v[...] = 7.0
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    for i in range(1, 4):  # force t0 off-device
        mgr.access_tensor(f"t{i}")
        mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
    assert mgr.location(0) == "host"
    assert (mgr.access_tensor("t0") == 7.0).all()
    assert mgr.stats.d2h_count >= 1 and mgr.stats.h2d_count >= 1


def test_compute_chunks_are_not_evictable():
    mgr, _ = _mgr(n_tensors=4, device_chunks=2)
    mgr.access_tensor("t0")
    mgr.access_tensor("t1")  # both chunks COMPUTE, device full
    with pytest.raises(OutOfMemory):
        mgr.access_tensor("t2")


def test_pinned_chunks_are_not_evictable():
    mgr, _ = _mgr(n_tensors=4, device_chunks=2)
    mgr.access_tensor("t0")
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    mgr.pin(0)
    mgr.access_tensor("t1")
    mgr.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    mgr.access_tensor("t2")  # must evict t1, not pinned t0
    assert mgr.location(0) == "device"
    assert mgr.location(1) == "host"
    mgr.unpin(0)


def _run_schedule(policy, accesses, device_chunks, moments=None):
    mgr, cmap = _mgr(n_tensors=8, device_chunks=device_chunks, policy=policy)
    if moments:
        mgr.register_moments(moments)
    for m, t in enumerate(accesses):
        mgr.set_moment(m)
        mgr.access_tensor(f"t{t}")
        mgr.release_tensor(f"t{t}", TensorState.HOLD_AFTER_FWD)
    return mgr.stats.total_bytes


def test_opt_beats_lru_with_future_knowledge():
    """Belady's OPT with the traced schedule must not move more data than
    LRU on a looping access pattern (the paper's Section 8.3 claim)."""
    # cyclic scan of 4 chunks with 3 device slots: LRU always evicts the
    # next-needed chunk (thrashes); OPT keeps 2 of the cycle resident
    pattern = [0, 1, 2, 3] * 12
    moments = {}
    for m, t in enumerate(pattern):
        moments.setdefault(t, []).append(m)
    opt = _run_schedule("opt", pattern, device_chunks=3, moments=moments)
    lru = _run_schedule("lru", pattern, device_chunks=3)
    fifo = _run_schedule("fifo", pattern, device_chunks=3)
    assert opt <= lru <= fifo * 2  # OPT is optimal; fifo sanity bound
    assert opt < lru  # strict win on this adversarial-for-LRU pattern


def test_free_chunks_release_payload():
    mgr, _ = _mgr(n_tensors=2, device_chunks=2)
    mgr.access_tensor("t0")
    mgr.release_tensor("t0", TensorState.FREE)
    assert mgr.location(0) is None
    assert mgr.device_bytes_used() == 0 or mgr.location(1) == "device"
