"""Pallas kernels vs their jnp oracles (interpret mode), shape/dtype sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunked_adam import BLOCK, chunked_adam_kernel
from repro.kernels.flash_attention import flash_attention_kernel


pytestmark = pytest.mark.kernels  # whole module: the kernel-sweep CI job


@pytest.mark.parametrize("n_blocks", [1, 3])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_chunked_adam_sweep(n_blocks, gdtype, wd):
    n = BLOCK * n_blocks
    k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
    p32 = jax.random.normal(k1, (n,))
    m = jax.random.normal(k2, (n,)) * 0.01
    v = jnp.abs(jax.random.normal(k3, (n,))) * 0.01
    g = jax.random.normal(k4, (n,)).astype(gdtype)
    hp = dict(lr=3e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=wd,
              bias_corr1=0.1, bias_corr2=0.05)
    got = chunked_adam_kernel(p32, m, v, g, interpret=True, **hp)
    want = ref.adam_ref(p32, m, v, g, **hp)
    for a, b, name in zip(got[:3], want, "pmv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6, err_msg=name)
    # fused bf16 conversion of the updated params
    np.testing.assert_allclose(np.asarray(got[3].astype(jnp.float32)),
                               np.asarray(want[0]), rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (1, 128, 2, 64, 64, 64),
    (2, 256, 4, 64, 64, 128),
    (1, 256, 1, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, d, bq, bk, dtype, causal):
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(k1, (b, s, h, d), dtype)
    k = jax.random.normal(k2, (b, s, h, d), dtype)
    v = jax.random.normal(k3, (b, s, h, d), dtype)
    got = flash_attention_kernel(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_scan_twin():
    """The Pallas kernel and the jnp scan twin implement the same math."""
    from repro.models.layers import scan_attention
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(k1, (2, 128, 2, 64))
    k = jax.random.normal(k2, (2, 128, 2, 64))
    v = jax.random.normal(k3, (2, 128, 2, 64))
    a = flash_attention_kernel(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    b = scan_attention(q, k, v, causal=True, block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
