"""Property tests for the chunk-tensor mapping schema (paper Section 6.1)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunk import (
    ChunkMapError,
    TensorSpec,
    build_chunk_map,
    search_chunk_size,
)

shapes = st.lists(
    st.tuples(st.integers(1, 7), st.integers(1, 9)), min_size=1, max_size=40
)


@st.composite
def map_inputs(draw):
    shp = draw(shapes)
    specs = [TensorSpec(f"t{i}", s) for i, s in enumerate(shp)]
    largest = max(int(np.prod(s)) for s in shp)
    chunk_size = draw(st.integers(largest, largest * 4))
    nproc = draw(st.sampled_from([1, 2, 4, 8]))
    return specs, chunk_size, nproc


@given(map_inputs())
@settings(max_examples=200, deadline=None)
def test_packing_invariants(inp):
    specs, chunk_size, nproc = inp
    cmap = build_chunk_map(specs, chunk_size, nproc=nproc)
    # 1. every tensor fits inside its chunk
    for p in cmap.placements:
        assert 0 <= p.offset
        assert p.offset + p.numel <= chunk_size, "tensor straddles a chunk"
        assert 0 <= p.chunk_id < cmap.num_chunks
    # 2. no overlap within a chunk + append order preserved
    by_chunk = {}
    for p in cmap.placements:
        by_chunk.setdefault(p.chunk_id, []).append(p)
    for cid, ps in by_chunk.items():
        ivs = sorted((p.offset, p.offset + p.numel) for p in ps)
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert a1 <= b0, "overlapping placements"
    # 3. chunk ids are non-decreasing in model order (locality, N-ary model)
    ids = [p.chunk_id for p in cmap.placements]
    assert ids == sorted(ids)
    # 4. padded to communication groups of nproc chunks
    assert cmap.num_chunks % nproc == 0
    assert cmap.num_chunks >= cmap.num_payload_chunks
    assert cmap.num_chunks - cmap.num_payload_chunks < nproc
    # 5. capacity accounting
    assert cmap.total_numel == sum(int(np.prod(s.shape)) for s in specs)
    assert 0 < cmap.utilization <= 1


@given(map_inputs())
@settings(max_examples=100, deadline=None)
def test_comm_group_layout(inp):
    specs, chunk_size, nproc = inp
    cmap = build_chunk_map(specs, chunk_size, nproc=nproc)
    for c in range(cmap.num_chunks):
        g = cmap.comm_group(c)
        assert c in cmap.comm_group_chunk_ids(g)
        assert cmap.owner_rank(c) == c % nproc
    for r in range(nproc):
        local = cmap.local_chunk_ids(r)
        assert len(local) == cmap.num_comm_groups


def test_oversized_tensor_rejected():
    with pytest.raises(ChunkMapError):
        build_chunk_map([TensorSpec("big", (100,))], 64)


def test_group_boundaries_align():
    specs = [TensorSpec(f"t{i}", (10,)) for i in range(10)]
    cmap = build_chunk_map(specs, 32, nproc=2, group_boundaries={"t4"})
    p4 = cmap.placement("t4")
    assert p4.offset == 0
    assert p4.chunk_id % 2 == 0  # starts a fresh comm group


@given(map_inputs())
@settings(max_examples=50, deadline=None)
def test_chunk_size_search(inp):
    specs, _, nproc = inp
    res = search_chunk_size(specs, nproc=nproc, align=8)
    assert res.chunk_size % 8 == 0
    cmap = build_chunk_map(specs, res.chunk_size, nproc=nproc)
    assert abs(cmap.utilization - res.utilization) < 1e-9
    # search picks the best utilization among its candidates
    assert all(res.utilization >= u - 1e-9 for _, u in res.candidates)


def test_search_respects_budget():
    specs = [TensorSpec(f"t{i}", (100,)) for i in range(20)]
    res = search_chunk_size(specs, align=4, memory_budget_elems=2600)
    assert res.num_chunks * res.chunk_size <= 2600


# ---------------------------------------------------------------------------
# DynamicChunkMap — the serving KV stream's mutable mapping
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 30), min_size=1, max_size=120))
@settings(max_examples=100, deadline=None)
def test_dynamic_map_id_space_bounded_by_peak(ops):
    """Random add/remove traffic: live tensors map to distinct chunks and
    the id space never exceeds the peak concurrent tensor count."""
    from repro.core.chunk import DynamicChunkMap

    dm = DynamicChunkMap(8)
    live: list[str] = []
    peak = 0
    nxt = 0
    for op in ops:
        if live and op % 3 == 0:  # remove roughly a third of the time
            dm.remove_tensor(live.pop(op % len(live)))
        else:
            name = f"t{nxt}"
            nxt += 1
            dm.add_tensor(TensorSpec(name, (1 + op % 8,)))
            live.append(name)
        peak = max(peak, len(live))
        ids = [dm.placement(n).chunk_id for n in live]
        assert len(set(ids)) == len(ids)
        assert dm.num_payload_chunks == len(live)
        assert dm.num_chunks <= peak
        for n in live:
            p = dm.placement(n)
            assert dm.chunk_tensors(p.chunk_id) == [p]
