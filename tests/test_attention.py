"""Attention correctness: scan==naive, SWA, GQA alignment, distributed
cache decode, MLA absorbed decode — each against a dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import BaseConfig, MoEConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models.layers import shard_map_compat


def _qkv(key, b, sq, sk, h, kv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (b, sq, h, d), dtype),
            jax.random.normal(k2, (b, sk, kv, d), dtype),
            jax.random.normal(k3, (b, sk, kv, d), dtype))


@pytest.mark.parametrize("sq,sk,h,kv,d,block", [
    (16, 16, 4, 4, 8, 8),
    (32, 32, 4, 2, 16, 16),
    (7, 23, 2, 1, 8, 8),   # ragged, GQA to 1 kv head
    (64, 64, 8, 8, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_scan_matches_naive(sq, sk, h, kv, d, block, causal):
    q, k, v = _qkv(jax.random.key(0), 2, sq, sk, h, kv, d)
    if causal and sq != sk:
        pytest.skip("causal oracle assumes aligned q/k")
    want = L.naive_attention(q, k, v, causal=causal)
    got = L.scan_attention(q, k, v, causal=causal, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_sliding_window_masks():
    q, k, v = _qkv(jax.random.key(1), 1, 32, 32, 2, 2, 8)
    w_naive = L.naive_attention(q, k, v, causal=True, window=8)
    w_scan = L.scan_attention(q, k, v, causal=True, window=8, block=8)
    np.testing.assert_allclose(np.asarray(w_scan), np.asarray(w_naive),
                               rtol=2e-5, atol=2e-5)
    full = L.naive_attention(q, k, v, causal=True)
    assert not np.allclose(np.asarray(w_naive), np.asarray(full))


def _mesh(tp):
    from repro.launch.mesh import _mesh as mk

    return mk((1, tp), ("data", "model"))


@pytest.mark.parametrize("h,kv,tp", [(8, 2, 4), (8, 8, 4), (4, 2, 2)])
def test_tp_attention_matches_single_device(h, kv, tp):
    """fwd/prefill/decode under TP (incl. distributed-cache mode when
    kv % tp != 0) against the tp=1 full-attention oracle."""
    d, hd, B, S = 64, 16, 2, 12
    cfg = BaseConfig(name="t", d_model=d, n_heads=h, n_kv_heads=kv,
                     head_dim=hd, d_ff=64, vocab_size=64)
    ctx = L.AxisCtx(model_axis="model", tp=tp, data_axis="data", dp=1)
    key = jax.random.key(0)
    kq, kk, kv_, ko, kx = jax.random.split(key, 5)
    wq = L.dense_init(kq, (d, h * hd))
    wk = L.dense_init(kk, (d, kv * hd))
    wv = L.dense_init(kv_, (d, kv * hd))
    wo = L.dense_init(ko, (h * hd, d))
    x = jax.random.normal(kx, (B, S, d))
    p1 = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    ref = L.attention_fwd(p1, x, cfg, L.AxisCtx())

    kv_sharded = kv % tp == 0

    def run(x):
        rank = jax.lax.axis_index("model")
        h_l = h // tp
        p = {"wq": jax.lax.dynamic_slice_in_dim(wq, rank * h_l * hd, h_l * hd, 1),
             "wo": jax.lax.dynamic_slice_in_dim(wo, rank * h_l * hd, h_l * hd, 0)}
        if kv_sharded:
            kv_l = kv // tp
            p["wk"] = jax.lax.dynamic_slice_in_dim(wk, rank * kv_l * hd, kv_l * hd, 1)
            p["wv"] = jax.lax.dynamic_slice_in_dim(wv, rank * kv_l * hd, kv_l * hd, 1)
        else:
            p["wk"], p["wv"] = wk, wv
        y_fwd = L.attention_fwd(p, x, cfg, ctx)
        y_pre, cache = L.attention_prefill(p, x, cfg, ctx)
        cache2 = L.attention_init_cache(cfg, B, S, tp, jnp.float32)
        y_dec = x[:, :1] * 0
        for i in range(S):
            y_dec, cache2 = L.attention_decode(p, x[:, i:i + 1], cache2, i, cfg, ctx)
        return y_fwd, y_pre, y_dec

    f = jax.jit(shard_map_compat(run, mesh=_mesh(tp), in_specs=(P(),),
                              out_specs=P(), check_vma=False))
    y_fwd, y_pre, y_dec = f(x)
    np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(ref[:, -1]),
                               atol=2e-4)


def test_prefill_then_decode_continues():
    """decode continuing from a prefilled distributed cache (kv % tp != 0)."""
    d, h, kv, hd, tp, B, S = 64, 8, 2, 16, 4, 2, 8
    cfg = BaseConfig(name="t", d_model=d, n_heads=h, n_kv_heads=kv,
                     head_dim=hd, d_ff=64, vocab_size=64)
    ctx = L.AxisCtx(model_axis="model", tp=tp, data_axis="data", dp=1)
    key = jax.random.key(3)
    kq, kk, kv_, ko, kx = jax.random.split(key, 5)
    wq = L.dense_init(kq, (d, h * hd)); wk = L.dense_init(kk, (d, kv * hd))
    wv = L.dense_init(kv_, (d, kv * hd)); wo = L.dense_init(ko, (h * hd, d))
    x = jax.random.normal(kx, (B, S + 2, d))
    ref = L.attention_fwd({"wq": wq, "wk": wk, "wv": wv, "wo": wo},
                          x, cfg, L.AxisCtx())

    def run(x):
        rank = jax.lax.axis_index("model")
        h_l = h // tp
        p = {"wq": jax.lax.dynamic_slice_in_dim(wq, rank * h_l * hd, h_l * hd, 1),
             "wk": wk, "wv": wv,
             "wo": jax.lax.dynamic_slice_in_dim(wo, rank * h_l * hd, h_l * hd, 0)}
        _, cache = L.attention_prefill(p, x[:, :S], cfg, ctx)
        # grow the prefill cache chunks to the decode horizon
        full = L.attention_init_cache(cfg, B, S + 2, tp, cache["k"].dtype)
        cache = {k2: jax.lax.dynamic_update_slice(
            full[k2], cache[k2], (0, 0, 0, 0)) for k2 in cache}
        y = None
        for i in range(2):
            y, cache = L.attention_decode(p, x[:, S + i:S + i + 1], cache,
                                          S + i, cfg, ctx)
        return y

    f = jax.jit(shard_map_compat(run, mesh=_mesh(tp), in_specs=(P(),),
                              out_specs=P(), check_vma=False))
    y = f(x)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(ref[:, -1]),
                               atol=2e-4)


@pytest.mark.parametrize("tp", [1, 4])
def test_mla_decode_matches_fwd(tp):
    cfg = MoEConfig(name="mla-t", d_model=64, n_heads=4, n_kv_heads=4,
                    head_dim=32, d_ff=64, d_ff_expert=32, vocab_size=64,
                    kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                    v_head_dim=16, n_experts=4, top_k=2)
    B, S = 2, 10
    ctx1 = L.AxisCtx()
    p1 = MLA.init_mla(jax.random.key(0), cfg, 1, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    ref = MLA.mla_fwd(p1, x, cfg, ctx1)

    if tp == 1:
        cache = MLA.mla_init_cache(cfg, B, S, jnp.float32, tp=1)
        y = None
        for i in range(S):
            y, cache = MLA.mla_decode(p1, x[:, i:i + 1], cache, i, cfg, ctx1)
        np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(ref[:, -1]),
                                   atol=2e-4)
        return

    ctx = L.AxisCtx(model_axis="model", tp=tp, data_axis="data", dp=1)
    h_l = cfg.n_heads // tp
    nr = cfg.qk_nope_dim + cfg.qk_rope_dim

    def run(x):
        rank = jax.lax.axis_index("model")
        def sl(w, width):
            return jax.lax.dynamic_slice_in_dim(w, rank * h_l * width,
                                                h_l * width, 1)
        p = {"wq": sl(p1["wq"], nr), "w_dkv": p1["w_dkv"],
             "w_krope": p1["w_krope"], "kv_norm": p1["kv_norm"],
             "w_uk": sl(p1["w_uk"], cfg.qk_nope_dim),
             "w_uv": sl(p1["w_uv"], cfg.v_head_dim),
             "wo": jax.lax.dynamic_slice_in_dim(
                 p1["wo"], rank * h_l * cfg.v_head_dim,
                 h_l * cfg.v_head_dim, 0)}
        cache = MLA.mla_init_cache(cfg, B, S, jnp.float32, tp=tp)
        y = None
        for i in range(S):
            y, cache = MLA.mla_decode(p, x[:, i:i + 1], cache, i, cfg, ctx)
        return y

    f = jax.jit(shard_map_compat(run, mesh=_mesh(tp), in_specs=(P(),),
                              out_specs=P(), check_vma=False))
    y = f(x)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(ref[:, -1]),
                               atol=2e-4)
