"""Device-aware placement (paper Section 8.2): plan_placement edge cases
— zero margin, margin exceeding all OS groups, single comm group, the
Table-4 spill diagnostic, and the embedding-on-host heuristic."""

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.placement import plan_placement


def _plan(**over):
    kw = dict(
        margin_bytes=0,
        num_local_groups=4,
        chunk_size_elems=1024,
        param_fp16_local_bytes=8 * 1024 * 2,
        device_total_bytes=1 << 20,
        peak_nonmodel_bytes=1 << 16,
    )
    kw.update(over)
    return plan_placement(**kw)


def test_zero_margin_keeps_all_os_on_host():
    plan = _plan(margin_bytes=0)
    assert plan.os_device_groups == 0
    assert plan.os_device_fraction == 0.0
    assert plan.margin_or_spill_groups == 0


def test_margin_smaller_than_one_group():
    # one OS group = 3 fp32 chunks = 12 KiB elems*4; just under -> 0 groups
    group_bytes = 3 * 1024 * 4
    plan = _plan(margin_bytes=group_bytes - 1)
    assert plan.os_device_groups == 0
    plan = _plan(margin_bytes=group_bytes)
    assert plan.os_device_groups == 1


def test_margin_larger_than_all_os_groups_is_capped():
    group_bytes = 3 * 1024 * 4
    plan = _plan(margin_bytes=100 * group_bytes, num_local_groups=4)
    assert plan.os_device_groups == 4  # never more than exist
    assert plan.os_device_fraction == 1.0
    assert plan.margin_or_spill_groups == 4


def test_single_comm_group():
    group_bytes = 3 * 1024 * 4
    plan = _plan(num_local_groups=1, margin_bytes=10 * group_bytes)
    assert plan.os_device_groups == 1
    assert plan.os_device_fraction == 1.0
    plan = _plan(num_local_groups=1, margin_bytes=0)
    assert plan.os_device_groups == 0


def test_no_groups_fraction_is_zero():
    plan = _plan(num_local_groups=0, margin_bytes=1 << 30)
    assert plan.os_device_groups == 0
    assert plan.os_device_fraction == 0.0


def test_spill_diagnostic_negative_groups():
    """Table 4: when even the param-fp16 working set exceeds the fp16
    budget, the diagnostic reports NEGATIVE spilled groups."""
    plan = _plan(
        param_fp16_local_bytes=1 << 20,
        device_total_bytes=1 << 19,
        peak_nonmodel_bytes=1 << 18,
    )
    assert plan.margin_or_spill_groups < 0
    # ceil((2^20 - (2^19 - 2^18)) / (2 * 1024))
    spill_bytes = (1 << 20) - ((1 << 19) - (1 << 18))
    expect = -(-spill_bytes // (2 * 1024))
    assert plan.margin_or_spill_groups == -expect


def test_embedding_on_host_heuristic():
    assert _plan(vocab_size=50_000, hidden=512, batch_tokens=4_096
                 ).embedding_on_host
    assert not _plan(vocab_size=1_000, hidden=512, batch_tokens=4_096
                     ).embedding_on_host
    assert not _plan(vocab_size=50_000, hidden=512, batch_tokens=0
                     ).embedding_on_host  # unknown batch -> no claim


def test_os_device_chunk_ids_cover_placed_groups():
    specs = [TensorSpec(f"t{i}", (64,)) for i in range(8)]
    cmap = build_chunk_map(specs, 64, nproc=2)  # 8 chunks, 4 groups of 2
    plan = _plan(num_local_groups=cmap.num_comm_groups,
                 chunk_size_elems=64,
                 margin_bytes=2 * 3 * 64 * 4)  # exactly two OS groups fit
    assert plan.os_device_groups == 2
    ids = plan.os_device_chunk_ids(cmap)
    assert ids == {0, 1, 2, 3}  # the first two comm groups' chunks
