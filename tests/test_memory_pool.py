"""Unified heterogeneous memory space (core/memory.py): one device budget
shared by all streams, cross-stream eviction, O(1) incremental counters,
and the schedule-driven prefetcher's hidden/critical overlap accounting."""

import numpy as np
import pytest

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager, OutOfMemory
from repro.core.memory import HeteroMemory, SchedulePrefetcher
from repro.core.state import ChunkState, TensorState, derive_chunk_state


def _pool(n_tensors=4, chunk_size=16, device_chunks=2, policy="opt",
          streams=("param", "p32")):
    specs = [TensorSpec(f"t{i}", (chunk_size,)) for i in range(n_tensors)]
    cmap = build_chunk_map(specs, chunk_size)  # one tensor per chunk
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * chunk_size * 4, policy=policy)
    mgrs = {s: ChunkManager(cmap, name=s, pool=pool) for s in streams}
    return pool, mgrs, cmap


def test_streams_share_one_device_budget():
    """Aggregate device bytes across streams never exceed the configured
    budget at any moment (the seed's per-stream managers could jointly
    oversubscribe the device len(streams)x)."""
    pool, mgrs, _ = _pool(n_tensors=4, device_chunks=2,
                          streams=("param", "p32", "m", "v"))
    cap = pool.device_capacity
    for i in range(4):
        for s, mgr in mgrs.items():
            mgr.access_tensor(f"t{i}")
            mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
            assert pool.device_bytes_used() <= cap
            assert sum(m.device_bytes_used() for m in mgrs.values()) \
                == pool.device_bytes_used()
            pool.check_invariants()
    assert pool.peak_device_bytes <= cap


def test_cross_stream_eviction():
    """Admitting a param chunk evicts an optimizer-state chunk: eviction
    sees pressure from ALL streams, not just its own."""
    pool, mgrs, _ = _pool(n_tensors=2, device_chunks=1, policy="lru")
    os_mgr, param = mgrs["p32"], mgrs["param"]
    os_mgr.access_tensor("t0")
    os_mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    assert os_mgr.location(0) == "device"
    param.access_tensor("t1")  # device holds 1 chunk -> OS chunk must go
    assert os_mgr.location(0) == "host"
    assert param.location(1) == "device"
    assert pool.device_bytes_used() == param.chunk_bytes


def test_pinned_and_compute_chunks_block_cross_stream_eviction():
    pool, mgrs, _ = _pool(n_tensors=2, device_chunks=1)
    mgrs["p32"].access_tensor("t0")  # COMPUTE: unevictable
    with pytest.raises(OutOfMemory):
        mgrs["param"].access_tensor("t1")


def test_shared_pool_rejects_duplicate_stream_and_capacity_args():
    pool, mgrs, cmap = _pool()
    with pytest.raises(ValueError):
        ChunkManager(cmap, name="param", pool=pool)  # name collision
    with pytest.raises(ValueError):
        ChunkManager(cmap, name="fresh", pool=pool,
                     device_capacity_bytes=1024)  # pool owns capacity


def test_unified_stats_are_sum_of_stream_stats():
    pool, mgrs, _ = _pool(n_tensors=4, device_chunks=1, policy="lru")
    for i in range(4):
        mgr = mgrs["param"] if i % 2 == 0 else mgrs["p32"]
        mgr.access_tensor(f"t{i}")
        mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
    for i in range(4):  # second sweep forces real transfers both ways
        mgr = mgrs["param"] if i % 2 == 0 else mgrs["p32"]
        mgr.access_tensor(f"t{i}")
        mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
    per = [m.stats for m in mgrs.values()]
    assert pool.stats.h2d_bytes == sum(s.h2d_bytes for s in per) > 0
    assert pool.stats.d2h_bytes == sum(s.d2h_bytes for s in per) > 0


def test_incremental_counters_track_free_and_release():
    pool, mgrs, _ = _pool(n_tensors=2, device_chunks=2)
    mgr = mgrs["param"]
    mgr.access_tensor("t0")
    mgr.release_tensor("t0", TensorState.FREE)
    assert mgr.device_bytes_used() == 0
    mgr.access_tensor("t1")
    mgr.release_tensor("t1", TensorState.HOLD)
    mgr.free_chunk(1)
    assert mgr.device_bytes_used() == mgr.host_bytes_used() == 0
    pool.check_invariants()


def test_chunk_state_matches_slow_derivation():
    """chunk_state is O(1) via incremental tallies; it must agree with the
    full derivation from tensor states after any transition sequence."""
    specs = [TensorSpec(f"t{i}", (4,)) for i in range(6)]
    cmap = build_chunk_map(specs, 8)  # two tensors per chunk
    mgr = ChunkManager(cmap, device_capacity_bytes=3 * 8 * 4, policy="lru")

    def check():
        for c in range(cmap.num_chunks):
            names = [p.name for p in cmap.chunk_tensors(c)]
            slow = derive_chunk_state(mgr.tensor_state(n) for n in names)
            assert mgr.chunk_state(c) is slow

    mgr.access_tensor("t0"); check()
    mgr.access_tensor("t1"); check()
    mgr.release_tensor("t0", TensorState.HOLD_AFTER_FWD); check()
    mgr.release_tensor("t1", TensorState.FREE); check()
    mgr.reset_states(TensorState.HOLD); check()
    mgr.force_tensor_state("t0", TensorState.HOLD); check()
    mgr.access_tensor("t2"); mgr.release_tensor("t2", TensorState.FREE); check()


def test_chunk_tensors_index_matches_linear_scan():
    specs = [TensorSpec(f"t{i}", (3, 2)) for i in range(9)]
    cmap = build_chunk_map(specs, 13)
    for c in range(cmap.num_chunks):
        assert cmap.chunk_tensors(c) == [
            p for p in cmap.placements if p.chunk_id == c]


def test_per_step_peak_resets_between_snapshots():
    """take_step_peak_device_bytes reports the high-water mark SINCE the
    previous snapshot (per-phase pressure), while pool.peak_device_bytes
    stays the cumulative lifetime mark."""
    pool, mgrs, _ = _pool(n_tensors=4, device_chunks=4)
    mgr = mgrs["param"]
    cb = mgr.chunk_bytes
    # phase 1: three chunks resident
    for i in range(3):
        mgr.access_tensor(f"t{i}")
        mgr.release_tensor(f"t{i}", TensorState.HOLD)
    assert pool.take_step_peak_device_bytes() == 3 * cb
    # phase 2 STARTS with those three still resident (occupancy carries
    # over, so its peak is still 3 chunks), then drops to two
    mgr.free_chunk(1)
    mgr.free_chunk(2)
    mgr.access_tensor("t3")
    mgr.release_tensor("t3", TensorState.HOLD)
    assert pool.take_step_peak_device_bytes() == 3 * cb
    # phase 3 starts at the post-drop occupancy: per-step peak falls to 2
    # chunks even though the lifetime mark stays 3
    assert pool.take_step_peak_device_bytes() == 2 * cb
    assert pool.peak_device_bytes == 3 * cb


# ------------------------------------------------------------------ prefetch

def _pattern_run(pattern, n_tensors, prefetch, device_chunks=3):
    """Replay an access pattern over a small pool, with or without
    schedule-driven staging (lookahead 2, one in-flight stage)."""
    specs = [TensorSpec(f"t{i}", (16,)) for i in range(n_tensors)]
    cmap = build_chunk_map(specs, 16)
    pool = HeteroMemory(device_capacity_bytes=device_chunks * 16 * 4,
                        policy="opt")
    mgr = ChunkManager(cmap, name="param", pool=pool)
    moments = {}
    for m, t in enumerate(pattern):
        moments.setdefault(t, []).append(m)
    mgr.register_moments(moments)
    pf = SchedulePrefetcher(pool, lookahead=2, max_inflight=1)
    if prefetch:
        pf.install([(m, "param", t) for m, t in enumerate(pattern)])
    for m, t in enumerate(pattern):
        pool.set_moment(m)
        if prefetch:
            pf.advance(m)
        mgr.access_tensor(f"t{t}")
        mgr.release_tensor(f"t{t}", TensorState.HOLD_AFTER_FWD)
    pool.check_invariants()
    return pool


def _scan_pattern(n=6, rounds=6):
    # forward scan then reverse scan, the engine's FWD/BWD shape
    return (list(range(n)) + list(reversed(range(n)))) * rounds


def test_prefetch_hides_h2d_at_equal_volume():
    demand = _pattern_run(_scan_pattern(), 6, prefetch=False)
    staged = _pattern_run(_scan_pattern(), 6, prefetch=True)
    # same total traffic: staging only replays evictions demand paging
    # would also perform, just ahead of the consuming access
    assert staged.stats.h2d_bytes == demand.stats.h2d_bytes > 0
    assert staged.stats.d2h_bytes == demand.stats.d2h_bytes
    # ...but most of it moves off the critical path
    assert staged.prefetch.critical_h2d_bytes < demand.prefetch.critical_h2d_bytes
    assert staged.prefetch.hidden_h2d_bytes > 0
    assert staged.prefetch.hit_rate > 0.5
    assert demand.prefetch.hidden_h2d_bytes == 0


def test_prefetch_refuses_when_no_free_overlap_exists():
    """On a tight cyclic pattern every resident chunk is needed before the
    staged chunk's use: staging would inflate volume, so the prefetcher
    must decline rather than thrash — volume stays exactly demand's."""
    demand = _pattern_run([0, 1, 2, 3] * 12, 4, prefetch=False)
    staged = _pattern_run([0, 1, 2, 3] * 12, 4, prefetch=True)
    assert staged.stats.h2d_bytes == demand.stats.h2d_bytes
    assert staged.prefetch.wasted_stages == 0


def test_hidden_plus_critical_equals_total_h2d():
    for pattern, n in ((_scan_pattern(), 6), ([0, 1, 2, 3] * 12, 4)):
        for prefetch in (False, True):
            pool = _pattern_run(pattern, n, prefetch=prefetch)
            assert (pool.prefetch.hidden_h2d_bytes
                    + pool.prefetch.critical_h2d_bytes) == pool.stats.h2d_bytes


def test_stage_refuses_to_thrash():
    """Staging must not evict a chunk whose next use is sooner than the
    staged chunk's (that would trade hidden bytes for extra volume)."""
    specs = [TensorSpec(f"t{i}", (16,)) for i in range(3)]
    cmap = build_chunk_map(specs, 16)
    pool = HeteroMemory(device_capacity_bytes=1 * 16 * 4, policy="opt")
    mgr = ChunkManager(cmap, name="param", pool=pool)
    # t0 resident on device, needed again at moment 1; t1 on host, needed
    # at moment 5 -> staging t1 over t0 would thrash.
    for n in ("t0", "t1"):
        dev = "device" if n == "t0" else "host"
        mgr.access_tensor(n, dev)
        mgr.release_tensor(n, TensorState.HOLD_AFTER_FWD)
    # (accessing t1 on host leaves t0 where it was: both HOLD now)
    mgr.register_moments({0: [1], 1: [5]})
    pool.set_moment(0)
    assert not pool.stage("param", 1)
    assert mgr.location(0) == "device"
    # reverse the urgency: now t0 is the far one and staging succeeds
    mgr.register_moments({0: [9], 1: [2]})
    assert pool.stage("param", 1)
    assert mgr.location(1) == "device"
    assert mgr.location(0) == "host"
