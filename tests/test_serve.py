"""Serving path: prefill + greedy decode == argmax of the training-time
forward logits (dense arch, tp=2 mesh)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def test_prefill_logits_match_greedy_decode():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, _ = driver.init_state(rt, jax.random.key(0))

    B, S = 4, 16
    tok = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    shape = InputShape("serve", S, B, "decode")

    pre, _ = driver.build_prefill_step(rt, shape)
    logits, caches = pre(ps, {"tokens": tok})
    # prefill logits are the next-token distribution at position S-1
    greedy_from_prefill = np.asarray(jnp.argmax(logits[:, 0], axis=-1))

    # decode path: replay the same tokens one by one from empty caches
    dshape = InputShape("serve", S + 1, B, "decode")
    dec, _ = driver.build_decode_step(rt, dshape)
    caches0 = driver.init_caches(rt, dshape)
    nxt = None
    c = caches0
    for i in range(S):
        nxt, c = dec(ps, c, tok[:, i:i + 1], jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(nxt), greedy_from_prefill)


def test_decode_is_deterministic():
    cfg = get_config("qwen3-0.6b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, _ = driver.init_state(rt, jax.random.key(0))
    shape = InputShape("serve", 8, 4, "decode")
    dec, _ = driver.build_decode_step(rt, shape)
    tok = jnp.ones((4, 1), jnp.int32)
    c1 = driver.init_caches(rt, shape)
    n1, _ = dec(ps, c1, tok, jnp.int32(0))
    c2 = driver.init_caches(rt, shape)
    n2, _ = dec(ps, c2, tok, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))


def test_prefill_grow_then_decode_matches_fwd():
    """prefill -> grow_caches -> decode continuation equals the full
    forward oracle (exercises strided-slot cache growth end to end)."""
    from repro.runtime.driver import grow_caches

    cfg = get_config("qwen2.5-3b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, _ = driver.init_state(rt, jax.random.key(0))
    B, S, extra = 4, 12, 3
    tok = jax.random.randint(jax.random.key(5), (B, S + extra), 0,
                             cfg.vocab_size)
    pshape = InputShape("p", S, B, "decode")
    pre, _ = driver.build_prefill_step(rt, pshape)
    logits, caches = pre(ps, {"tokens": tok[:, :S]})
    dshape = InputShape("d", S + extra, B, "decode")
    caches = grow_caches(rt, caches, S, S + extra, dshape)
    dec, _ = driver.build_decode_step(rt, dshape)
    nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
    # replaying decode from empty caches must reproduce the same token
    c2 = driver.init_caches(rt, dshape)
    got = None
    for i in range(S):
        got, c2 = dec(ps, c2, tok[:, i:i + 1], jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(got), nxt)
    # and continuing from the GROWN prefill caches must agree with the
    # replayed-cache continuation for the next tokens
    ga, gb = caches, c2
    for i in range(extra):
        ta, ga = dec(ps, ga, tok[:, S + i:S + i + 1], jnp.int32(S + i))
        tb, gb = dec(ps, gb, tok[:, S + i:S + i + 1], jnp.int32(S + i))
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
