"""Hypothesis property tests: the chunk manager never corrupts payloads,
never exceeds capacity, and keeps states consistent under random access
sequences with any eviction policy."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager, OutOfMemory
from repro.core.state import TensorState


@st.composite
def schedules(draw):
    n = draw(st.integers(2, 8))
    ops = draw(st.lists(st.integers(0, n - 1), min_size=5, max_size=60))
    policy = draw(st.sampled_from(["opt", "lru", "fifo"]))
    device_chunks = draw(st.integers(2, n))
    return n, ops, policy, device_chunks


@given(schedules())
@settings(max_examples=60, deadline=None)
def test_payload_integrity_under_any_schedule(sched):
    n, ops, policy, device_chunks = sched
    size = 8
    specs = [TensorSpec(f"t{i}", (size,)) for i in range(n)]
    cmap = build_chunk_map(specs, size)
    mgr = ChunkManager(cmap, device_capacity_bytes=device_chunks * size * 4,
                       policy=policy)
    expected = {}
    for step, t in enumerate(ops):
        name = f"t{t}"
        mgr.set_moment(step)
        view = mgr.access_tensor(name)
        if name in expected:
            # payload must have survived any number of evictions
            np.testing.assert_array_equal(view.ravel(), expected[name])
        val = np.full(size, float(step + 1), np.float32)
        view[...] = val
        expected[name] = val
        mgr.release_tensor(name, TensorState.HOLD_AFTER_FWD)
        # capacity invariant
        assert mgr.device_bytes_used() <= device_chunks * size * 4
    # all payloads retrievable at the end
    for name, val in expected.items():
        np.testing.assert_array_equal(mgr.tensor_view(name).ravel(), val)


@given(schedules())
@settings(max_examples=40, deadline=None)
def test_policies_agree_on_values_not_placement(sched):
    """Different policies may place chunks differently but must never
    change the data (the engine-level loss-parity property, at manager
    granularity)."""
    n, ops, _, device_chunks = sched
    size = 8
    specs = [TensorSpec(f"t{i}", (size,)) for i in range(n)]
    finals = {}
    for policy in ("opt", "lru", "fifo"):
        cmap = build_chunk_map(specs, size)
        mgr = ChunkManager(cmap, device_capacity_bytes=device_chunks * size * 4,
                           policy=policy)
        for step, t in enumerate(ops):
            mgr.set_moment(step)
            v = mgr.access_tensor(f"t{t}")
            v[...] = v + 1.0
            mgr.release_tensor(f"t{t}", TensorState.HOLD_AFTER_FWD)
        touched = sorted(set(ops))
        finals[policy] = np.stack(
            [mgr.tensor_view(f"t{i}").copy() for i in touched])
    np.testing.assert_array_equal(finals["opt"], finals["lru"])
    np.testing.assert_array_equal(finals["opt"], finals["fifo"])
