"""§Perf switches must not change training math (loss parity vs baseline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def _loss(cfg, opt, steps=2):
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, opt)
    ps, oss = driver.init_state(rt, jax.random.key(0))
    step, _, _ = driver.build_train_step(rt, InputShape("t", 64, 4, "train"))
    tok = jax.random.randint(jax.random.key(1), (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
             "global_tokens": jnp.float32(256)}
    for i in range(steps):
        ps, oss, m = step(ps, oss, batch, jnp.int32(i))
    return float(m["loss"])


@pytest.mark.parametrize("arch,opt,exact", [
    ("qwen3-0.6b", RuntimeOptions(inner_remat=True), True),
    ("qwen3-0.6b", RuntimeOptions(xent_block=16), True),
    ("qwen3-0.6b", RuntimeOptions(accum_steps=2), True),
    ("deepseek-v2-lite-16b", RuntimeOptions(moe_combine_first=True), True),
    ("xlstm-1.3b", RuntimeOptions(inner_remat=True, accum_steps=2), True),
    ("qwen3-0.6b", RuntimeOptions(remat="dots"), True),
    ("qwen3-0.6b", RuntimeOptions(gather_policy="step"), True),
])
def test_option_loss_parity(arch, opt, exact):
    cfg = get_config(arch, smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    base = _loss(cfg, RuntimeOptions())
    got = _loss(cfg, opt)
    tol = 5e-5 if exact else 5e-2
    assert abs(base - got) < tol * max(abs(base), 1.0), (base, got)


def test_accum_must_divide_batch():
    cfg = get_config("qwen3-0.6b", smoke=True)
    with pytest.raises(Exception):
        _loss(cfg, RuntimeOptions(accum_steps=3), steps=1)
