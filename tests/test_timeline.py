"""Transfer timeline (core/timeline.py): FIFO DMA-queue semantics, stall
classification (critical wait / late hidden / end-of-step drain), the
step decomposition through the training, distributed and serving
engines, and the bandwidth-aware prefetch win at equal byte volume."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine
from repro.core.timeline import TransferTimeline


def _lm_batch(cfg, b, s, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def _cfg(layers=4):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=layers, param_dtype="float32", compute_dtype="float32")


# ---------------------------------------------------------------------------
# unit: the DMA queues and the clock rules
# ---------------------------------------------------------------------------


def test_critical_transfer_waits_queue_plus_wire():
    """A critical H2D queued behind a hidden transfer stalls for the
    backlog AND its own wire time — DMA-engine contention."""
    tl = TransferTimeline(h2d_bandwidth=100.0)
    tl.install_durations({0: 1.0})
    tl.advance_to_moment(0)
    tl.record_h2d(100, stream="a", critical=False, key=("a", 1))  # 1s wire
    tl.record_h2d(100, stream="b", critical=True)  # ends at t=2
    rep = tl.take_step()
    assert rep.h2d_stall_s == 2.0
    assert rep.stall_by_stream == {"b": 2.0}
    assert rep.stall_by_moment == {0: 2.0}
    assert rep.compute_s == 1.0
    assert rep.wall_s == rep.step_s == 3.0


def test_late_hidden_transfer_surfaces_at_wait():
    """A staged transfer whose consumer arrives before the wire finishes
    stalls for exactly the remainder."""
    tl = TransferTimeline(h2d_bandwidth=100.0)
    tl.install_durations({0: 0.25, 1: 0.25})
    tl.advance_to_moment(0)
    tl.record_h2d(100, stream="s", critical=False, key=("s", 0))  # ends 1.0
    tl.advance_to_moment(1)  # +0.25 compute
    assert tl.wait_for(("s", 0)) == 0.75
    rep = tl.take_step()
    assert rep.h2d_stall_s == 0.75
    assert rep.compute_s == 0.5
    # a second wait on the same key is a no-op (rendezvous consumed)
    assert tl.wait_for(("s", 0)) == 0.0


def test_cancelled_key_never_stalls():
    tl = TransferTimeline(h2d_bandwidth=1.0)
    tl.advance_to_moment(0)
    tl.record_h2d(100, stream="s", critical=False, key=("s", 0))
    tl.cancel(("s", 0))
    assert tl.wait_for(("s", 0)) == 0.0


def test_drain_attribution_is_marginal_not_double_counted():
    """Concurrent end-of-step queue drains are attributed engine-by-
    engine in completion order; the sum equals the wall advance."""
    tl = TransferTimeline(h2d_bandwidth=100.0, d2h_bandwidth=50.0)
    tl.advance_to_moment(0)
    tl.record_h2d(100, stream="a", critical=False)  # ends 1.0
    tl.record_d2h(100, stream="b", critical=False)  # ends 2.0
    rep = tl.take_step()
    assert rep.h2d_stall_s == 1.0  # first to finish
    assert rep.d2h_stall_s == 1.0  # marginal wait beyond h2d
    assert rep.wall_s == rep.step_s == 2.0


def test_infinite_bandwidth_is_instantaneous():
    tl = TransferTimeline()
    tl.advance_to_moment(0)
    tl.record_h2d(10**12, stream="a", critical=True)
    tl.record_d2h(10**12, stream="a", critical=True)
    tl.record_collective(10**12, critical=True)
    rep = tl.take_step()
    assert rep.stall_s == 0.0 and rep.wall_s == 0.0


def test_planning_queries_project_queue_and_windows():
    tl = TransferTimeline(h2d_bandwidth=100.0)
    tl.install_durations({0: 1.0, 1: 2.0, 2: 4.0})
    tl.advance_to_moment(0)
    assert tl.projected_ready_s("h2d", 100) == 1.0
    tl.record_h2d(100, stream="a", critical=False)
    assert tl.projected_ready_s("h2d", 100) == 2.0  # behind the backlog
    # window until moment 2 = durations of moments 0 and 1
    assert tl.time_until(2) == 3.0
    assert tl.time_until(1) == 1.0
    assert tl.time_until(0) == 0.0


# ---------------------------------------------------------------------------
# training engine: decomposition + the bandwidth-aware win
# ---------------------------------------------------------------------------


def test_engine_infinite_bandwidth_zero_stall():
    cfg = _cfg()
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=4_000_000, policy="opt",
        device_aware_placement=False, timeline=TransferTimeline())
    batch = _lm_batch(cfg, 2, 32)
    eng.step(batch)
    for _ in range(2):
        m = eng.step(batch)
        t = m.timeline
        assert t is not None
        assert t.stall_s == 0.0
        assert t.compute_s > 0.0
        assert t.wall_s == t.step_s == t.compute_s


def test_engine_finite_bandwidth_decomposition_conserves():
    cfg = _cfg()
    tl = TransferTimeline(h2d_bandwidth=1e8, d2h_bandwidth=1e8)
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=4_000_000, policy="opt",
        device_aware_placement=False, timeline=tl)
    batch = _lm_batch(cfg, 2, 32)
    eng.step(batch)
    m = eng.step(batch)
    t = m.timeline
    assert t.stall_s > 0.0  # transfers this slow cannot all hide
    assert abs(t.wall_s - t.step_s) <= 1e-9 * t.wall_s
    # stall landed on real streams at real moments
    assert any(v > 0 for v in t.stall_by_stream.values())
    assert all(v >= 0 for v in t.stall_by_moment.values())
    eng.pool.check_invariants()


def test_bandwidth_aware_prefetch_cuts_stall_at_equal_volume():
    """The benchmark's acceptance bar in miniature: same bytes moved,
    same losses, less stall."""
    from repro.analysis.costmodel import train_operator_costs

    cfg = _cfg()
    batch = _lm_batch(cfg, 4, 64)

    def run(aware):
        tl = TransferTimeline()
        eng = PatrickStarEngine(
            model_class(cfg), cfg, device_memory_bytes=4_000_000,
            policy="opt", device_aware_placement=True, timeline=tl,
            bandwidth_aware_prefetch=aware)
        cb = eng.params_mgr.chunk_bytes
        costs = train_operator_costs(cfg, global_batch=4, seq_len=64,
                                     num_layer_ops=4, chunk_bytes=cb)
        bw = cb / costs.fwd_layer_s  # one chunk's wire = one fwd layer
        tl.h2d.bandwidth = bw
        tl.d2h.bandwidth = bw
        eng.step(batch)
        tot = {"h2d": 0, "d2h": 0, "stall": 0.0, "loss": []}
        for _ in range(2):
            m = eng.step(batch)
            tot["h2d"] += m.h2d_bytes + m.adam_h2d_bytes
            tot["d2h"] += m.d2h_bytes + m.adam_d2h_bytes
            tot["stall"] += m.timeline.stall_s
            tot["loss"].append(m.loss)
        return tot

    fixed = run(False)
    aware = run(True)
    assert aware["h2d"] == fixed["h2d"]
    assert aware["d2h"] == fixed["d2h"]
    assert aware["loss"] == fixed["loss"]
    assert aware["stall"] < fixed["stall"], (aware["stall"], fixed["stall"])


def test_engine_without_timeline_reports_none():
    cfg = _cfg(layers=2)
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=4_000_000, policy="opt")
    m = eng.step(_lm_batch(cfg, 2, 16))
    assert m.timeline is None


# ---------------------------------------------------------------------------
# distributed plane: the collective lane
# ---------------------------------------------------------------------------


def test_distributed_gather_stall_and_loss_parity():
    """Finite collective bandwidth surfaces gather stall; attaching the
    timeline never changes the math (losses equal the no-timeline run)."""
    from repro.core.distributed import DistributedPatrickStarEngine

    cfg = _cfg(layers=2)
    batch = _lm_batch(cfg, 4, 32)

    def run(factory):
        eng = DistributedPatrickStarEngine(
            model_class(cfg), cfg, nproc=2, device_memory_bytes=4_000_000,
            device_aware_placement=False, timeline_factory=factory)
        losses = [eng.step(batch).loss for _ in range(3)]
        eng.check_invariants()
        return eng, losses

    base, base_losses = run(None)
    timed, losses = run(lambda: TransferTimeline(collective_bandwidth=1e9))
    assert losses == base_losses
    m = timed.step(batch)
    assert base.step(batch).loss == m.loss
    for rm in m.rank_metrics:
        t = rm.timeline
        assert t.gather_stall_s > 0.0
        assert abs(t.wall_s - t.step_s) <= 1e-9 * max(t.wall_s, 1e-30)
    # collective byte ledger is untouched by the timeline
    assert timed.collectives[0].allgather_bytes \
        == base.collectives[0].allgather_bytes


# ---------------------------------------------------------------------------
# serving plane: per-round decomposition + batched decode
# ---------------------------------------------------------------------------


def _serve_cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def test_serving_round_decomposition_conserves():
    from repro.core.serving import ServingEngine

    cfg = _serve_cfg()
    eng = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_200_000,
        host_memory_bytes=8_000_000, max_seq_len=24,
        timeline=TransferTimeline(h2d_bandwidth=5e8, d2h_bandwidth=5e8))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(3), (5, 8), 0, cfg.vocab_size))
    for p in prompts:
        eng.submit(p, 6)
    mets = eng.run()
    eng.check_invariants()
    assert sum(m.timeline.compute_s for m in mets) > 0.0
    for m in mets:
        t = m.timeline
        assert t is not None
        assert abs(t.wall_s - t.step_s) <= 1e-9 * max(t.wall_s, 1e-30)


def test_serving_infinite_bandwidth_zero_stall():
    from repro.core.serving import ServingEngine

    cfg = _serve_cfg()
    eng = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_200_000,
        host_memory_bytes=8_000_000, max_seq_len=16,
        timeline=TransferTimeline())
    eng.submit(np.arange(4, dtype=np.int32) % cfg.vocab_size, 4)
    for m in eng.run():
        assert m.timeline.stall_s == 0.0


def test_batched_decode_matches_sequential_and_is_exercised():
    """Same-position sequences packed into one g.decode call emit the
    same tokens as the sequence-at-a-time path (max_decode_batch=1)."""
    from repro.core.serving import ServingEngine

    cfg = _serve_cfg()
    prompts = np.asarray(jax.random.randint(
        jax.random.key(4), (6, 7), 0, cfg.vocab_size))

    def serve(cap):
        eng = ServingEngine(
            model_class(cfg), cfg, device_memory_bytes=1_500_000,
            host_memory_bytes=8_000_000, max_seq_len=24,
            max_decode_batch=cap)
        rids = [eng.submit(p, 8) for p in prompts]
        eng.run()
        eng.check_invariants()
        return eng, [eng.result(r) for r in rids]

    eng_b, batched = serve(4)
    assert eng_b.max_decode_batch == 4
    eng_s, sequential = serve(1)
    assert batched == sequential
    # the auto cap actually batches on this budget
    eng_auto = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_500_000,
        host_memory_bytes=8_000_000, max_seq_len=24)
    assert eng_auto.max_decode_batch > 1


def test_decode_batches_group_same_position_capped():
    from repro.core.serving import ServeRequest, ServingEngine

    cfg = _serve_cfg()
    eng = ServingEngine(model_class(cfg), cfg,
                        device_memory_bytes=1_500_000,
                        host_memory_bytes=8_000_000, max_seq_len=16,
                        max_decode_batch=2)

    def req(rid, pos):
        r = ServeRequest(rid=rid, prompt=np.zeros(1, np.int32),
                         max_new_tokens=4)
        r.pos = pos
        return r

    reqs = [req(0, 5), req(1, 3), req(2, 5), req(3, 5), req(4, 3)]
    batches = eng._decode_batches(reqs)
    assert [[r.rid for r in b] for b in batches] == [[1, 4], [0, 2], [3]]
    assert all(len({r.pos for r in b}) == 1 for b in batches)
