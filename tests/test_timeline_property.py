"""Hypothesis property tests for the transfer timeline's conservation
invariants under random multi-stream chunk traffic with a (bandwidth-
aware) prefetcher running: ``hidden + critical == h2d`` still holds,
every stall is >= 0 and exactly 0 under infinite bandwidth, and the
per-step decomposition sums to step time."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, OutOfMemory, SchedulePrefetcher
from repro.core.state import TensorState
from repro.core.timeline import TransferTimeline

SIZE = 8  # elements per tensor == per chunk (one tensor per chunk)
CB = SIZE * 4  # chunk bytes (fp32)


@st.composite
def timeline_traffic(draw):
    n = draw(st.integers(2, 6))
    n_streams = draw(st.integers(1, 3))
    ops = draw(st.lists(
        st.tuples(st.integers(0, n_streams - 1), st.integers(0, n - 1),
                  st.sampled_from(["hold", "free"])),
        min_size=5, max_size=60))
    policy = draw(st.sampled_from(["opt", "lru", "fifo"]))
    device_chunks = draw(st.integers(1, n * n_streams))
    # finite bandwidths spanning instant-ish to glacial (bytes/sec), per
    # engine; None = infinite
    bw = lambda: draw(st.one_of(
        st.none(), st.floats(1.0, 1e6, allow_nan=False, allow_infinity=False)))
    h2d_bw, d2h_bw = bw(), bw()
    durations = draw(st.lists(
        st.floats(0.0, 2.0, allow_nan=False, allow_infinity=False),
        min_size=len(ops), max_size=len(ops)))
    aware = draw(st.booleans())
    return n, n_streams, ops, policy, device_chunks, h2d_bw, d2h_bw, \
        durations, aware


def _run(n, n_streams, ops, policy, device_chunks, h2d_bw, d2h_bw,
         durations, aware, check=None):
    """Replay one traffic sequence through a timeline-attached pool with
    a prefetcher consuming the exact future; returns the step report."""
    streams = [f"s{i}" for i in range(n_streams)]
    specs = [TensorSpec(f"t{i}", (SIZE,)) for i in range(n)]
    cmap = build_chunk_map(specs, SIZE)
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * CB,
        host_capacity_bytes=(n * n_streams + 2) * CB, policy=policy)
    tl = TransferTimeline(h2d_bandwidth=h2d_bw, d2h_bandwidth=d2h_bw)
    pool.set_timeline(tl)
    mgrs = {s: ChunkManager(cmap, name=s, pool=pool) for s in streams}
    # the exact future: per-stream OPT schedules + the staging queue
    per_stream: dict[str, dict[int, list[int]]] = {}
    refs = []
    for m, (s_idx, t_idx, _rel) in enumerate(ops):
        per_stream.setdefault(streams[s_idx], {}).setdefault(
            t_idx, []).append(m)
        refs.append((m, streams[s_idx], t_idx))
    for s, sched in per_stream.items():
        pool.register_moments(s, sched)
    tl.install_durations({m: d for m, d in enumerate(durations) if d > 0})
    pf = SchedulePrefetcher(pool, lookahead=4, max_inflight=2,
                            timeline=tl if aware else None)
    pf.install(refs)
    for m, (s_idx, t_idx, rel) in enumerate(ops):
        mgr = mgrs[streams[s_idx]]
        pool.set_moment(m)
        pf.advance(m)
        try:
            mgr.access_tensor(f"t{t_idx}")
        except OutOfMemory:
            break
        mgr.release_tensor(
            f"t{t_idx}",
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
        if check is not None:
            check(pool, tl)
    pool.check_invariants()
    return pool, tl.take_step()


@given(timeline_traffic())
@settings(max_examples=50, deadline=None)
def test_hidden_plus_critical_equals_h2d_with_timeline(t):
    """The overlap-split invariant survives the timeline hooks and the
    bandwidth-aware issue policy, at every intermediate point."""

    def check(pool, _tl):
        assert (pool.prefetch.hidden_h2d_bytes
                + pool.prefetch.critical_h2d_bytes) == pool.stats.h2d_bytes

    pool, _rep = _run(*t, check=check)
    assert (pool.prefetch.hidden_h2d_bytes
            + pool.prefetch.critical_h2d_bytes) == pool.stats.h2d_bytes


@given(timeline_traffic())
@settings(max_examples=50, deadline=None)
def test_decomposition_sums_to_step_time_and_stalls_nonnegative(t):
    """wall == compute + h2d_stall + d2h_stall + gather_stall (up to
    float associativity), every component >= 0, and the per-stream /
    per-moment maps only ever hold non-negative entries."""

    def check(_pool, tl):
        s = tl._step
        assert s.compute_s >= 0 and s.h2d_stall_s >= 0
        assert s.d2h_stall_s >= 0 and s.gather_stall_s >= 0
        assert all(v >= 0 for v in s.stall_by_stream.values())
        assert all(v >= 0 for v in s.stall_by_moment.values())

    _pool, rep = _run(*t, check=check)
    assert rep.compute_s >= 0 and rep.stall_s >= 0
    assert math.isclose(rep.wall_s, rep.step_s,
                        rel_tol=1e-9, abs_tol=1e-12), (rep.wall_s, rep.step_s)
    # stall is attributed: engine totals and the stream map agree
    assert math.isclose(sum(rep.stall_by_stream.values()), rep.stall_s,
                        rel_tol=1e-9, abs_tol=1e-12)


@given(timeline_traffic())
@settings(max_examples=50, deadline=None)
def test_infinite_bandwidth_stall_exactly_zero(t):
    """The same traffic under infinite bandwidth stalls EXACTLY zero
    seconds and completes in exactly the summed compute."""
    n, n_streams, ops, policy, device_chunks, _h, _d, durations, aware = t
    _pool, rep = _run(n, n_streams, ops, policy, device_chunks,
                      None, None, durations, aware)
    assert rep.stall_s == 0.0
    assert rep.stall_by_stream == {}
    assert rep.wall_s == rep.compute_s
