"""Two-tenant pool tests (core/memory.py Tenant layer, PR 9): tenant
creation and stream-name qualification, per-tenant accounting mirrors,
the priority/soft-budget eviction shield, over-budget-first victim
urgency, tenant-grouped OutOfMemory diagnostics, tenant-scoped staging,
acquire_pool lease resolution, and an always-on seeded random driver
asserting the co-tenancy invariants under interleaved traffic."""

import random

import pytest

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import (
    HeteroMemory,
    OutOfMemory,
    acquire_pool,
)
from repro.core.state import TensorState
from repro.core.timeline import TransferTimeline

SIZE = 8  # elements per tensor == per chunk (one tensor per chunk)
CB = SIZE * 4  # chunk bytes (fp32)


def _cmap(n):
    return build_chunk_map([TensorSpec(f"t{i}", (SIZE,)) for i in range(n)],
                           SIZE)


def _two_tenant_pool(
    *,
    policy="fifo",
    device_chunks=4,
    host_chunks=4,
    slow_chunks=None,
    serve_chunks=2,
    train_chunks=8,
    serve_priority=10,
    device_budget_chunks=2,
    host_budget_chunks=2,
    slow_budget_chunks=None,
):
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * CB,
        host_capacity_bytes=host_chunks * CB,
        slow_capacity_bytes=(None if slow_chunks is None
                             else slow_chunks * CB),
        policy=policy)
    serve = pool.create_tenant(
        "serve", priority=serve_priority,
        device_budget_bytes=(None if device_budget_chunks is None
                             else device_budget_chunks * CB),
        host_budget_bytes=(None if host_budget_chunks is None
                           else host_budget_chunks * CB),
        slow_budget_bytes=(None if slow_budget_chunks is None
                           else slow_budget_chunks * CB))
    kv = ChunkManager(_cmap(serve_chunks), name="kv", pool=pool,
                      tenant=serve)
    train = ChunkManager(_cmap(train_chunks), name="os", pool=pool)
    return pool, serve, kv, train


def _hold(mgr, i, dev="device"):
    mgr.access_tensor(f"t{i}", dev)
    mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)


# ---------------------------------------------------------------------------
# tenant registry + stream naming
# ---------------------------------------------------------------------------


def test_create_tenant_validation():
    pool = HeteroMemory(device_capacity_bytes=4 * CB)
    with pytest.raises(ValueError, match="invalid tenant name"):
        pool.create_tenant("")
    with pytest.raises(ValueError, match="invalid tenant name"):
        pool.create_tenant("a:b")
    pool.create_tenant("serve")
    with pytest.raises(ValueError, match="already exists"):
        pool.create_tenant("serve")
    with pytest.raises(ValueError, match="already exists"):
        pool.create_tenant("default")


def test_stream_names_are_tenant_qualified():
    """Two tenants can both own a "param" stream: named tenants' streams
    register pool-wide as "tenant:stream", the default tenant keeps the
    historical bare names."""
    pool = HeteroMemory(device_capacity_bytes=8 * CB)
    serve = pool.create_tenant("serve")
    a = ChunkManager(_cmap(2), name="param", pool=pool)
    b = ChunkManager(_cmap(2), name="param", pool=pool, tenant=serve)
    assert a.name == "param"
    assert b.name == "serve:param"
    assert set(pool.streams) == {"param", "serve:param"}
    assert a.tenant is pool.default_tenant
    assert b.tenant is serve
    assert serve.qualify("kv") == "serve:kv"
    assert pool.default_tenant.qualify("kv") == "kv"


def test_stream_rejects_tenant_from_other_pool():
    pool_a = HeteroMemory(device_capacity_bytes=4 * CB)
    pool_b = HeteroMemory(device_capacity_bytes=4 * CB)
    foreign = pool_b.create_tenant("serve")
    with pytest.raises(ValueError, match="different pool"):
        ChunkManager(_cmap(2), name="kv", pool=pool_a, tenant=foreign)


def test_tenant_counters_mirror_streams():
    """Per-tenant tier counters equal the sum over the tenant's streams,
    and the tenants' sums equal the pool totals (also re-asserted from
    scratch by check_invariants)."""
    pool, serve, kv, train = _two_tenant_pool()
    _hold(kv, 0)
    _hold(kv, 1, "host")
    _hold(train, 0)
    _hold(train, 1)
    assert serve.device_bytes_used() == CB
    assert serve.host_bytes_used() == CB
    assert pool.default_tenant.device_bytes_used() == 2 * CB
    assert (serve.device_bytes_used()
            + pool.default_tenant.device_bytes_used()
            == pool.device_bytes_used())
    assert (serve.host_bytes_used()
            + pool.default_tenant.host_bytes_used()
            == pool.host_bytes_used())
    pool.check_invariants()


# ---------------------------------------------------------------------------
# priority shield + victim urgency
# ---------------------------------------------------------------------------


def test_priority_shield_protects_in_budget_tenant():
    """A higher-priority tenant within its soft budget never loses a
    chunk to a lower-priority tenant's demand: the trainer fills the rest
    of the device tier, then its next admission must evict ITS OWN chunks
    (or refuse), never serve's."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=4, host_chunks=8, serve_chunks=2,
        device_budget_chunks=2)
    _hold(kv, 0)
    _hold(kv, 1)  # serve at its device budget (2 chunks), not over
    for i in range(4):  # 2 fit, then each admission must victimize train
        _hold(train, i)
    assert kv.location(0) == "device"
    assert kv.location(1) == "device"
    assert pool.evictions[("serve", "default")] == 0
    assert pool.evictions[("default", "default")] >= 2
    pool.check_invariants()


def test_priority_shield_drops_when_over_budget():
    """The shield covers only IN-budget residency: a high-priority tenant
    holding more than its soft budget on a tier is fair game there (the
    shared overflow region drains first).

    The over-budget state is built with access-without-release:
    COMPUTE-pinned chunks cannot be self-evicted, so the budget loop
    yields softly and serve lands three resident chunks against a
    one-chunk budget once they drop to HOLD."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=4, host_chunks=8, serve_chunks=3,
        device_budget_chunks=1)
    for i in range(3):  # pin 3 chunks against a 1-chunk budget
        kv.access_tensor(f"t{i}")
    for i in range(3):
        kv.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)
    assert serve.over_budget("device")
    _hold(train, 0)  # fills the 4th slot, no eviction yet
    _hold(train, 1)
    _hold(train, 2)
    # the over-budget serve chunks were reclaimed first, down to (not
    # below) serve's soft budget; the next trainer admission then has to
    # victimize the trainer's own chunks
    assert pool.evictions[("serve", "default")] == 2
    assert serve.device_bytes_used() == CB
    assert not serve.over_budget("device")
    _hold(train, 3)
    assert pool.evictions[("serve", "default")] == 2
    assert serve.device_bytes_used() == CB
    pool.check_invariants()


def test_over_budget_tenant_gives_up_chunks_first():
    """Victim urgency: chunks of a tenant over its soft budget are
    reclaimed before the other tenant's residency, even when FIFO age
    says otherwise (serve's chunks are YOUNGER here).  Serve goes over
    budget by holding both chunks in COMPUTE simultaneously — the budget
    self-eviction loop cannot touch pinned chunks."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=4, host_chunks=8, serve_chunks=2,
        serve_priority=0, device_budget_chunks=1)
    _hold(train, 0)  # oldest arrival
    kv.access_tensor("t0")
    kv.access_tensor("t1")
    kv.release_tensor("t0", TensorState.HOLD_AFTER_FWD)
    kv.release_tensor("t1", TensorState.HOLD_AFTER_FWD)
    assert serve.over_budget("device")
    _hold(train, 1)
    _hold(train, 2)  # must evict: urgency picks over-budget serve first
    assert pool.evictions[("serve", "default")] == 1
    assert train.location(0) == "device"  # FIFO-oldest but in budget
    pool.check_invariants()


def test_budgeted_tenant_self_evicts_to_budget():
    """A tenant with a device soft budget reproduces, against its share,
    the eviction pressure a solo pool cap would exert: sequential HOLD
    accesses beyond the budget evict the tenant's OWN colder chunks even
    when the shared pool has plenty of free device space."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=8, host_chunks=8, serve_chunks=3,
        device_budget_chunks=1, host_budget_chunks=None)
    _hold(kv, 0)
    _hold(kv, 1)  # over budget -> t0 self-evicted to host
    _hold(kv, 2)  # and again for t1
    assert serve.device_bytes_used() == CB
    assert not serve.over_budget("device")
    assert pool.evictions[("serve", "serve")] == 2
    assert pool.evictions[("serve", "default")] == 0
    assert kv.location(0) == "host"
    assert kv.location(1) == "host"
    assert kv.location(2) == "device"
    pool.check_invariants()


def test_shielded_oom_names_blocking_tenant():
    """When every candidate is shielded by a higher-priority tenant's
    soft budget, the refusal says so and names the tenant — and the usage
    report groups streams per tenant with [used/budget] annotations."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=2, host_chunks=2, serve_chunks=2, train_chunks=4,
        device_budget_chunks=2, host_budget_chunks=2)
    _hold(kv, 0)
    _hold(kv, 1)  # serve fills the device tier, within budget
    _hold(train, 0, "host")
    _hold(train, 1, "host")  # host full too: no cascade escape
    with pytest.raises(OutOfMemory) as ei:
        _hold(train, 2)
    msg = str(ei.value)
    assert "shielded by the soft budget of higher-priority tenant(s): serve" \
        in msg
    assert "serve[64/64]" in msg  # tenant-grouped report with budgets
    assert "serve:kv=" in msg
    assert "default[" in msg
    pool.check_invariants()


def test_single_tenant_oom_report_unchanged():
    """With only the default tenant the report keeps the historical
    per-stream shape — no tenant grouping, no budget annotations."""
    pool = HeteroMemory(device_capacity_bytes=CB, host_capacity_bytes=CB)
    mgr = ChunkManager(_cmap(3), name="param", pool=pool)
    _hold(mgr, 0)
    _hold(mgr, 1, "host")
    mgr.access_tensor("t0")  # pin t0 in COMPUTE
    with pytest.raises(OutOfMemory) as ei:
        mgr.access_tensor("t2")
    msg = str(ei.value)
    assert "tier usage by stream" in msg
    assert "param=" in msg
    assert "default[" not in msg
    assert "shielded" not in msg


def test_equal_priority_sees_no_shield():
    """The shield needs strictly higher priority: between equal-priority
    tenants, soft budgets only set urgency, never block eviction."""
    pool, serve, kv, train = _two_tenant_pool(
        device_chunks=2, host_chunks=8, serve_chunks=2,
        serve_priority=0, device_budget_chunks=2)
    _hold(kv, 0)
    _hold(kv, 1)
    _hold(train, 0)  # evicts a serve chunk despite serve being in budget
    assert pool.evictions[("serve", "default")] == 1
    pool.check_invariants()


# ---------------------------------------------------------------------------
# staging stays tenant-scoped
# ---------------------------------------------------------------------------


def test_staging_never_reclaims_other_tenants_residency():
    """A tenant's prefetch staging may only evict ITS OWN device
    residents: cross-tenant space is taken on the demand path (under the
    shield), never by the speculative staging path."""
    pool = HeteroMemory(device_capacity_bytes=2 * CB,
                        host_capacity_bytes=8 * CB, policy="opt")
    serve = pool.create_tenant("serve")
    kv = ChunkManager(_cmap(2), name="kv", pool=pool, tenant=serve)
    train = ChunkManager(_cmap(2), name="os", pool=pool)
    _hold(train, 0)
    _hold(train, 1)  # device full with default-tenant chunks
    _hold(kv, 0, "host")  # serve's chunk parked on host
    kv.register_moments({0: [100]})
    train.register_moments({0: [500], 1: [600]})  # far, tempting victims
    assert pool.stage("serve:kv", 0) is False  # refused: not serve's space
    assert train.location(0) == "device"
    assert train.location(1) == "device"
    assert pool.staged_count(serve) == 0
    pool.check_invariants()


# ---------------------------------------------------------------------------
# acquire_pool / PoolLease resolution
# ---------------------------------------------------------------------------


def test_acquire_pool_owned_builds_private_pool():
    lease = acquire_pool(device_memory_bytes=4 * CB,
                         host_memory_bytes=8 * CB, policy="fifo")
    assert lease.owned
    assert lease.tenant is lease.pool.default_tenant
    assert lease.device_bytes == 4 * CB
    assert lease.host_bytes == 8 * CB
    assert lease.pool.policy == "fifo"
    mgr = lease.stream("param", _cmap(2))
    assert mgr.name == "param"  # default tenant: historical bare name
    assert mgr.pool is lease.pool


def test_acquire_pool_validation():
    pool = HeteroMemory(device_capacity_bytes=4 * CB)
    other = HeteroMemory(device_capacity_bytes=4 * CB)
    with pytest.raises(ValueError, match="owned pool needs"):
        acquire_pool()
    with pytest.raises(ValueError, match="requires an external pool"):
        acquire_pool(tenant=pool.create_tenant("t"),
                     device_memory_bytes=4 * CB)
    with pytest.raises(ValueError, match="different pool"):
        acquire_pool(pool=other, tenant=pool.tenants["t"])
    with pytest.raises(ValueError, match="own their timeline"):
        acquire_pool(pool=pool, timeline=TransferTimeline())


def test_acquire_pool_share_resolution():
    """External-lease planning shares resolve explicit arg -> tenant soft
    budget -> pool cap, per tier independently."""
    pool = HeteroMemory(device_capacity_bytes=10 * CB,
                        host_capacity_bytes=20 * CB,
                        slow_capacity_bytes=30 * CB)
    t = pool.create_tenant("serve", device_budget_bytes=4 * CB)
    lease = acquire_pool(pool=pool, tenant=t, host_memory_bytes=5 * CB)
    assert not lease.owned
    assert lease.device_bytes == 4 * CB  # tenant soft budget
    assert lease.host_bytes == 5 * CB  # explicit override
    assert lease.slow_bytes == 30 * CB  # pool cap fallback
    mgr = lease.stream("param", _cmap(2))
    assert mgr.name == "serve:param"
    assert mgr.tenant is t


# ---------------------------------------------------------------------------
# always-on seeded two-tenant driver (the hypothesis-free variant of the
# property suite: runs in every environment)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fifo", "lru", "opt"])
def test_random_interleaved_traffic_holds_cotenancy_invariants(policy):
    """Seeded random interleaving of two tenants' chunk traffic on a
    three-tier pool.  After EVERY operation: no tier exceeds its cap,
    per-tenant counters sum to pool usage (check_invariants), and — since
    serve's whole footprint fits inside its per-tier soft budgets, so it
    can never be over budget anywhere — the higher-priority serve tenant
    never loses a chunk to the trainer (evictions ledger stays zero)."""
    rng = random.Random(1234 + len(policy))
    pool, serve, kv, train = _two_tenant_pool(
        policy=policy, device_chunks=5, host_chunks=4, slow_chunks=16,
        serve_chunks=4, train_chunks=12, serve_priority=10,
        device_budget_chunks=4, host_budget_chunks=4, slow_budget_chunks=4)
    dev_cap, host_cap, slow_cap = 5 * CB, 4 * CB, 16 * CB
    oom = 0
    for m in range(400):
        pool.set_moment(m)
        if rng.random() < 0.3:
            mgr, n = kv, 4
        else:
            mgr, n = train, 12
        i = rng.randrange(n)
        dev = "device" if rng.random() < 0.75 else "host"
        try:
            mgr.access_tensor(f"t{i}", dev)
        except OutOfMemory:
            oom += 1
            pool.check_invariants()
            continue
        mgr.release_tensor(
            f"t{i}",
            TensorState.HOLD_AFTER_FWD if rng.random() < 0.8
            else TensorState.FREE)
        assert pool.device_bytes_used() <= dev_cap
        assert pool.host_bytes_used() <= host_cap
        assert pool.slow_bytes_used() <= slow_cap
        assert (serve.bytes_used(dev)
                + pool.default_tenant.bytes_used(dev)
                == pool._used(dev))
        # serve's 4 chunks always fit its 4-chunk budgets -> never over
        # budget -> the shield must have held on every tier
        assert pool.evictions[("serve", "default")] == 0
        pool.check_invariants()
    # the run must actually have exercised contention, not idled
    assert pool.evictions[("default", "default")] > 0 or oom > 0
    assert serve.stats.total_bytes > 0
    assert pool.default_tenant.stats.total_bytes > 0
