"""Hypothesis property tests for the unified heterogeneous pool
(core/memory.py): budgets hold under arbitrary multi-stream chunk
traffic, OPT eviction replays Belady exactly on random schedules, and the
per-stream incremental counters always sum to the pool's."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, OutOfMemory
from repro.core.state import TensorState

SIZE = 8  # elements per tensor == per chunk (one tensor per chunk)
CB = SIZE * 4  # chunk bytes (fp32)


def _pool(n_tensors, device_chunks, policy, stream_names,
          host_chunks=None, slow_chunks=None):
    specs = [TensorSpec(f"t{i}", (SIZE,)) for i in range(n_tensors)]
    cmap = build_chunk_map(specs, SIZE)
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * CB,
        host_capacity_bytes=None if host_chunks is None else host_chunks * CB,
        slow_capacity_bytes=None if slow_chunks is None else slow_chunks * CB,
        policy=policy)
    mgrs = {s: ChunkManager(cmap, name=s, pool=pool) for s in stream_names}
    return pool, mgrs


@st.composite
def traffic(draw):
    n = draw(st.integers(2, 6))
    n_streams = draw(st.integers(1, 4))
    streams = [f"s{i}" for i in range(n_streams)]
    ops = draw(st.lists(
        st.tuples(st.integers(0, n_streams - 1), st.integers(0, n - 1),
                  st.sampled_from(["hold", "free"])),
        min_size=5, max_size=80))
    policy = draw(st.sampled_from(["opt", "lru", "fifo"]))
    device_chunks = draw(st.integers(1, n * n_streams))
    return n, streams, ops, policy, device_chunks


@given(traffic())
@settings(max_examples=60, deadline=None)
def test_budget_never_exceeded_under_random_traffic(t):
    """Neither tier ever exceeds its byte budget, at ANY intermediate
    point, no matter how many streams contend for the one device budget.
    (OutOfMemory is an acceptable outcome on infeasible sequences; a
    budget violation never is.)"""
    n, streams, ops, policy, device_chunks = t
    host_chunks = n * len(streams) + 2  # bounded host: cascades exercise it
    pool, mgrs = _pool(n, device_chunks, policy, streams,
                       host_chunks=host_chunks)
    dev_cap = device_chunks * CB
    host_cap = host_chunks * CB
    for m, (s_idx, t_idx, rel) in enumerate(ops):
        mgr = mgrs[streams[s_idx]]
        pool.set_moment(m)
        try:
            mgr.access_tensor(f"t{t_idx}")
        except OutOfMemory:
            pool.check_invariants()
            return
        mgr.release_tensor(
            f"t{t_idx}",
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
        assert pool.device_bytes_used() <= dev_cap
        assert pool.host_bytes_used() <= host_cap
        pool.check_invariants()


@given(traffic())
@settings(max_examples=60, deadline=None)
def test_stream_counters_sum_to_pool_usage(t):
    """The per-stream incremental device/host counters sum to the pool's
    O(1) totals after every operation (and the slow payload-scan agrees,
    via check_invariants)."""
    n, streams, ops, policy, device_chunks = t
    pool, mgrs = _pool(n, device_chunks, policy, streams)
    for m, (s_idx, t_idx, rel) in enumerate(ops):
        mgr = mgrs[streams[s_idx]]
        pool.set_moment(m)
        mgr.access_tensor(f"t{t_idx}")
        mgr.release_tensor(
            f"t{t_idx}",
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
        assert sum(g.device_bytes_used() for g in mgrs.values()) \
            == pool.device_bytes_used()
        assert sum(g.host_bytes_used() for g in mgrs.values()) \
            == pool.host_bytes_used()
        assert pool.device_bytes_used() + pool.host_bytes_used() \
            == sum(g.device_bytes_used() + g.host_bytes_used()
                   for g in mgrs.values())
        pool.check_invariants()


@st.composite
def tiered_traffic(draw):
    n, streams, ops, policy, device_chunks = draw(traffic())
    host_chunks = draw(st.integers(1, n * len(streams) + 2))
    slow_chunks = draw(st.integers(1, n * len(streams) + 2))
    return n, streams, ops, policy, device_chunks, host_chunks, slow_chunks


@given(tiered_traffic())
@settings(max_examples=60, deadline=None)
def test_three_tier_budgets_never_exceeded(t):
    """With a bounded slow tier behind the host, NO tier ever exceeds its
    byte budget at any intermediate point (check_invariants asserts every
    tier's cap after every move), and the per-stream counters — slow tier
    included — sum to the pool's totals.  OutOfMemory is acceptable on
    infeasible sequences; an overflow never is."""
    n, streams, ops, policy, device_chunks, host_chunks, slow_chunks = t
    pool, mgrs = _pool(n, device_chunks, policy, streams,
                       host_chunks=host_chunks, slow_chunks=slow_chunks)
    for m, (s_idx, t_idx, rel) in enumerate(ops):
        mgr = mgrs[streams[s_idx]]
        pool.set_moment(m)
        try:
            mgr.access_tensor(f"t{t_idx}")
        except OutOfMemory:
            pool.check_invariants()
            return
        mgr.release_tensor(
            f"t{t_idx}",
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
        assert pool.device_bytes_used() <= device_chunks * CB
        assert pool.host_bytes_used() <= host_chunks * CB
        assert pool.slow_bytes_used() <= slow_chunks * CB
        assert sum(g.slow_bytes_used() for g in mgrs.values()) \
            == pool.slow_bytes_used()
        assert sum(g.device_bytes_used() + g.host_bytes_used()
                   + g.slow_bytes_used() for g in mgrs.values()) \
            == (pool.device_bytes_used() + pool.host_bytes_used()
                + pool.slow_bytes_used())
        pool.check_invariants()


@st.composite
def opt_schedules(draw):
    n = draw(st.integers(2, 8))
    pattern = draw(st.lists(st.integers(0, n - 1), min_size=5, max_size=80))
    device_chunks = draw(st.integers(1, n))
    return n, pattern, device_chunks


def _belady_misses(pattern, cap):
    """Reference Belady/MIN replay: on a miss with a full cache, evict the
    resident chunk whose next reference is farthest (absent = infinity).
    Ties only occur between never-referenced-again chunks, which are
    interchangeable, so the miss count is deterministic."""
    resident: set[int] = set()
    misses = 0
    for i, c in enumerate(pattern):
        if c in resident:
            continue
        misses += 1
        if len(resident) >= cap:
            future = {}
            for r in resident:
                nxt = next((j for j in range(i + 1, len(pattern))
                            if pattern[j] == r), None)
                future[r] = len(pattern) + 1 if nxt is None else nxt
            resident.discard(max(resident, key=lambda r: future[r]))
        resident.add(c)
    return misses


@st.composite
def two_tenant_traffic(draw):
    """Interleaved traffic for a shielded "serve" tenant and the default
    "train" tenant on a three-tier pool.  Serve's soft budgets are sized
    to its WHOLE footprint on every tier, so it can never run over
    budget and the priority shield must hold unconditionally."""
    serve_chunks = draw(st.integers(1, 3))
    train_chunks = draw(st.integers(2, 8))
    device_chunks = draw(st.integers(serve_chunks + 1, serve_chunks + 6))
    host_chunks = draw(st.integers(serve_chunks, serve_chunks + 6))
    slow_chunks = draw(st.integers(serve_chunks, 16))
    ops = draw(st.lists(
        st.tuples(
            st.booleans(),  # True -> serve tenant
            st.integers(0, 7),  # tensor index (mod the stream's size)
            st.sampled_from(["device", "host"]),
            st.sampled_from(["hold", "free"])),
        min_size=5, max_size=80))
    policy = draw(st.sampled_from(["opt", "lru", "fifo"]))
    return (serve_chunks, train_chunks, device_chunks, host_chunks,
            slow_chunks, ops, policy)


@given(two_tenant_traffic())
@settings(max_examples=60, deadline=None)
def test_two_tenant_traffic_holds_cotenancy_invariants(t):
    """Arbitrary interleaved two-tenant traffic: no tier ever exceeds its
    cap, per-tenant counters sum to pool usage after every operation, and
    the higher-priority serve tenant — in budget by construction — never
    loses a chunk to the trainer (the evictions ledger stays zero).
    OutOfMemory is acceptable on infeasible sequences; a cap overflow or
    a shield breach never is."""
    (serve_chunks, train_chunks, device_chunks, host_chunks, slow_chunks,
     ops, policy) = t
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * CB,
        host_capacity_bytes=host_chunks * CB,
        slow_capacity_bytes=slow_chunks * CB,
        policy=policy)
    serve = pool.create_tenant(
        "serve", priority=10,
        device_budget_bytes=serve_chunks * CB,
        host_budget_bytes=serve_chunks * CB,
        slow_budget_bytes=serve_chunks * CB)
    kv = ChunkManager(_cmap_n(serve_chunks), name="kv", pool=pool,
                      tenant=serve)
    train = ChunkManager(_cmap_n(train_chunks), name="os", pool=pool)
    for m, (is_serve, t_idx, dev, rel) in enumerate(ops):
        pool.set_moment(m)
        mgr, n = (kv, serve_chunks) if is_serve else (train, train_chunks)
        name = f"t{t_idx % n}"
        try:
            mgr.access_tensor(name, dev)
        except OutOfMemory:
            pool.check_invariants()
            assert pool.evictions[("serve", "default")] == 0
            return
        mgr.release_tensor(
            name,
            TensorState.HOLD_AFTER_FWD if rel == "hold" else TensorState.FREE)
        assert pool.device_bytes_used() <= device_chunks * CB
        assert pool.host_bytes_used() <= host_chunks * CB
        assert pool.slow_bytes_used() <= slow_chunks * CB
        for tier in ("device", "host", "slow"):
            assert (serve.bytes_used(tier)
                    + pool.default_tenant.bytes_used(tier)
                    == pool._used(tier))
        assert pool.evictions[("serve", "default")] == 0
        pool.check_invariants()


def _cmap_n(n):
    return build_chunk_map([TensorSpec(f"t{i}", (SIZE,)) for i in range(n)],
                           SIZE)


@given(opt_schedules())
@settings(max_examples=60, deadline=None)
def test_opt_eviction_matches_belady_replay(t):
    """The pool's OPT policy, fed the full future-reference schedule (as
    the warm-up tracer provides it), must produce EXACTLY the reference
    Belady miss count on random access patterns — the schedule plumbing
    (per-stream moments, bisect semantics at the access moment) loses no
    future knowledge."""
    n, pattern, device_chunks = t
    pool, mgrs = _pool(n, device_chunks, "opt", ["param"])
    mgr = mgrs["param"]
    moments: dict[int, list[int]] = {}
    for m, c in enumerate(pattern):
        moments.setdefault(c, []).append(m)
    mgr.register_moments(moments)
    misses = 0
    for m, c in enumerate(pattern):
        pool.set_moment(m)
        if mgr.location(c) != "device":  # first touch or was evicted
            misses += 1
        mgr.access_tensor(f"t{c}")
        mgr.release_tensor(f"t{c}", TensorState.HOLD_AFTER_FWD)
        assert pool.device_bytes_used() <= device_chunks * CB
    assert misses == _belady_misses(pattern, device_chunks)
    pool.check_invariants()
