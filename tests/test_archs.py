"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED config on the smoke mesh
(dp=2 x tp=2), runs 3 chunked-ZeRO train steps (loss finite, decreasing,
shapes right, no NaNs in the updated stores), and one prefill+decode step
where the family supports decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, model_class
from repro.configs.base import INPUT_SHAPES, InputShape
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gpt2-paper")]


def _batch(cfg, b, s, key):
    ks = jax.random.split(key, 3)
    if cfg.arch_type == "audio":
        f = min(cfg.encoder_frames, s)
        return {"frames": jax.random.normal(ks[0], (b, f, cfg.frontend_dim)),
                "tokens": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
                "labels": jax.random.randint(ks[2], (b, s), 0, cfg.vocab_size),
                "global_tokens": jnp.float32(b * s)}
    if cfg.arch_type == "vlm":
        st = s - cfg.num_patches
        return {"patch_embeds": jax.random.normal(
                    ks[0], (b, cfg.num_patches, cfg.vision_dim)),
                "tokens": jax.random.randint(ks[1], (b, st), 0, cfg.vocab_size),
                "labels": jax.random.randint(ks[2], (b, st), 0, cfg.vocab_size),
                "global_tokens": jnp.float32(b * st)}
    tok = jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


@pytest.fixture(scope="module")
def mesh():
    return make_smoke_mesh(2, 2)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_and_decode(arch, mesh):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if hasattr(cfg, "n_experts"):
        assert cfg.n_experts <= 4
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, oss = driver.init_state(rt, jax.random.key(0))
    shape = InputShape("smoke", 64, 4, "train")
    step, _, _ = driver.build_train_step(rt, shape)
    batch = _batch(cfg, 4, 64, jax.random.key(1))
    losses = []
    for i in range(3):
        ps, oss, m = step(ps, oss, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses  # memorizes the repeated batch
    # updated stores stay finite (no NaN blowups through ADAM)
    for name, arr in ps.items():
        assert bool(jnp.isfinite(arr.astype(jnp.float32)).all()), name

    if rt.model.supports_decode:
        sshape = InputShape("serve", 64, 4, "decode")
        dec, _ = driver.build_decode_step(rt, sshape)
        caches = driver.init_caches(rt, sshape)
        tok = jnp.zeros((4, 1), jnp.int32)
        nxt, caches2 = dec(ps, caches, tok, jnp.int32(5))
        nxt = np.asarray(nxt)
        assert nxt.shape == (4,)
        assert ((0 <= nxt) & (nxt < cfg.vocab_size)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_metadata(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400),
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, n_heads=16,
                           n_kv_heads=8, d_ff=3072, vocab_size=151936),
        "deepseek-7b": dict(num_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, n_heads=32,
                            vocab_size=32000),
        "xlstm-1.3b": dict(num_layers=48, d_model=2048, n_heads=4,
                           vocab_size=50304),
        "nemotron-4-340b": dict(num_layers=96, d_model=18432, n_heads=96,
                                n_kv_heads=8, d_ff=73728, vocab_size=256000),
        "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, n_heads=32,
                                  d_ff=8192, vocab_size=32064),
        "qwen2.5-3b": dict(num_layers=36, d_model=2048, n_heads=16,
                           n_kv_heads=2, d_ff=11008, vocab_size=151936),
        "whisper-large-v3": dict(num_layers=32, d_model=1280, n_heads=20,
                                 d_ff=5120, vocab_size=51866),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, vocab_size=32000),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "mixtral-8x7b":
        assert cfg.n_experts == 8 and cfg.top_k == 2
        assert cfg.sliding_window == 4096
    if arch == "deepseek-v2-lite-16b":
        assert cfg.kv_lora_rank == 512 and cfg.top_k == 6
        assert cfg.n_shared_experts == 2
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64
    # long_500k only for sub-quadratic families
    subq = {"zamba2-1.2b", "xlstm-1.3b", "mixtral-8x7b"}
    assert ("long_500k" in cfg.supported_shapes()) == (arch in subq)
