"""MoE routing/dispatch properties + correctness vs a dense-masked oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models import moe as MOE


def _cfg(e=4, k=2, dff=16, d=8, cap=1.25):
    return MoEConfig(name="t", d_model=d, n_heads=2, n_kv_heads=2, head_dim=4,
                     d_ff=16, d_ff_expert=dff, vocab_size=32, n_experts=e,
                     top_k=k, capacity_factor=cap, router_aux_coef=0.0)


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]))
@settings(max_examples=30, deadline=None)
def test_dispatch_invariants(seed, e, k):
    t = 16
    idx = jax.random.randint(jax.random.key(seed), (t, k), 0, e)
    cap = 6
    pos, keep, slot_to_token = MOE.dispatch_indices(idx, e, cap)
    pos, keep, s2t = map(np.asarray, (pos, keep, slot_to_token))
    # kept slots hold valid token ids; dropped never exceed capacity rule
    assert ((0 <= s2t) & (s2t <= t)).all()
    # each expert receives at most `cap` kept assignments
    for ex in range(e):
        kept = ((np.asarray(idx) == ex) & keep).sum()
        assert kept <= cap
    # kept assignments have unique (expert, position) slots
    slots = np.asarray(idx) * cap + np.minimum(pos, cap - 1)
    kept_slots = slots[keep]
    assert len(np.unique(kept_slots)) == len(kept_slots)
    # the slot map inverts the assignment for every kept pair
    tok_idx = np.repeat(np.arange(t), k).reshape(t, k)
    for (ti, ki) in zip(*np.nonzero(keep)):
        assert s2t[slots[ti, ki]] == tok_idx[ti, ki]


def test_moe_matches_dense_masked_oracle():
    """With capacity high enough that nothing drops, the gather/scatter
    dispatch must equal the dense 'every expert on every token' oracle."""
    cfg = _cfg(e=4, k=2, cap=8.0)  # no drops
    p = MOE.init_moe_mlp(jax.random.key(0), cfg, 1, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, _ = MOE.moe_fwd(p, x, cfg, L.AxisCtx())

    xt = x.reshape(-1, cfg.d_model)
    probs, idx, _ = MOE.route_topk(xt, p["router"], cfg)
    act = L.ACTIVATIONS[cfg.activation]
    dense = jnp.einsum(
        "td,edf->tef", xt, p["w_gate"])
    up = jnp.einsum("td,edf->tef", xt, p["w_up"])
    h = act(dense) * up
    out_all = jnp.einsum("tef,efd->ted", h, p["w_down"])  # [T,E,d]
    want = jnp.zeros_like(xt)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(out_all, idx[:, k][:, None, None].repeat(
            cfg.d_model, axis=2), axis=1)[:, 0]
        want = want + probs[:, k][:, None] * sel
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), atol=1e-4)


def test_capacity_drops_tokens():
    cfg = _cfg(e=2, k=1, cap=0.5)
    p = MOE.init_moe_mlp(jax.random.key(0), cfg, 1, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    y, _ = MOE.moe_fwd(p, x, cfg, L.AxisCtx())
    # with cap 0.5 some tokens get zero expert output (dropped)
    norms = np.linalg.norm(np.asarray(y.reshape(-1, cfg.d_model)), axis=-1)
    assert (norms < 1e-6).any()


def test_aux_loss_penalizes_imbalance():
    cfg = _cfg(e=4, k=1)._replace if False else _cfg(e=4, k=1).replace(
        router_aux_coef=1.0)
    t, d = 64, cfg.d_model
    x = jax.random.normal(jax.random.key(2), (t, d))
    # balanced router vs collapsed router
    w_bal = jnp.zeros((d, 4))
    w_col = jnp.zeros((d, 4)).at[:, 0].set(10.0)
    _, _, aux_bal = MOE.route_topk(x, w_bal, cfg)
    _, _, aux_col = MOE.route_topk(x, w_col, cfg)
    assert float(aux_col) > float(aux_bal)
