"""Rank-sharded serving fleet (core/distributed.py:
DistributedServingEngine): round-robin placement, lock-step rounds,
additive capacity, the rank-local-KV zero-collectives invariant, and
token parity with the single-rank oracle."""

import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.distributed import DistributedServingEngine
from repro.core.serving import ServingEngine


def _cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _prompts(cfg, n, plen, seed=17):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
            for _ in range(n)]


def test_fleet_parity_and_zero_collectives():
    """A 2-rank paged fleet serves the same burst to the same tokens as
    one engine, places sequences round-robin, books ZERO collective
    bytes on every rank, and sums per-rank capacity."""
    cfg = _cfg()
    prompts = _prompts(cfg, 6, 8)
    news = [8, 4, 8, 6, 8, 5]

    oracle_eng = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_300_000,
        host_memory_bytes=8_000_000, max_seq_len=40, page_tokens=8)
    rids = [oracle_eng.submit(p, n) for p, n in zip(prompts, news)]
    oracle_eng.run()
    oracle = [oracle_eng.result(r) for r in rids]

    fleet = DistributedServingEngine(
        model_class(cfg), cfg, nproc=2, device_memory_bytes=1_300_000,
        host_memory_bytes=8_000_000, max_seq_len=40, page_tokens=8)
    gids = [fleet.submit(p, n) for p, n in zip(prompts, news)]
    # round-robin placement: alternating ranks, in submit order
    assert [fleet._placement[g][0] for g in gids] == [0, 1, 0, 1, 0, 1]
    mets = fleet.run()
    fleet.check_invariants()  # includes the zero-collectives assertion

    assert [fleet.result(g) for g in gids] == oracle
    assert fleet.total_decode_tokens == oracle_eng.total_decode_tokens
    assert fleet.total_prefill_tokens == oracle_eng.total_prefill_tokens
    assert fleet.peak_concurrency == sum(
        c.peak_concurrency for c in fleet.ranks)
    # fleet metrics aggregate per-rank rounds
    assert sum(m.completed for m in mets) == len(prompts)
    assert all(m.peak_device_bytes <= 1_300_000 for m in mets)
    assert fleet.active_count == 0 and fleet.queued_count == 0
    assert fleet.step_round() is None  # drained


def test_fleet_validates_nproc():
    cfg = _cfg()
    with pytest.raises(ValueError, match="nproc"):
        DistributedServingEngine(
            model_class(cfg), cfg, nproc=0, device_memory_bytes=1_300_000,
            host_memory_bytes=8_000_000, max_seq_len=24)


@pytest.mark.slow
def test_fleet_compiled_multi_rank_parity():
    """Compiled cores under the fleet driver: a 2-rank compiled paged
    fleet matches the eager paged oracle token for token."""
    cfg = _cfg()
    prompts = _prompts(cfg, 4, 8, seed=23)
    news = [8, 4, 8, 6]

    oracle_eng = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_300_000,
        host_memory_bytes=8_000_000, max_seq_len=40, page_tokens=8)
    rids = [oracle_eng.submit(p, n) for p, n in zip(prompts, news)]
    oracle_eng.run()
    oracle = [oracle_eng.result(r) for r in rids]

    fleet = DistributedServingEngine(
        model_cls=model_class(cfg), cfg=cfg, nproc=2,
        device_memory_bytes=1_300_000, host_memory_bytes=8_000_000,
        compiled=True, max_seq_len=40, page_tokens=8)
    gids = [fleet.submit(p, n) for p, n in zip(prompts, news)]
    fleet.run()
    fleet.check_invariants()
    assert [fleet.result(g) for g in gids] == oracle
