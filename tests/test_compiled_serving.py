"""Compiled serving plane (runtime/serve.py): token-for-token parity
with the eager oracle across the arch zoo, the padded-slot
recompilation policy, stable slot<->chunk binding, and the
DynamicChunkMap explicit-id allocator it relies on."""

import random

import jax
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.chunk import ChunkMapError, DynamicChunkMap, TensorSpec
from repro.core.serving import ServingEngine
from repro.runtime.serve import CompiledServingEngine


def _cfg(arch):
    return get_config(arch, smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _burst(cfg, n=6, plen=8, seed=2):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (n, plen), 0, cfg.vocab_size))


# staggered lifetimes: early completions churn the slot set and leave the
# survivors decoding from divergent positions — the position-vector path
_NEW_TOKENS = [8, 3, 8, 5, 8, 8]


def _serve(cls, cfg, prompts, new_tokens, *, device, host, horizon=24, **kw):
    eng = cls(model_class(cfg), cfg, device_memory_bytes=device,
              host_memory_bytes=host, max_seq_len=horizon, **kw)
    rids = [eng.submit(p, n) for p, n in zip(prompts, new_tokens)]
    for m in eng.run():
        assert m.peak_device_bytes <= eng.device_capacity
    eng.check_invariants()
    return eng, [eng.result(r) for r in rids]


def _parity(arch, device, host):
    """Eager vs compiled round: exact token parity under a device budget
    tight enough that the kv stream pages (both engines replay the same
    plan against the pool, so both must spill)."""
    cfg = _cfg(arch)
    prompts = _burst(cfg)
    eager, out_e = _serve(ServingEngine, cfg, prompts, _NEW_TOKENS,
                          device=device, host=host)
    comp, out_c = _serve(CompiledServingEngine, cfg, prompts, _NEW_TOKENS,
                         device=device, host=host)
    assert out_e == out_c, (out_e, out_c)
    # the budget actually exercised the paging path in both planes
    assert eager.pool.stats.d2h_bytes > 0
    assert comp.pool.stats.d2h_bytes > 0
    return eager, comp


# ---------------------------------------------------------------------------
# acceptance: compiled round == eager oracle (one dense config in tier-1;
# the MoE and non-batch-leading-cache sweeps ride the slow CI job)
# ---------------------------------------------------------------------------


def test_compiled_round_matches_eager_dense():
    _parity("qwen3-0.6b", device=1_300_000, host=8_000_000)


@pytest.mark.slow
def test_compiled_round_matches_eager_moe():
    """MoE: expert capacity is f(token count), so per-sequence routing
    semantics must survive the lowering — the round step's vmap lanes
    keep every sequence's routing independent of slot population."""
    eager, _ = _parity("mixtral-8x7b", device=2_800_000, host=24_000_000)
    # the eager oracle must NOT batch MoE calls (capacity coupling would
    # change tokens); the compiled lanes stay per-sequence by construction
    assert eager._prefill_batchable() is False


@pytest.mark.slow
def test_compiled_round_matches_eager_zamba():
    """Non-batch-leading cache layout (zamba stacks per-unit mamba states
    ahead of the batch dim): the eager engine must serve it sequence-at-
    a-time, the lane-stacked slot layout batches it anyway."""
    eager, comp = _parity("zamba2-1.2b", device=2_000_000, host=24_000_000)
    assert eager._prefill_batchable() is False
    assert comp._prefill_batchable() is True


# ---------------------------------------------------------------------------
# recompilation policy: padded slot shapes, not membership
# ---------------------------------------------------------------------------


def test_no_recompile_on_membership_change():
    """Admission/retire churn within one padded shape must not recompile
    the round step: compilation keys only on the padded slot count."""
    cfg = _cfg("qwen3-0.6b")
    prompts = _burst(cfg)
    comp, _ = _serve(CompiledServingEngine, cfg, prompts, _NEW_TOKENS,
                     device=1_300_000, host=8_000_000)
    # 6 concurrent sequences pad to 8; completions re-bound slots without
    # ever crossing a power of two
    assert comp.padded_slots == 8
    assert comp.decode_compile_count == 1
    # a second wave after full drain reuses every compiled shape
    rids = [comp.submit(p, n) for p, n in zip(prompts, _NEW_TOKENS)]
    comp.run()
    comp.check_invariants()
    assert comp.decode_compile_count == 1
    assert all(comp.result(r) for r in rids)


def test_slot_chunk_binding_is_stable_across_rebinds():
    """Slot s always maps to chunk ids [s*L, (s+1)*L): the kv id space is
    bounded by the padded-slot high-water mark however many sequences
    churn through, and re-admission after a drain walks the same ids."""
    cfg = _cfg("qwen3-0.6b")
    prompts = _burst(cfg)
    comp, _ = _serve(CompiledServingEngine, cfg, prompts, _NEW_TOKENS,
                     device=1_300_000, host=8_000_000)
    total_layers = comp._total_layers
    # second wave: inspect live placements mid-flight
    for p, n in zip(prompts, _NEW_TOKENS):
        comp.submit(p, n)
    comp.step_round()
    cm = comp.kv_mgr.cmap
    for pl in cm.placements:
        rid = int(pl.name.split(".")[1])
        slot = comp._slot_of[rid]
        lo, hi = slot * total_layers, (slot + 1) * total_layers
        assert lo <= pl.chunk_id < hi, (pl.name, pl.chunk_id, slot)
    # id space bounded by peak concurrency's slot range, not request count
    assert cm.num_chunks <= comp.peak_concurrency * total_layers
    comp.run()
    comp.check_invariants()


# ---------------------------------------------------------------------------
# DynamicChunkMap explicit-id binding under padded-slot churn (property
# test: randomized bind/complete traffic, engine-style lowest-free-slot)
# ---------------------------------------------------------------------------


def test_dynamic_map_slot_binding_property_under_churn():
    layers = 3
    rng = random.Random(0)
    for trial in range(20):
        dm = DynamicChunkMap(64)
        live: dict[int, list[str]] = {}  # slot -> tensor names
        high_water = 0
        next_rid = 0
        for _ in range(60):
            if live and (rng.random() < 0.45 or len(live) >= 6):
                slot = rng.choice(sorted(live))
                for n in live.pop(slot):
                    dm.remove_tensor(n)
            else:
                # engine rule: lowest free slot first
                slot = next(s for s in range(len(live) + 1)
                            if s not in live)
                rid = next_rid
                next_rid += 1
                names = []
                for j in range(layers):
                    p = dm.add_tensor(
                        TensorSpec(f"kv.{rid}.{j}", (32,)),
                        chunk_id=slot * layers + j)
                    assert p.chunk_id == slot * layers + j
                    names.append(p.name)
                live[slot] = names
                high_water = max(high_water, len(live))
            # invariants after every mutation:
            # 1. live payload count matches the live slot set
            assert dm.num_payload_chunks == len(live) * layers
            # 2. id space bounded by the slot high-water mark (recycling
            #    works: churn never leaks ids)
            assert dm.num_chunks <= high_water * layers
            # 3. every live tensor sits exactly in its slot's id range
            for slot, names in live.items():
                for j, n in enumerate(names):
                    assert dm.placement(n).chunk_id == slot * layers + j
            # 4. binding into an occupied chunk refuses
            if live:
                slot = next(iter(live))
                with pytest.raises(ChunkMapError):
                    dm.add_tensor(TensorSpec("dup", (1,)),
                                  chunk_id=slot * layers)


def test_dynamic_map_explicit_id_interops_with_default_alloc():
    dm = DynamicChunkMap(16)
    a = dm.add_tensor(TensorSpec("a", (16,)), chunk_id=2)
    assert a.chunk_id == 2
    # ids 0 and 1 were opened below the new high-water mark: default
    # allocation recycles them before growing the id space
    b = dm.add_tensor(TensorSpec("b", (8,)))
    c = dm.add_tensor(TensorSpec("c", (8,)))
    assert {b.chunk_id, c.chunk_id} == {0, 1}
    d = dm.add_tensor(TensorSpec("d", (8,)))
    assert d.chunk_id == 3
    assert dm.num_chunks == 4
    dm.remove_tensor("a")
    e = dm.add_tensor(TensorSpec("e", (4,)), chunk_id=2)
    assert e.chunk_id == 2
    with pytest.raises(ChunkMapError):
        dm.add_tensor(TensorSpec("f", (4,)), chunk_id=-1)
