"""PatrickStarEngine (the paper's eager runtime): learning, heterogeneous
memory accounting, eviction-policy ordering, Listing-1 API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.engine import PatrickStarEngine, initialize_engine


def _cfg():
    return get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _batch(cfg, b=4, s=32, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def test_engine_learns():
    cfg = _cfg()
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=4_000_000, lr=1e-2)
    batch = _batch(cfg)
    losses = [eng.step(batch).loss for _ in range(5)]
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0] * 0.7, losses


def test_warmup_produces_schedule_and_placement():
    cfg = _cfg()
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=16_000_000)
    eng.step(_batch(cfg))
    assert not eng.tracer.warmup
    assert eng.tracer.schedule(), "no chunk moments traced"
    assert eng.placement is not None
    # with generous device memory, some OS groups land in the GPU margin
    assert eng.placement.os_device_groups >= 0


def test_eviction_policy_ordering():
    """OPT (paper) <= LRU <= FIFO in moved bytes on a constrained device,
    with identical losses (policies change placement, never math)."""
    cfg = _cfg()
    budget = 2_500_000
    stats, losses = {}, {}
    for policy in ("opt", "lru", "fifo"):
        eng = PatrickStarEngine(model_class(cfg), cfg,
                                device_memory_bytes=budget, policy=policy,
                                device_aware_placement=False)
        batch = _batch(cfg)
        eng.step(batch)  # warm-up
        m = eng.step(batch)  # measured iteration
        stats[policy] = m.moved_bytes
        losses[policy] = m.loss
    assert stats["opt"] <= stats["lru"] + 1, stats
    assert abs(losses["opt"] - losses["lru"]) < 1e-4
    assert abs(losses["opt"] - losses["fifo"]) < 1e-4


def test_unified_budget_all_streams():
    """One device budget for ALL four streams (param + p32 + m + v): sized
    so they cannot co-reside, the engine still trains (cross-stream
    eviction spills to host instead of OOM) and the pool's device
    high-water mark never exceeds the budget at any moment."""
    cfg = _cfg()
    budget = 3_000_000
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=budget, device_aware_placement=False)
    total_model_bytes = sum(
        m.cmap.num_chunks * m.chunk_bytes
        for m in [eng.params_mgr, *eng.os_mgrs.values()])
    assert total_model_bytes > budget  # genuinely oversubscribed
    batch = _batch(cfg)
    mets = [eng.step(batch) for _ in range(3)]  # no OutOfMemory
    assert all(np.isfinite(m.loss) for m in mets)
    assert eng.pool.peak_device_bytes <= budget
    # metrics report the PER-STEP device peak: bounded by the budget and
    # by the pool's cumulative mark, and present on every step
    assert all(0 < m.peak_device_bytes <= eng.pool.peak_device_bytes
               for m in mets)
    eng.pool.check_invariants()
    # the per-stream views share the pool's accounting
    assert sum(m.device_bytes_used()
               for m in [eng.params_mgr, *eng.os_mgrs.values()]) \
        == eng.pool.device_bytes_used()


def test_prefetch_reduces_critical_path_bytes():
    """Post-warm-up, schedule-driven staging must strictly reduce
    critical-path H2D bytes vs pure demand paging at equal total transfer
    volume (OPT policy) — offloading that is not just 'fits' but 'fast'."""
    cfg = _cfg()
    mets = {}
    for prefetch in (False, True):
        eng = PatrickStarEngine(model_class(cfg), cfg,
                                device_memory_bytes=2_500_000, policy="opt",
                                device_aware_placement=False, prefetch=prefetch)
        batch = _batch(cfg)
        eng.step(batch)  # warm-up
        mets[prefetch] = eng.step(batch)
    demand, staged = mets[False], mets[True]
    assert demand.hidden_h2d_bytes == 0  # demand paging hides nothing
    total = lambda m: m.h2d_bytes + m.adam_h2d_bytes
    assert total(staged) == total(demand) > 0
    assert staged.critical_h2d_bytes < demand.critical_h2d_bytes
    assert staged.hidden_h2d_bytes > 0
    assert staged.prefetch_hit_rate > 0.5
    assert (staged.hidden_h2d_bytes + staged.critical_h2d_bytes
            == total(staged))


def test_grad_reuse_saves_memory():
    """Model data is 14M bytes (4 streams, grads reusing param chunks),
    not 18M (ZeRO-Offload) — Section 6.1."""
    cfg = _cfg()
    eng = PatrickStarEngine(model_class(cfg), cfg,
                            device_memory_bytes=8_000_000)
    streams = 1 + len(eng.os_mgrs)  # param(+grad reuse) and 3 OS streams
    assert streams == 4  # 4 * ~4M bytes-per-chunk-elem == "14M" footprint
    # no dedicated grad manager exists anywhere on the engine
    assert not hasattr(eng, "grads_mgr")


def test_listing1_api():
    cfg = _cfg()
    model, optimizer = initialize_engine(
        model_func=lambda: (model_class(cfg), cfg),
        config={"device_memory_bytes": 4_000_000, "lr": 1e-2})
    batch = _batch(cfg)
    optimizer.zero_grad()
    loss = model(batch)
    model.backward(loss)
    optimizer.step()
    assert np.isfinite(model.loss)
