"""Unified telemetry plane (core/telemetry.py): event<->counter
conservation through the training / serving / compiled / distributed
engines, exact per-step stall attribution via ``take_step`` marks,
Chrome trace export round-trip, the OutOfMemory flight recorder, the
``Tenant.snapshot`` helper, and the disabled-hub byte-identity
guarantee (``telemetry=None`` changes nothing)."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import tracereport
from repro.configs import get_config, model_class
from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.engine import PatrickStarEngine
from repro.core.manager import ChunkManager
from repro.core.memory import HeteroMemory, OutOfMemory
from repro.core.serving import ServingEngine
from repro.core.state import TensorState
from repro.core.telemetry import Telemetry, default_hub, set_default_hub
from repro.core.timeline import TransferTimeline
from repro.runtime.serve import CompiledServingEngine

BUDGET = 4_000_000
_LANE_FIELD = {"h2d": "h2d_stall_s", "d2h": "d2h_stall_s",
               "h2s": "h2s_stall_s", "s2h": "s2h_stall_s",
               "coll": "gather_stall_s"}


def _cfg(layers=4):
    return get_config("gpt2-paper-1b", smoke=True).replace(
        num_layers=layers, param_dtype="float32", compute_dtype="float32")


def _lm_batch(cfg, b, s, seed=1):
    tok = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
            "global_tokens": jnp.float32(b * s)}


def _train(hub, *, steps=3, layers=4, bw=2e8):
    cfg = _cfg(layers)
    tl = TransferTimeline(h2d_bandwidth=bw, d2h_bandwidth=bw)
    eng = PatrickStarEngine(
        model_class(cfg), cfg, device_memory_bytes=BUDGET, policy="opt",
        device_aware_placement=True, timeline=tl, telemetry=hub)
    batch = _lm_batch(cfg, 2, 32)
    mets = [eng.step(batch) for _ in range(steps)]
    eng.pool.check_invariants()
    return eng, mets


# ---------------------------------------------------------------------------
# conservation: events == counters, exactly
# ---------------------------------------------------------------------------


def test_train_conservation_exact():
    """3-step train under tight bandwidth: event-derived per-lane byte
    totals, move counts, hidden/critical split, prefetch lifecycle
    counts and stall seconds all equal the live counters exactly."""
    hub = Telemetry()
    eng, _ = _train(hub)
    assert hub.events, "hub recorded nothing"
    hub.assert_conservation()
    hub.assert_balanced_spans()
    # spot-check the byte identity by hand as well
    assert hub.lane_bytes()["h2d"] == eng.pool.stats.h2d_bytes
    assert hub.lane_bytes()["d2h"] == eng.pool.stats.d2h_bytes
    hidden, critical = hub.h2d_split()
    assert hidden == eng.pool.prefetch.hidden_h2d_bytes
    assert critical == eng.pool.prefetch.critical_h2d_bytes


def test_per_step_stall_attribution_is_exact():
    """Each ``take_step`` mark carries the StepTimeline lane totals, and
    the stall events inside that step's segment sum to them bit-for-bit
    (identical left-folds of the same float sequence)."""
    hub = Telemetry()
    _, mets = _train(hub)
    marks = [seg for seg in hub.step_segments()
             if seg and seg[-1].kind == "mark"
             and seg[-1].name == "take_step"]
    assert len(marks) == len(mets)
    total_stall = 0.0
    for seg, met in zip(marks, mets):
        mark = seg[-1]
        step = met.timeline
        for lane, field in _LANE_FIELD.items():
            got = 0.0
            for ev in seg:
                if ev.kind == "stall" and ev.name == lane:
                    got += ev.dur
            assert got == mark.attrs[field] == getattr(step, field), (
                lane, got, mark.attrs[field], getattr(step, field))
        assert mark.attrs["compute_s"] == step.compute_s
        assert mark.attrs["wall_s"] == step.wall_s
        total_stall += sum(mark.attrs[f] for f in _LANE_FIELD.values())
    assert total_stall > 0.0, "scenario must actually stall"


@pytest.mark.parametrize("cls", [ServingEngine, CompiledServingEngine])
def test_serving_burst_conservation(cls):
    """A serving burst (eager and compiled) conserves bytes and stalls,
    closes every round/op span, and snapshots per-round metrics."""
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    prompts = np.asarray(jax.random.randint(
        jax.random.key(2), (4, 8), 0, cfg.vocab_size))
    hub = Telemetry()
    tl = TransferTimeline(h2d_bandwidth=2e8, d2h_bandwidth=2e8)
    eng = cls(model_class(cfg), cfg, device_memory_bytes=1_200_000,
              host_memory_bytes=8_000_000, max_seq_len=24,
              timeline=tl, telemetry=hub)
    rids = [eng.submit(p, 5) for p in prompts]
    rounds = list(eng.run())
    assert all(eng.result(r) is not None for r in rids)
    eng.check_invariants()
    assert hub.events
    hub.assert_conservation()
    hub.assert_balanced_spans()
    # one per-round snapshot per completed round, in order
    snaps = [s for s in hub.snapshots if s["label"].startswith("serve")
             or ":round" in s["label"]]
    assert len(snaps) == len(rounds)
    trace = hub.chrome_trace()
    assert trace["otherData"]["clock"] == "timeline"
    tracereport.validate(trace)


def test_distributed_rank_tracks():
    """Per-rank cores share one hub: every placeable event is rank-
    tagged after construction, per-rank stall conservation is exact, and
    rank-prefixed tracks stay monotone in the export."""
    from repro.core.distributed import DistributedPatrickStarEngine

    cfg = _cfg(2)
    hub = Telemetry()
    eng = DistributedPatrickStarEngine(
        model_class(cfg), cfg, nproc=2, device_memory_bytes=BUDGET,
        device_aware_placement=False,
        timeline_factory=lambda: TransferTimeline(collective_bandwidth=5e9),
        telemetry=hub)
    batch = _lm_batch(cfg, 4, 32)
    for _ in range(2):
        eng.step(batch)
    eng.check_invariants()
    hub.assert_conservation()
    hub.assert_balanced_spans()
    ranks = {ev.rank for ev in hub.events if ev.kind == "move"}
    assert ranks == {0, 1}
    trace = hub.chrome_trace()
    tracereport.validate(trace)
    tracks = {e["args"]["name"] for e in trace["traceEvents"]
              if e.get("ph") == "M"}
    assert any(t.startswith("rank0/") for t in tracks)
    assert any(t.startswith("rank1/") for t in tracks)


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_roundtrips(tmp_path):
    """The exported JSON is a valid trace_event object that survives a
    json round-trip unchanged, with monotone per-track timestamps and
    balanced spans; counters ride along in otherData."""
    hub = Telemetry()
    _train(hub, steps=2)
    path = tmp_path / "train.json"
    trace = hub.dump_chrome_trace(str(path))
    loaded = tracereport.load(str(path))
    assert loaded == json.loads(json.dumps(trace))
    tracereport.validate(loaded)
    assert loaded["otherData"]["clock"] == "timeline"
    assert loaded["otherData"]["counters"]["lane_bytes"]["h2d"] > 0
    phases = {e["ph"] for e in loaded["traceEvents"]}
    assert {"X", "B", "E", "i", "M"} <= phases


def test_tracereport_cli(tmp_path, capsys):
    hub = Telemetry()
    _train(hub, steps=2)
    path = tmp_path / "train.json"
    hub.dump_chrome_trace(str(path))
    assert tracereport.main([str(path), "--top", "3"]) == 0
    out = capsys.readouterr().out
    assert "valid" in out
    assert "top 3 chunks by transferred bytes" in out
    assert "stall attribution" in out
    assert "eviction churn" in out


def test_span_discipline():
    hub = Telemetry()
    hub.begin_span("t", "outer")
    hub.begin_span("t", "inner")
    hub.end_span("t")
    with pytest.raises(AssertionError, match="unclosed"):
        hub.assert_balanced_spans()
    hub.end_span("t")
    hub.assert_balanced_spans()
    with pytest.raises(AssertionError):
        hub.end_span("t")  # nothing open


# ---------------------------------------------------------------------------
# flight recorder on OutOfMemory
# ---------------------------------------------------------------------------

SIZE = 8
CB = SIZE * 4


def _cmap(n):
    return build_chunk_map([TensorSpec(f"t{i}", (SIZE,)) for i in range(n)],
                           SIZE)


def _hold(mgr, i, dev="device"):
    mgr.access_tensor(f"t{i}", dev)
    mgr.release_tensor(f"t{i}", TensorState.HOLD_AFTER_FWD)


def test_oom_report_appends_flight_recorder():
    """A shielded refusal dumps the last telemetry events into the
    OutOfMemory report, next to the per-tenant usage table — and the
    recorded oom event names the shielding tenants."""
    pool = HeteroMemory(device_capacity_bytes=2 * CB,
                        host_capacity_bytes=2 * CB, policy="fifo")
    hub = Telemetry()
    pool.set_telemetry(hub)
    serve = pool.create_tenant("serve", priority=10,
                               device_budget_bytes=2 * CB,
                               host_budget_bytes=2 * CB)
    kv = ChunkManager(_cmap(2), name="kv", pool=pool, tenant=serve)
    train = ChunkManager(_cmap(4), name="os", pool=pool)
    _hold(kv, 0)
    _hold(kv, 1)             # serve fills the device tier, within budget
    _hold(train, 0, "host")
    _hold(train, 1, "host")  # host full too: no cascade escape
    with pytest.raises(OutOfMemory) as ei:
        _hold(train, 2)
    msg = str(ei.value)
    # the existing tenant-grouped usage table is still there...
    assert "shielded by the soft budget of higher-priority tenant(s): serve" \
        in msg
    assert "serve[64/64]" in msg
    # ...and the flight recorder rides along, with real event lines
    assert "flight recorder (last" in msg
    assert "move h2d" in msg or "state" in msg
    # the recorded oom event names the shielded blockers
    ooms = [ev for ev in hub.events if ev.kind == "oom"]
    assert ooms and ooms[-1].attrs["blocked_by"] == ["serve"]
    assert ooms[-1].name == "no-evictable"
    # the ring is bounded
    assert len(hub.flight_record(8)) <= 8


def test_flight_recorder_ring_is_bounded():
    hub = Telemetry(ring_capacity=16)
    for i in range(100):
        hub.mark(f"m{i}")
    assert len(hub.ring) == 16
    rec = hub.flight_record(32)
    assert len(rec) == 16 and rec[-1].name == "m99"
    assert "m99" in hub.flight_report(4)


# ---------------------------------------------------------------------------
# disabled hub: byte-identity; default hub; Tenant.snapshot
# ---------------------------------------------------------------------------


def test_disabled_hub_is_byte_identical():
    """telemetry=None must not change a single decision: same losses,
    same victims, same counters as a hub-attached run."""
    eng_off, mets_off = _train(None, steps=2)
    eng_on, mets_on = _train(Telemetry(), steps=2)
    assert [m.loss for m in mets_off] == [m.loss for m in mets_on]
    assert eng_off.pool.evictions == eng_on.pool.evictions
    assert eng_off.pool.stats == eng_on.pool.stats
    assert eng_off.pool.prefetch == eng_on.pool.prefetch
    off_t, on_t = mets_off[-1].timeline, mets_on[-1].timeline
    assert off_t.wall_s == on_t.wall_s
    assert off_t.h2d_stall_s == on_t.h2d_stall_s


def test_default_hub_adopted_at_pool_construction():
    hub = Telemetry()
    prev = set_default_hub(hub)
    try:
        pool = HeteroMemory(device_capacity_bytes=4 * CB)
        assert pool.telemetry is hub
        assert default_hub() is hub
    finally:
        set_default_hub(prev)
    pool2 = HeteroMemory(device_capacity_bytes=4 * CB)
    assert pool2.telemetry is None


def test_explicit_hub_detaches_pool_from_default_hub():
    """An explicit telemetry= overriding an adopted default hub detaches
    the pool from it: each hub's counter ground truth covers exactly the
    pools whose events it holds, so BOTH still conserve."""
    default = Telemetry()
    prev = set_default_hub(default)
    try:
        local = Telemetry()
        eng, _ = _train(local, steps=1)
    finally:
        set_default_hub(prev)
    assert eng.pool.telemetry is local
    local.assert_conservation()
    assert local.lane_bytes()["d2h"] > 0
    default.assert_conservation()  # no stranded pools: trivially empty
    assert not [ev for ev in default.events if ev.kind == "move"]
    assert default.counter_totals()["lane_bytes"]["h2d"] == 0


def test_capture_states_off_suppresses_state_events():
    hub = Telemetry(capture_states=False)
    pool = HeteroMemory(device_capacity_bytes=4 * CB)
    pool.set_telemetry(hub)
    mgr = ChunkManager(_cmap(2), name="s", pool=pool)
    _hold(mgr, 0)
    _hold(mgr, 0, "host")  # a real d2h hop
    assert [ev for ev in hub.events if ev.kind == "move"]
    assert not [ev for ev in hub.events if ev.kind == "state"]


def test_tenant_snapshot_returns_independent_copies():
    pool = HeteroMemory(device_capacity_bytes=4 * CB)
    mgr = ChunkManager(_cmap(2), name="s", pool=pool)
    _hold(mgr, 0)
    tenant = pool.default_tenant
    st, pf = tenant.snapshot()
    assert st == tenant.stats and st is not tenant.stats
    assert pf == tenant.prefetch and pf is not tenant.prefetch
    before = st.d2h_bytes
    _hold(mgr, 0, "host")  # a real d2h hop
    assert tenant.stats.d2h_bytes > before  # live object moved on...
    assert st.d2h_bytes == before           # ...the snapshot did not
