# Give the test process a small multi-device CPU topology for the
# distribution tests (tp/dp parity, collectives in HLO).  NOTE: this is
# deliberately 8, not the dry-run's 512 — the production-mesh dry-run
# manages its own device count in repro/launch/dryrun.py.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
