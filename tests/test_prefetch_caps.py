"""Regression tests pinning the two prefetchers' in-flight-cap semantics
and the pool's ``_staged`` set lifecycle.

The caps are MEMORY bounds, not rate limits: staged-but-unconsumed state
(device chunks for :class:`SchedulePrefetcher`, gathered (p-1)/p group
replicas for :class:`GatherPrefetcher`) must never exceed ``max_inflight``
ACROSS calls — a per-call counter would let up to ``lookahead`` entries
pile up over consecutive ``advance()`` calls (the bug this file pins)."""

import numpy as np
import pytest

from repro.core.chunk import TensorSpec, build_chunk_map
from repro.core.manager import ChunkManager
from repro.core.memory import GatherPrefetcher, HeteroMemory, SchedulePrefetcher
from repro.core.state import TensorState


def _pool(n=8, chunk_elems=16, device_chunks=4):
    specs = [TensorSpec(f"t{i}", (chunk_elems,)) for i in range(n)]
    cmap = build_chunk_map(specs, chunk_elems)  # one tensor per chunk
    pool = HeteroMemory(
        device_capacity_bytes=device_chunks * chunk_elems * 4, policy="opt")
    mgr = ChunkManager(cmap, name="param", pool=pool)
    return pool, mgr, cmap


def _park_on_host(mgr, n):
    """Materialize chunks host-side in HOLD (stageable residents)."""
    for i in range(n):
        mgr.access_tensor(f"t{i}", "host")
        mgr.release_tensor(f"t{i}", TensorState.HOLD)


# ---------------------------------------------------------------------------
# SchedulePrefetcher: staged-but-unconsumed <= max_inflight across calls
# ---------------------------------------------------------------------------


def test_schedule_prefetcher_inflight_cap_across_advances():
    pool, mgr, _ = _pool(n=8, device_chunks=8)
    _park_on_host(mgr, 8)
    refs = [(m, "param", m) for m in range(8)]  # chunk m used at moment m
    pool.register_moments("param", {c: [m] for m, _, c in refs})
    pf = SchedulePrefetcher(pool, lookahead=6, max_inflight=2)
    pf.install(refs)
    # advance at successive moments WITHOUT consuming anything: a
    # per-call cap would stage up to `lookahead` chunks here
    for m in range(4):
        pool.set_moment(m)
        pf.advance(m)
        assert len(pool._staged) <= pf.max_inflight, (m, pool._staged)
    assert len(pool._staged) == 2
    # consuming a staged chunk frees a slot; the next advance refills it
    staged_ids = sorted(c for _s, c in pool._staged)
    mgr.access_tensor(f"t{staged_ids[0]}")
    mgr.release_tensor(f"t{staged_ids[0]}", TensorState.HOLD)
    assert len(pool._staged) == 1
    assert pool.prefetch.hits == 1
    pf.advance(staged_ids[0])
    assert len(pool._staged) == 2


def test_schedule_prefetcher_multi_moment_schedule_never_exceeds_cap():
    """Denser schedule (several chunks per moment), tight device tier:
    the staged set stays bounded while demand traffic churns the tier."""
    pool, mgr, _ = _pool(n=8, device_chunks=3)
    _park_on_host(mgr, 8)
    refs = [(m // 2, "param", m) for m in range(8)]  # 2 chunks per moment
    sched = {}
    for m, _s, c in refs:
        sched.setdefault(c, []).append(m)
    pool.register_moments("param", sched)
    pf = SchedulePrefetcher(pool, lookahead=4, max_inflight=2)
    pf.install(refs)
    for m in range(4):
        pool.set_moment(m)
        pf.advance(m)
        assert len(pool._staged) <= pf.max_inflight
        for c in (2 * m, 2 * m + 1):  # consume the moment's chunks
            mgr.access_tensor(f"t{c}")
            mgr.release_tensor(f"t{c}", TensorState.HOLD)
        assert len(pool._staged) <= pf.max_inflight
    pool.check_invariants()


# ---------------------------------------------------------------------------
# _staged lifecycle: eviction / release / unregister all retire entries
# ---------------------------------------------------------------------------


def test_staged_entry_retired_by_eviction_and_counted_wasted():
    pool, mgr, _ = _pool(n=4, device_chunks=1)
    _park_on_host(mgr, 4)
    pool.register_moments("param", {0: [5], 1: [1], 2: [2], 3: [3]})
    pool.set_moment(0)
    assert pool.stage("param", 0)
    assert ("param", 0) in pool._staged
    # a COMPUTE admission of another chunk must evict the staged one
    # (only resident, device holds 1 chunk) and book it wasted
    mgr.access_tensor("t1")
    assert ("param", 0) not in pool._staged
    assert pool.prefetch.wasted_stages == 1
    mgr.release_tensor("t1", TensorState.HOLD)
    pool.check_invariants()


def test_staged_entry_retired_by_release_and_free():
    pool, mgr, _ = _pool(n=4, device_chunks=2)
    _park_on_host(mgr, 2)
    pool.register_moments("param", {0: [5], 1: [6]})
    pool.set_moment(0)
    assert pool.stage("param", 0)
    assert pool.stage("param", 1)
    # FREEing every tensor of a staged chunk drops the payload AND the
    # staged entry (release_payload path)
    mgr.release_tensor("t0", TensorState.FREE)
    assert ("param", 0) not in pool._staged
    assert pool.device_bytes_used() == mgr.chunk_bytes
    pool.check_invariants()


def test_staged_entries_cleared_on_unregister_stream():
    pool, mgr, _ = _pool(n=4, device_chunks=4)
    _park_on_host(mgr, 4)
    pool.register_moments("param", {c: [c + 5] for c in range(4)})
    pool.set_moment(0)
    assert pool.stage("param", 0)
    assert pool.stage("param", 1)
    pool.unregister_stream("param")
    assert not pool._staged
    assert pool.device_bytes_used() == 0 and pool.host_bytes_used() == 0
    # refs naming the unregistered stream are a no-op, not a KeyError
    assert pool.stage("param", 0) is False


# ---------------------------------------------------------------------------
# GatherPrefetcher: unconsumed staged gathers <= max_inflight ACROSS calls
# ---------------------------------------------------------------------------


def test_gather_prefetcher_inflight_cap_across_advances():
    """THE satellite bug: the old per-call counter let every advance()
    stage another group, so up to `lookahead` unconsumed groups could
    hold (p-1)/p bytes each.  The cap must be global until retire()."""
    fetched = []
    pf = GatherPrefetcher(lambda g: fetched.append(g) or True,
                          lookahead=4, max_inflight=1)
    pf.install([(m, m) for m in range(6)])  # group m read at moment m
    pf.advance(0)
    assert fetched == [1] and pf.inflight == {1}
    # consecutive advances WITHOUT a retire must not stage more groups
    assert pf.advance(0) == 0
    assert pf.advance(1) == 0
    assert fetched == [1] and pf.inflight == {1}
    # dropping the group post-FWD/BWD frees the slot
    pf.retire(1)
    pf.advance(1)
    assert fetched == [1, 2] and pf.inflight == {2}


def test_gather_prefetcher_cap_two_and_failed_fetch_not_counted():
    calls = []

    def fetch(g):
        calls.append(g)
        return g % 2 == 0  # odd groups refuse (mixed state / resident)

    pf = GatherPrefetcher(fetch, lookahead=6, max_inflight=2)
    pf.install([(m, m) for m in range(8)])
    pf.advance(0)  # window (0, 6]: groups 1..6; 1 refuses, 2 stages, ...
    assert pf.inflight == {2, 4}
    n0 = len(calls)
    assert pf.advance(1) == 0  # still full: no new staged gathers
    # a full in-flight set must not even probe further fetches
    assert len(calls) == n0
    pf.retire(2)
    pf.advance(2)
    assert pf.inflight == {4, 6}


def test_gather_prefetcher_install_resets_inflight():
    pf = GatherPrefetcher(lambda g: True, lookahead=2, max_inflight=1)
    pf.install([(0, 0), (1, 1)])
    pf.advance(0)
    assert pf.inflight
    pf.install([(0, 0), (1, 1)])  # new iteration schedule
    assert not pf.inflight


# ---------------------------------------------------------------------------
# distributed integration: staged groups retired when replicas drop
# ---------------------------------------------------------------------------


def test_distributed_gather_inflight_bounded_over_steps():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config, model_class
    from repro.core.distributed import DistributedPatrickStarEngine

    cfg = get_config("gpt2-paper-1b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    tok = np.asarray(jax.random.randint(
        jax.random.key(1), (4, 32), 0, cfg.vocab_size))
    batch = {"tokens": tok, "labels": np.roll(tok, -1, 1),
             "global_tokens": np.float32(4 * 32)}
    dist = DistributedPatrickStarEngine(model_class(cfg), cfg, nproc=2,
                                        device_memory_bytes=4_000_000,
                                        gather_lookahead=3)
    gpf = dist.gather_prefetcher
    cap = gpf.max_inflight
    seen_inflight = 0
    orig = gpf.advance

    def tracked(moment):
        out = orig(moment)
        nonlocal seen_inflight
        seen_inflight = max(seen_inflight, len(gpf.inflight))
        assert len(gpf.inflight) <= cap, moment
        return out

    gpf.advance = tracked
    dist.step(batch)  # warm-up installs the gather schedule
    m = dist.step(batch)
    assert seen_inflight >= 1  # the prefetcher actually staged gathers
    assert m.hidden_allgather_bytes > 0
    # every staged group was retired by its post-FWD/BWD drop
    assert not gpf.inflight
    dist.check_invariants()
