"""Chunk-managed serving plane (core/serving.py): token-for-token parity
with the compiled decode path, continuous-batching admission, the dynamic
kv stream's alloc/free/unregister lifecycle, and the managed-vs-unmanaged
capacity win."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_class
from repro.core.serving import ServingEngine
from repro.core.state import TensorState


def _cfg():
    return get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")


def _engine(cfg, *, device=1_200_000, host=8_000_000, horizon=24, **kw):
    return ServingEngine(model_class(cfg), cfg, device_memory_bytes=device,
                         host_memory_bytes=host, max_seq_len=horizon, **kw)


# ---------------------------------------------------------------------------
# acceptance: chunk-managed greedy decode == compiled build_decode_step
# ---------------------------------------------------------------------------


def test_managed_decode_matches_compiled_decode_step():
    """Greedy continuation through the kv chunk stream — under a device
    budget tight enough to force mid-round KV spills — must equal the
    compiled ``driver.build_decode_step`` replay token for token."""
    from repro.configs.base import InputShape
    from repro.core import zero
    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime import driver
    from repro.runtime.step import ChunkedRuntime, RuntimeOptions

    cfg = _cfg()
    mesh = make_smoke_mesh(2, 1)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    # one init tree shared by both planes (tp=1: local == full tensors)
    params = rt.model.init_params(jax.random.key(0))
    pstores = {}
    for name, lay in rt.layouts.items():
        if name == "stem":
            pstores[name] = zero.flatten_to_store(lay, params["stem"])[None]
        else:
            stacked = params["groups"][name]
            pstores[name] = jax.vmap(
                lambda t, _l=lay: zero.flatten_to_store(_l, t))(stacked)[None]

    B, S, new = 4, 10, 6
    horizon = S + new
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    shape = InputShape("serve", horizon, B, "decode")
    dec, _ = driver.build_decode_step(rt, shape)
    caches = driver.init_caches(rt, shape)
    tok = prompts[:, :1]
    gen = []
    for i in range(horizon - 1):
        nxt, caches = dec(pstores, caches,
                          prompts[:, i:i + 1] if i < S else tok, jnp.int32(i))
        if i >= S - 1:
            tok = nxt[:, None].astype(jnp.int32)
            gen.append(np.asarray(nxt))
    compiled = np.stack(gen, 1)  # [B, new]

    # device budget below the param stream alone: params AND kv must page
    eng = _engine(cfg, device=1_200_000, horizon=horizon, init_params=params)
    assert eng.device_capacity < eng._param_stream_bytes + B * eng.kv_seq_bytes
    pn = np.asarray(prompts)
    rids = [eng.submit(pn[i], new) for i in range(B)]
    eng.run()
    for b, rid in enumerate(rids):
        assert eng.result(rid) == compiled[b].tolist(), b
    eng.check_invariants()
    # the tight budget actually exercised the spill path
    assert eng.pool.stats.d2h_bytes > 0
    assert eng.pool.peak_device_bytes <= eng.device_capacity


def test_round_peak_device_within_budget_and_prefetch_hides_bytes():
    cfg = _cfg()
    eng = _engine(cfg, device=1_200_000, horizon=24)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(2), (6, 8), 0, cfg.vocab_size))
    for p in prompts:
        eng.submit(p, 8)
    for m in eng.run():
        assert m.peak_device_bytes <= eng.device_capacity
    eng.check_invariants()
    pf = eng.pool.prefetch
    assert pf.hits > 0 and pf.hidden_h2d_bytes > 0


# ---------------------------------------------------------------------------
# continuous batching: admission queue, mid-flight frees, drain/re-register
# ---------------------------------------------------------------------------


def test_admission_queues_when_budget_full_then_drains():
    """With budgets sized for ~2 concurrent sequences the rest must wait
    in the queue and be admitted as earlier sequences complete — and the
    whole backlog still finishes with the same tokens an uncontended
    engine produces."""
    cfg = _cfg()
    # a long horizon makes one sequence's KV larger than a param chunk,
    # so the total-capacity admission bound binds on KV increments
    horizon = 512
    prompts = np.asarray(jax.random.randint(
        jax.random.key(3), (5, 6), 0, cfg.vocab_size))

    wide = _engine(cfg, device=4_000_000, host=16_000_000, horizon=horizon)
    wide_rids = [wide.submit(p, 5) for p in prompts]
    wide.run()

    # capacity sized so param stream + 2 sequences' kv (+ swap headroom)
    # fit, the third queues
    probe = _engine(cfg, device=1_500_000, host=16_000_000, horizon=horizon)
    host = (probe._param_stream_bytes + probe.params_mgr.chunk_bytes
            + 2 * probe.kv_seq_bytes + probe.kv_seq_bytes // 2 - 1_500_000)
    eng = ServingEngine(model_class(cfg), cfg,
                        device_memory_bytes=1_500_000,
                        host_memory_bytes=host, max_seq_len=horizon)
    rids = [eng.submit(p, 5) for p in prompts]
    first = eng.step_round()
    assert first.admitted == 2 and first.queued == 3
    mets = [first] + eng.run()
    assert sum(m.admitted for m in mets) == 5
    assert all(m.active <= 2 for m in mets)
    for rid, wrid in zip(rids, wide_rids):
        assert eng.result(rid) == wide.result(wrid)
    eng.check_invariants()


def test_kv_stream_unregisters_on_drain_and_reregisters():
    cfg = _cfg()
    eng = _engine(cfg, device=1_500_000, horizon=16)
    p = np.arange(4, dtype=np.int32) % cfg.vocab_size
    r0 = eng.submit(p, 3)
    eng.run()
    # fully drained: the kv stream is gone from the pool
    assert eng.kv_mgr is None
    assert "kv" not in eng.pool.streams
    out0 = eng.result(r0)
    # second wave re-registers the stream from scratch; a fresh engine
    # with the same seed must agree (determinism across re-registration)
    r1 = eng.submit(p, 3)
    eng.run()
    assert eng.result(r1) == out0
    assert eng.kv_mgr is None  # drained again
    eng.check_invariants()


def test_completion_frees_chunks_mid_flight():
    """A short sequence finishing early returns its kv chunks to the pool
    while longer ones keep decoding (continuous batching's whole point)."""
    cfg = _cfg()
    eng = _engine(cfg, device=1_500_000, horizon=24)
    p = np.arange(6, dtype=np.int32) % cfg.vocab_size
    eng.submit(p, 2)   # short
    eng.submit(p, 10)  # long
    eng.step_round()   # prefill both (1 token each)
    assert eng.kv_mgr.cmap.num_payload_chunks == 2 * eng._total_layers
    eng.step_round()   # short completes (2nd token), long continues
    assert eng.active_count == 1
    assert eng.kv_mgr.cmap.num_payload_chunks == eng._total_layers
    eng.run()
    eng.check_invariants()


# ---------------------------------------------------------------------------
# batched prefill: admission cohorts share one g.prefill per layer
# ---------------------------------------------------------------------------


def test_batched_prefill_matches_prefill_batch_one():
    """Cohort prefill (equal-length admissions packed into one batched
    g.prefill per layer) must be token-for-token identical to the
    sequence-at-a-time engine, and must not pay MORE param traffic."""
    cfg = _cfg()
    prompts = np.asarray(jax.random.randint(
        jax.random.key(5), (6, 8), 0, cfg.vocab_size))

    def serve(cap):
        eng = _engine(cfg, device=1_200_000, horizon=24,
                      max_prefill_batch=cap)
        rids = [eng.submit(p, 6) for p in prompts]
        eng.run()
        eng.check_invariants()
        return eng, [eng.result(r) for r in rids]

    batched, out_b = serve(None)  # default: cap = max_decode_batch
    single, out_s = serve(1)
    assert batched.max_prefill_batch > 1
    assert out_b == out_s
    assert batched.pool.stats.h2d_bytes <= single.pool.stats.h2d_bytes


def test_prefill_cohorts_pack_equal_lengths_up_to_cap():
    from repro.core.serving import ServeRequest

    cfg = _cfg()
    eng = _engine(cfg, horizon=24, max_prefill_batch=2)
    newly = [ServeRequest(rid=i, prompt=np.arange(n, dtype=np.int32),
                          max_new_tokens=2)
             for i, n in enumerate((8, 4, 8, 8, 4, 8))]
    cohorts = eng._prefill_cohorts(newly)
    # equal-length runs pack to the cap; lengths never mix in a cohort
    assert [[r.rid for r in c] for c in cohorts] == [[1, 4], [0, 2], [3, 5]]
    # sequence-at-a-time archs (non-batch-leading cache leaves, MoE
    # capacity coupling) force singleton cohorts whatever the cap
    eng._batchable = {k: False for k in eng._batchable}
    assert all(len(c) == 1 for c in eng._prefill_cohorts(newly))


# ---------------------------------------------------------------------------
# capacity: managed kv stream vs unmanaged device-resident caches
# ---------------------------------------------------------------------------


def test_managed_kv_at_least_doubles_concurrency():
    """Fixed tight device budget: the managed kv stream (spillable to
    host) must admit >= 2x the concurrent sequences of the unmanaged
    baseline (raw device arrays), with identical outputs."""
    cfg = _cfg()
    N = 16
    prompts = np.asarray(jax.random.randint(
        jax.random.key(4), (N, 8), 0, cfg.vocab_size))

    def serve(manage_kv, host):
        eng = _engine(cfg, device=1_200_000, host=host, horizon=40,
                      manage_kv=manage_kv)
        rids = [eng.submit(p, 10) for p in prompts]
        eng.run(max_rounds=300)
        eng.check_invariants()
        return eng, [eng.result(r) for r in rids]

    managed, out_m = serve(True, 8_000_000)
    unmanaged, out_u = serve(False, None)
    assert out_m == out_u
    assert managed.peak_concurrency >= 2 * unmanaged.peak_concurrency, (
        managed.peak_concurrency, unmanaged.peak_concurrency)


def test_unmanaged_kv_reserves_device_budget():
    cfg = _cfg()
    eng = _engine(cfg, device=1_200_000, host=None, horizon=40,
                  manage_kv=False)
    p = np.arange(8, dtype=np.int32) % cfg.vocab_size
    for _ in range(12):
        eng.submit(p, 6)
    while eng.queued_count or eng.active_count:
        eng.step_round()
        assert eng.device_bytes_in_use() <= eng.device_capacity
    eng.check_invariants()


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_submit_validations():
    cfg = _cfg()
    eng = _engine(cfg, horizon=8)
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.submit(np.arange(6, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="at least one token"):
        eng.submit(np.zeros((0,), np.int32), 4)
    # a budget that can never host one sequence refuses at submit
    small = ServingEngine(
        model_class(cfg), cfg, device_memory_bytes=1_000_000,
        host_memory_bytes=1_000_000, max_seq_len=8)
    small.host_capacity = 0
    small.pool.host_capacity = 0
    with pytest.raises(ValueError, match="never be admitted"):
        small.submit(np.arange(2, dtype=np.int32), 2)


def test_kv_first_access_zero_fills_like_fresh_cache():
    """A freshly mapped kv tensor is FREE; its first access zero-fills —
    which IS an empty decode cache, so admission needs no init write."""
    cfg = _cfg()
    eng = _engine(cfg, horizon=16)
    eng.submit(np.arange(3, dtype=np.int32), 2)
    newly = eng._admit()
    name = eng._kv_name(newly[0].rid, eng._decode_groups[0].name, 0)
    assert eng.kv_mgr.tensor_state(name) is TensorState.FREE
    view = eng.kv_mgr.access_tensor(name, "device")
    assert not view.any()
    eng.kv_mgr.release_tensor(name, TensorState.HOLD)


# ---------------------------------------------------------------------------
# DynamicChunkMap — the kv stream's mutable mapping (deterministic checks;
# the random-traffic property test lives in test_chunk_map.py)
# ---------------------------------------------------------------------------


def test_dynamic_map_add_remove_recycles_chunk_ids():
    from repro.core.chunk import DynamicChunkMap, TensorSpec

    dm = DynamicChunkMap(64)
    a = dm.add_tensor(TensorSpec("a", (64,)))
    b = dm.add_tensor(TensorSpec("b", (32,)))
    assert (a.chunk_id, a.offset) == (0, 0)
    assert (b.chunk_id, b.offset) == (1, 0)  # one tensor per chunk
    assert dm.num_payload_chunks == 2
    dm.remove_tensor("a")
    assert dm.num_payload_chunks == 1
    with pytest.raises(KeyError):
        dm.placement("a")
    # the freed id is recycled before the id space grows
    c = dm.add_tensor(TensorSpec("c", (64,)))
    assert c.chunk_id == 0
    assert dm.num_chunks == 2  # high-water bound, not live count


def test_dynamic_map_rejects_dup_and_oversize_and_groups():
    from repro.core.chunk import ChunkMapError, DynamicChunkMap, TensorSpec

    dm = DynamicChunkMap(16)
    dm.add_tensor(TensorSpec("a", (16,)))
    with pytest.raises(ChunkMapError):
        dm.add_tensor(TensorSpec("a", (8,)))
    with pytest.raises(ChunkMapError):
        dm.add_tensor(TensorSpec("big", (17,)))
    with pytest.raises(ChunkMapError):
        dm.comm_group(0)  # rank-local: no communication groups
