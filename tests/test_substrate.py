"""Data pipeline, checkpointing, roofline HLO parsing."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import parse_collectives
from repro.configs import get_config, model_class
from repro.configs.base import InputShape
from repro.data.pipeline import PackedLMLoader, SyntheticCorpus, make_batch_fn
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import driver
from repro.runtime.step import ChunkedRuntime, RuntimeOptions


def test_corpus_is_deterministic_and_structured():
    c1 = SyntheticCorpus(512, seed=3)
    c2 = SyntheticCorpus(512, seed=3)
    t1, t2 = c1.tokens(4096), c2.tokens(4096)
    np.testing.assert_array_equal(t1, t2)
    assert t1.min() >= 0 and t1.max() < 512
    # motifs make the stream compressible: repeated 8-grams exist
    views = np.lib.stride_tricks.sliding_window_view(t1, 8)
    uniq = len({tuple(v) for v in views})
    assert uniq <= len(views) - 10  # injected motifs repeat


def test_loader_shards_disjoint_streams():
    c = SyntheticCorpus(128, seed=0)
    l0 = iter(PackedLMLoader(c, 2, 16, shard=(0, 2)))
    l1 = iter(PackedLMLoader(c, 2, 16, shard=(1, 2)))
    b0, b1 = next(l0), next(l1)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


@pytest.mark.parametrize("arch", ["phi-3-vision-4.2b", "whisper-large-v3"])
def test_modality_batches(arch):
    cfg = get_config(arch, smoke=True)
    nxt = make_batch_fn(cfg, 2, 48)
    b = nxt()
    if cfg.arch_type == "vlm":
        assert b["patch_embeds"].shape == (2, cfg.num_patches, cfg.vision_dim)
        assert b["tokens"].shape == (2, 48 - cfg.num_patches)
    else:
        assert b["frames"].shape[0] == 2
        assert b["tokens"].shape == (2, 48)


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import checkpoint as ckpt

    cfg = get_config("qwen3-0.6b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps, oss = driver.init_state(rt, jax.random.key(0))
    shape = InputShape("t", 32, 4, "train")
    step, _, _ = driver.build_train_step(rt, shape)
    tok = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1),
             "global_tokens": jnp.float32(128)}
    ps, oss, m0 = step(ps, oss, batch, jnp.int32(0))
    ckpt.save(rt, ps, oss, str(tmp_path / "ck"), step=1)

    rt2 = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    ps2, oss2, step_no = ckpt.restore(rt2, str(tmp_path / "ck"))
    assert step_no == 1
    # resuming reproduces the same next step as continuing
    step2, _, _ = driver.build_train_step(rt2, shape)
    _, _, m_resume = step2(ps2, oss2, batch, jnp.int32(1))
    _, _, m_cont = step(ps, oss, batch, jnp.int32(1))
    assert abs(float(m_resume["loss"]) - float(m_cont["loss"])) < 1e-5


def test_parse_collectives_synthetic():
    hlo = """
  %ag = bf16[4,1408]{1,0} all-gather(bf16[1,1408]{1,0} %x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups=[2,2]<=[4], to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(f32[8,64]{1,0} %z), replica_groups={{0,1,2,3}}, dimensions={0}
  %dot = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""
    st = parse_collectives(hlo)
    assert set(st.by_kind) == {"all-gather", "all-reduce", "reduce-scatter"}
    ag = st.by_kind["all-gather"]
    assert ag[0] == 1 and ag[1] == 4 * 1408 * 2
    assert abs(ag[2] - 0.75 * 4 * 1408 * 2) < 1e-6
    ar = st.by_kind["all-reduce"]
    assert abs(ar[2] - 2 * 0.5 * 128 * 4) < 1e-6
    rs = st.by_kind["reduce-scatter"]
    assert abs(rs[2] - 0.75 * (2 * 64 * 4) * 4) < 1e-6


def test_train_hlo_has_chunked_collectives():
    """The compiled train step carries the paper's communication pattern:
    all-gather (chunk fetch) + reduce-scatter (grad release)."""
    cfg = get_config("qwen3-0.6b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rt = ChunkedRuntime(model_class(cfg), cfg, mesh, RuntimeOptions())
    shape = InputShape("t", 32, 4, "train")
    jf, args, _ = driver.build_train_step(rt, shape)
    txt = jf.lower(*args).compile().as_text()
    st = parse_collectives(txt)
    assert "all-gather" in st.by_kind
    assert "reduce-scatter" in st.by_kind
